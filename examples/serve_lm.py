"""Batched serving example: prefill + KV-cache decode on any assigned arch.

    PYTHONPATH=src python examples/serve_lm.py --arch deepseek-v2-236b
    PYTHONPATH=src python examples/serve_lm.py --arch rwkv6-7b --tokens 16
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.configs import get_config, reduced
from repro.serving import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-20b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    print(f"serving {cfg.name} (reduced config, family={cfg.family})")
    engine = ServingEngine(cfg, batch_size=args.batch, max_seq=96)

    rng = np.random.default_rng(0)
    requests = [Request(f"req-{i}",
                        rng.integers(0, cfg.vocab_size, 6 + i).astype(np.int32),
                        max_new_tokens=args.tokens)
                for i in range(args.batch)]
    t0 = time.time()
    done = engine.generate(requests)
    dt = time.time() - t0
    for r in done:
        print(f"  {r.request_id}: prompt[{len(r.prompt)}] -> {r.generated}")
    m = engine.metrics
    print(f"prefill={m['prefill_ms']:.0f}ms decode={m['decode_ms']:.0f}ms "
          f"({m['decode_ms']/max(m['tokens'],1):.1f} ms/token) "
          f"wall={dt:.1f}s")


if __name__ == "__main__":
    main()
