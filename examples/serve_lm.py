"""LM serving example: fixed-batch vs continuous batching on any arch.

Runs the same mixed-length request trace twice — once through the
run-to-completion baseline (``generate``), once through the continuous
path (``submit`` + ``drain``: finished sequences leave the decode batch,
freed KV slots are re-primed from fresh prefills) — and prints the
per-request TTFT / tokens-per-second telemetry the engine stamps.

    PYTHONPATH=src python examples/serve_lm.py --arch deepseek-v2-236b
    PYTHONPATH=src python examples/serve_lm.py --arch rwkv6-7b --tokens 16
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.configs import get_config, reduced
from repro.serving import Request, ServingEngine


def make_requests(rng, cfg, n, tokens):
    """Mixed budgets: every fourth request wants 2x the tokens, so a fixed
    batch idles the short rows while continuous batching refills them."""
    return [Request(f"req-{i}",
                    rng.integers(1, cfg.vocab_size, 6 + i % 4).astype(np.int32),
                    max_new_tokens=tokens * 2 if i % 4 == 3 else tokens)
            for i in range(n)]


def report(label, reqs, dt, engine, steps_before=0):
    tokens = sum(len(r.generated) for r in reqs)
    steps = engine.metrics["decode_steps"] - steps_before
    print(f"[{label}] {tokens} tokens in {dt*1e3:.0f}ms "
          f"({tokens/dt:.0f} tok/s, {steps} decode steps)")
    for r in reqs:
        print(f"  {r.request_id}: prompt[{len(r.prompt)}] "
              f"+{len(r.generated)} tokens  ttft={r.ttft_ms:.1f}ms  "
              f"{r.tokens_per_s:.0f} tok/s -> {r.generated[:8]}"
              f"{'...' if len(r.generated) > 8 else ''}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-20b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    print(f"serving {cfg.name} (reduced config, family={cfg.family})")
    n_reqs = args.batch * 2

    def trace():
        return make_requests(np.random.default_rng(0), cfg, n_reqs,
                             args.tokens)

    # fixed-batch baseline: arrival-order groups run to completion
    fixed = ServingEngine(cfg, batch_size=args.batch, max_seq=96)
    for i in range(0, n_reqs, args.batch):     # warmup: jit compiles
        fixed.generate(trace()[i:i + args.batch])
    reqs = trace()
    steps0 = fixed.metrics["decode_steps"]
    t0 = time.perf_counter()
    for i in range(0, n_reqs, args.batch):
        fixed.generate(reqs[i:i + args.batch])
    report("fixed-batch", reqs, time.perf_counter() - t0, fixed, steps0)

    # continuous batching: same trace, requests join/leave the batch per step
    cont = ServingEngine(cfg, params=fixed.params,
                         batch_size=args.batch, max_seq=96)
    for r in trace():                          # warmup: per-length prefills
        cont.submit(r)
    cont.drain()
    reqs = trace()
    steps0 = cont.metrics["decode_steps"]
    t0 = time.perf_counter()
    for r in reqs:
        cont.submit(r)
    cont.drain()
    report("continuous", reqs, time.perf_counter() - t0, cont, steps0)


if __name__ == "__main__":
    main()
