"""End-to-end training driver: any assigned architecture, reduced or custom
size, with checkpointing and deterministic resume.

    # quick demo (seconds):
    PYTHONPATH=src python examples/train_lm.py --arch qwen2.5-32b --steps 20

    # ~100M-parameter run (the deliverable-scale invocation; minutes on CPU,
    # the same code path the 512-chip dry-run lowers):
    PYTHONPATH=src python examples/train_lm.py --arch qwen2.5-32b \
        --d-model 640 --layers 12 --heads 10 --d-ff 2560 --vocab 32768 \
        --steps 300 --batch 4 --seq 512
"""
import argparse
import dataclasses
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.models import count_params
from repro.training import AdamWConfig, build_train_step, init_train_state
from repro.training.checkpoint import CheckpointManager
from repro.training.data import PrefetchIterator, SyntheticTokenDataset


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-32b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--d-model", type=int, default=None)
    ap.add_argument("--layers", type=int, default=None)
    ap.add_argument("--heads", type=int, default=None)
    ap.add_argument("--d-ff", type=int, default=None)
    ap.add_argument("--vocab", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro-train-ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    overrides = {}
    if args.d_model:
        overrides.update(d_model=args.d_model)
    if args.layers:
        overrides.update(num_layers=args.layers)
    if args.heads:
        overrides.update(num_heads=args.heads,
                         num_kv_heads=min(args.heads, cfg.num_kv_heads or 2),
                         head_dim=None)
    if args.d_ff:
        overrides.update(d_ff=args.d_ff)
    if args.vocab:
        overrides.update(vocab_size=args.vocab)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    n = count_params(cfg)
    print(f"arch={cfg.name} params={n/1e6:.1f}M layers={cfg.num_layers} "
          f"d={cfg.d_model} vocab={cfg.vocab_size}")

    state = init_train_state(cfg)
    step_fn = jax.jit(build_train_step(cfg, AdamWConfig(lr=args.lr,
                                                        warmup_steps=20)),
                      donate_argnums=0)
    data = SyntheticTokenDataset(cfg.vocab_size, args.seq, args.batch)
    ckpt = CheckpointManager(args.ckpt_dir, keep=2, async_save=True)

    start = 0
    if args.resume and ckpt.latest_step() is not None:
        state, meta = ckpt.restore(state)
        data.load_state_dict(meta["data"])
        start = meta["step"]
        print(f"resumed from step {start}")

    it = PrefetchIterator(iter(data))
    t0 = time.time()
    for i, batch in zip(range(start, args.steps), it):
        state, metrics = step_fn(state, {k: jnp.asarray(v)
                                         for k, v in batch.items()})
        if i % 10 == 0 or i == args.steps - 1:
            dt = time.time() - t0
            tps = (i - start + 1) * args.batch * args.seq / max(dt, 1e-9)
            print(f"step {i:4d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"tok/s={tps:,.0f}")
        if args.ckpt_every and i and i % args.ckpt_every == 0:
            ckpt.save(i, state, {"data": data.state_dict(), "step": i})
    ckpt.save(args.steps, state, {"data": data.state_dict(),
                                  "step": args.steps})
    ckpt.wait()
    it.close()
    print(f"done; checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
