"""Quickstart: the phys-MCP control plane in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py             # in-process
    PYTHONPATH=src python examples/quickstart.py --remote    # over the wire

Registers the paper's five-backend test bed, then walks the two workflow
styles from paper §IV-D: capability-driven (the matcher picks) and directed
(the client names a backend; the control plane validates).

``--remote`` runs the IDENTICAL flows against the same plane exposed
through a :class:`ControlPlaneGateway`, driven by the
:class:`ControlPlaneClient` SDK — same task objects, same result/trace
types, one extra line of setup.  That symmetry is the protocol-first
redesign's point.
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import Orchestrator, TaskRequest
from repro.substrates import FastService, standard_testbed


def run_flows(discover, submit, twin_state, label):
    print(f"== discovery ({label}) ==")
    for desc in discover():
        cap = desc.capability
        print(f"  {desc.resource_id:24s} class={desc.substrate_class:10s} "
              f"io={cap.input_signal.modality:>13s} "
              f"timing={cap.timing.latency_regime:12s} "
              f"reset={','.join(cap.lifecycle.reset_modes)}")

    print("\n== capability-driven: fast vector inference ==")
    res, trace = submit(TaskRequest(
        function="inference", input_modality="vector",
        output_modality="vector", payload=[0.1, 0.2, 0.3, 0.4],
        required_telemetry=("execution_ms",)))
    print(f"  -> {res.resource_id} status={res.status} "
          f"y={['%.3f' % v for v in res.output['vector']]}")
    print(f"  control overhead: {trace.control_overhead_ms:.3f} ms")

    print("\n== capability-driven: slow chemical assay ==")
    res, _ = submit(TaskRequest(
        function="assay", input_modality="concentration",
        output_modality="concentration",
        payload={"concentrations": [0.1, 0.7, 0.1, 0.1]},
        required_telemetry=("convergence_ms", "contamination")))
    print(f"  -> {res.resource_id} winner=species-{res.output['winner']} "
          f"convergence={res.telemetry['convergence_ms']:.0f}ms "
          f"contamination={res.telemetry['contamination']}")

    print("\n== directed: externalized HTTP backend ==")
    res, _ = submit(TaskRequest(
        function="inference", input_modality="vector",
        output_modality="vector", backend_preference="fast-external",
        payload=[0.5, 0.5, 0.5, 0.5]))
    print(f"  -> {res.resource_id} transport={res.telemetry['transport_ms']}ms")

    print("\n== twin plane ==")
    for rid in ("chemical-ode", "memristive-local"):
        print(f"  {rid}: {twin_state(rid)}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--remote", action="store_true",
                    help="drive the same plane through a gateway + client "
                         "SDK (wire protocol v1) instead of in-process")
    args = ap.parse_args()

    svc = FastService().start()
    orch = Orchestrator()
    standard_testbed(orch, http_service=svc)

    if args.remote:
        from repro.gateway import ControlPlaneClient, ControlPlaneGateway

        gw = ControlPlaneGateway(orch, plane="quickstart").start()
        client = ControlPlaneClient(gw.url)
        print(f"(control plane exposed at {gw.url}, "
              "speaking protocol v1)\n")
        try:
            run_flows(client.discover, client.invoke, client.twin,
                      label="over the wire")
        finally:
            gw.stop()
    else:
        run_flows(orch.discover, orch.submit,
                  lambda rid: orch.twins.get(rid).to_dict(),
                  label="in-process")
    svc.stop()


if __name__ == "__main__":
    main()
