"""The paper's running example (§VII-B): closed-loop evoked-response
screening against the Cortical-Labs-style wetware API path, with fallback.

    PYTHONPATH=src python examples/closed_loop_wetware.py

Stage 1: discover wetware resources exposing spike I/O + recording telemetry.
Stage 2: submit the structured screening task (directed at the CL backend).
Stage 3: receive the normalized result + structured recording artifact.
Then: break the CL path and watch the same request fall back to the
compatible synthetic wetware backend without changing the client contract.
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import Orchestrator, TaskRequest
from repro.substrates import FastService, standard_testbed


def screening_task(**overrides):
    kw = dict(function="screening", input_modality="spikes",
              output_modality="spikes",
              backend_preference="cortical-labs-backend",
              payload={"pattern": [1, 0, 1, 1], "amplitude": 1.0,
                       "window_ms": 120.0},
              required_telemetry=("firing_rate_hz", "response_delay_ms"))
    kw.update(overrides)
    return TaskRequest(**kw)


def main():
    svc = FastService().start()
    orch = Orchestrator()
    adapters = standard_testbed(orch, http_service=svc)

    print("== stage 1: discovery ==")
    wet = orch.discover(input_modality="spikes", repeated=True)
    for d in wet:
        print(f"  {d.resource_id:24s} adapter={d.adapter_type:12s} "
              f"supervision={d.capability.policy.requires_supervision}")

    print("\n== stage 2+3: three directed screening runs ==")
    for i in range(3):
        res, trace = orch.submit(screening_task())
        rec = res.artifacts["recording"]
        print(f"  run {i}: {res.status} on {res.resource_id} "
              f"responded={res.output['responded']} "
              f"rate={res.telemetry['firing_rate_hz']}Hz "
              f"health={res.telemetry['culture_health']} "
              f"artifact={rec['recording_id']} ({rec['channels']}ch)")
        print(f"         session={res.telemetry['session_ms']:.0f}ms "
              f">> observation={res.telemetry['observation_ms']:.0f}ms "
              f"(the paper's timing-structure point)")

    print("\n== fault: CL path down -> fallback to synthetic wetware ==")
    adapters["cortical-labs-backend"].inject_fault("prepare_failure")
    res, trace = orch.submit(screening_task(
        required_telemetry=("firing_rate_hz",)))
    print(f"  -> {res.status} on {res.resource_id} "
          f"(fallback={trace.fallback_used}); attempts: "
          f"{[a['resource'] for a in trace.attempts]}")

    print("\n== safety: unsupervised request is rejected before execution ==")
    res, trace = orch.submit(screening_task(supervision_available=False,
                                            allow_fallback=False))
    print(f"  -> {res.status}: {trace.rejected_reason[:90]}")
    svc.stop()


if __name__ == "__main__":
    main()
