"""Beyond-paper: phys-MCP orchestrating a (simulated) TPU fleet.

    PYTHONPATH=src python examples/orchestrated_training.py

Two pod-slice substrates (same arch, different sharding recipes) register
with the control plane. Work quanta flow through the matcher; we then
inject a straggler and a hard preparation failure and watch the control
plane mitigate and recover through checkpoints — the paper's
match → invoke → validate → fallback loop applied to training
(DESIGN.md §2).
"""
import os
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.substrates.tpu_pod import TpuPodSubstrate
from repro.training.runner import FleetRunner


def main():
    tmp = tempfile.mkdtemp(prefix="fleet-")
    fr = FleetRunner()
    for name, recipe in (("A", "baseline"), ("B", "tp_only")):
        sub = TpuPodSubstrate("internlm2-20b", recipe=recipe,
                              ckpt_dir=os.path.join(tmp, name),
                              batch=2, seq=32)
        fr.add_slice(sub)
        roof = (sub.record or {}).get("roofline", {})
        print(f"registered slice {sub.resource_id}: twin(roofline) "
              f"dominant={roof.get('dominant')} "
              f"step_lb={roof.get('step_time_lb_s', 0):.2f}s")

    print("\n== healthy: matcher places all quanta ==")
    rep = fr.train(quanta=3, steps_per_quantum=2)
    print(f"  placements={rep.placements} losses={[f'{l:.3f}' for l in rep.losses]}")

    primary = max(rep.placements, key=rep.placements.get)
    print(f"\n== straggler injected on {primary} ==")
    fr.slices[primary].inject_straggler(0.4)
    rep2 = fr.train(quanta=2, steps_per_quantum=2)
    print(f"  placements={rep2.placements}  (telemetry-driven mitigation)")

    print(f"\n== hard failure on {primary} (directed at it!) ==")
    fr.slices[primary].inject_fault("prepare_failure")
    rep3 = fr.train(quanta=2, steps_per_quantum=1, preferred=primary)
    print(f"  placements={rep3.placements} fallbacks={rep3.fallbacks} "
          f"(checkpoint-restore on the healthy slice)")


if __name__ == "__main__":
    main()
