"""Sharded AdamW with configurable moment dtype.

Implemented from scratch (no optax dependency): moments live in
``cfg.moment_dtype`` (fp32 default; bf16 for the 236B/340B archs so the
single-pod HBM budget holds — DESIGN.md §5.4), parameters stay in
``cfg.param_dtype``.  The update is fully shardable: every moment tensor
inherits its parameter's NamedSharding, so ZeRO-style optimizer-state
sharding falls out of the FSDP recipe for free.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import common as cm


class OptState(NamedTuple):
    step: Any
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100


def init_opt_state(params, moment_dtype) -> OptState:
    mdt = jnp.dtype(moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return OptState(step=jnp.zeros((), jnp.int32),
                    mu=jax.tree.map(zeros, params),
                    nu=jax.tree.map(zeros, params))


def opt_state_specs(param_specs, moment_dtype) -> OptState:
    """ParamSpec tree → ParamSpec tree for the optimizer state (same axes)."""
    mdt = jnp.dtype(moment_dtype)
    remap = lambda s: cm.ParamSpec(s.shape, s.axes, mdt, "zeros")
    return OptState(step=cm.ParamSpec((), (), jnp.int32, "zeros"),
                    mu=jax.tree.map(remap, param_specs, is_leaf=cm.is_spec),
                    nu=jax.tree.map(remap, param_specs, is_leaf=cm.is_spec))


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def _schedule(hp: AdamWConfig, step):
    warm = jnp.minimum(step.astype(jnp.float32) / max(hp.warmup_steps, 1), 1.0)
    return hp.lr * warm


def apply_updates(hp: AdamWConfig, params, grads, state: OptState):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.asarray(jnp.minimum(1.0, hp.grad_clip / jnp.maximum(gnorm, 1e-12)))
    step = state.step + 1
    lr = jnp.asarray(_schedule(hp, step))
    b1, b2 = hp.b1, hp.b2
    t = step.astype(jnp.float32)
    bc1 = jnp.asarray(1.0 - b1 ** t)
    bc2 = jnp.asarray(1.0 - b2 ** t)

    def upd_one(p, g, m, v):
        # arithmetic dtype follows the moment dtype: fp32 by default, bf16
        # for the 236B/340B configs (DESIGN.md §5.4 — halves the fp32
        # temporaries of the update chain, which dominate peak memory on
        # stacked expert/FFN shards; large-scale bf16-optimizer practice)
        cdt = jnp.float32 if m.dtype == jnp.float32 else jnp.bfloat16
        gf = g.astype(cdt) * scale.astype(cdt)
        mf = b1 * m.astype(cdt) + (1 - b1) * gf
        vf = b2 * v.astype(cdt) + (1 - b2) * jnp.square(gf)
        mhat = mf / bc1.astype(cdt)
        vhat = vf / bc2.astype(cdt)
        delta = (mhat / (jnp.sqrt(vhat) + jnp.asarray(hp.eps, cdt))
                 + jnp.asarray(hp.weight_decay, cdt) * p.astype(cdt))
        newp = (p.astype(cdt) - lr.astype(cdt) * delta).astype(p.dtype)
        return newp, mf.astype(m.dtype), vf.astype(v.dtype)

    # NOTE (EXPERIMENTS.md §Perf, refuted): chunking the update of stacked
    # giants with lax.map RAISED peak memory (deepseek 24.4→31.1 GB) — the
    # loop's stacked outputs cannot alias its live inputs, whereas the plain
    # elementwise chain donates buffers. Keep the straight-line update.
    upd = upd_one

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, OptState(step, new_m, new_v), {"grad_norm": gnorm, "lr": lr}
