from repro.training.optimizer import AdamWConfig, OptState, apply_updates, init_opt_state  # noqa: F401
from repro.training.train_step import (  # noqa: F401
    TrainState,
    abstract_train_state,
    build_train_step,
    init_train_state,
    train_state_specs,
)
