"""The jitted training step: loss → grads → clip → AdamW → metrics."""
from __future__ import annotations

from typing import NamedTuple, Any

import jax
import jax.numpy as jnp

from repro.models import loss_fn, model_specs
from repro.models.common import abstract_params, init_params
from repro.training.optimizer import (AdamWConfig, OptState, apply_updates,
                                      init_opt_state, opt_state_specs)


class TrainState(NamedTuple):
    params: Any
    opt: OptState


def init_train_state(cfg, seed: int = 0) -> TrainState:
    params = init_params(model_specs(cfg), seed)
    return TrainState(params, init_opt_state(params, cfg.moment_dtype))


def abstract_train_state(cfg) -> TrainState:
    specs = model_specs(cfg)
    oss = opt_state_specs(specs, cfg.moment_dtype)
    return TrainState(abstract_params(specs),
                      OptState(jax.ShapeDtypeStruct((), jnp.int32),
                               abstract_params(oss.mu),
                               abstract_params(oss.nu)))


def train_state_specs(cfg):
    """ParamSpec pytree mirroring TrainState (for sharding derivation)."""
    specs = model_specs(cfg)
    return TrainState(specs, opt_state_specs(specs, cfg.moment_dtype))


def build_train_step(cfg, hp: AdamWConfig = AdamWConfig()):
    """Train step with optional gradient accumulation.

    ``cfg.microbatches > 1`` scans over micro-slices of the global batch,
    accumulating fp32 grads sharded like the params — this is what keeps the
    per-step activation footprint (remat layer boundaries, attention blocks,
    xent logits) inside the 16 GB/chip HBM budget at global_batch=256.
    """

    def grad_fn(params, micro):
        return jax.value_and_grad(
            lambda p: loss_fn(cfg, p, micro), has_aux=True)(params)

    def train_step(state: TrainState, batch):
        m = cfg.microbatches
        if m <= 1:
            (loss, metrics), grads = grad_fn(state.params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((m, x.shape[0] // m) + x.shape[1:]), batch)
            adt = jnp.dtype(cfg.grad_accum_dtype)
            acc0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, adt), state.params)

            def body(carry, mb):
                acc, loss_sum = carry
                (loss, metrics), grads = grad_fn(state.params, mb)
                acc = jax.tree.map(
                    lambda a, g: a + g.astype(adt), acc, grads)
                return (acc, loss_sum + loss), metrics

            (acc, loss_sum), metrics = jax.lax.scan(
                body, (acc0, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda a: a / m, acc)
            loss = loss_sum / m
            metrics = jax.tree.map(lambda x: x.mean(), metrics)
        new_params, new_opt, opt_metrics = apply_updates(
            hp, state.params, grads, state.opt)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return TrainState(new_params, new_opt), metrics

    return train_step
