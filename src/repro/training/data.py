"""Synthetic token data pipeline: deterministic, shard-aware, prefetched.

Real deployments stream tokenized shards per host; here the source is a
seeded PRNG stream with a Zipf-ish unigram distribution (so the loss curve
is non-trivial), but the *pipeline machinery* is production-shaped:

- per-host sharding (``host_id``/``num_hosts``) so each data-parallel host
  reads a disjoint stream,
- background prefetch thread with a bounded queue,
- deterministic resume: ``state_dict()``/``load_state_dict()`` capture the
  stream position so checkpoint-restore replays no batch twice.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


class SyntheticTokenDataset:
    def __init__(self, vocab_size: int, seq_len: int, batch_size: int,
                 seed: int = 17, host_id: int = 0, num_hosts: int = 1,
                 zipf_a: float = 1.3):
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.batch_size = batch_size
        self.seed = seed
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.zipf_a = zipf_a
        self._step = 0
        # Zipf-ish unigram distribution over the vocab
        ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
        self._probs = ranks ** (-zipf_a)
        self._probs /= self._probs.sum()

    def _rng_for(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            (self.seed * 1_000_003 + step * self.num_hosts + self.host_id)
            % (2**63))

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = self._rng_for(step)
        # next-token structure: tokens shifted by one make the labels
        stream = rng.choice(self.vocab_size, size=(self.batch_size,
                                                   self.seq_len + 1),
                            p=self._probs)
        return {"tokens": stream[:, :-1].astype(np.int32),
                "labels": stream[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.batch_at(self._step)
            self._step += 1

    # -- deterministic resume -------------------------------------------------
    def state_dict(self) -> Dict:
        return {"step": self._step, "seed": self.seed,
                "host_id": self.host_id, "num_hosts": self.num_hosts}

    def load_state_dict(self, state: Dict) -> None:
        assert state["seed"] == self.seed, "resume with a different seed"
        self._step = int(state["step"])


class PrefetchIterator:
    """Background-thread prefetcher with bounded queue."""

    _SENTINEL = object()

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._it = it
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._stop = threading.Event()
        self._thread.start()

    def _fill(self):
        try:
            for item in self._it:
                if self._stop.is_set():
                    return
                self._q.put(item)
        except BaseException as e:      # propagate into consumer
            self._err = e
        finally:
            self._q.put(self._SENTINEL)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._SENTINEL:
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
