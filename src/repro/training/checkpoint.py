"""Checkpointing: atomic save/restore of train state, async writer, retention.

No external deps: pytrees are flattened with path-derived keys into ``.npz``
archives.  Saves are atomic (tmp + rename), optionally asynchronous (the
fault-tolerance path in ``repro.training.runner`` checkpoints on a cadence
without blocking the step loop), and retention keeps the newest K checkpoints.
Restore validates step metadata and reproduces the exact pytree structure.
"""
from __future__ import annotations

import json
import os
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) if hasattr(p, "idx")
            else str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten(template, flat: Dict[str, np.ndarray]):
    paths_leaves = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths_leaves[0]:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) if hasattr(p, "idx")
            else str(p) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        want = np.asarray(leaf)
        if tuple(arr.shape) != tuple(want.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {want.shape}")
        leaves.append(arr.astype(want.dtype))
    return jax.tree_util.tree_unflatten(paths_leaves[1], leaves)


class CheckpointManager:
    def __init__(self, directory, keep: int = 3, async_save: bool = False):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._pending: Optional[threading.Thread] = None

    # -- save -------------------------------------------------------------
    def save(self, step: int, state, metadata: Optional[Dict] = None) -> Path:
        if self.async_save:
            self.wait()
            host_state = jax.tree.map(np.asarray, state)  # snapshot now
            t = threading.Thread(target=self._write,
                                 args=(step, host_state, metadata or {}))
            t.start()
            self._pending = t
            return self.dir / f"ckpt-{step:08d}.npz"
        return self._write(step, state, metadata or {})

    def _write(self, step: int, state, metadata: Dict) -> Path:
        flat = _flatten(state)
        final = self.dir / f"ckpt-{step:08d}.npz"
        tmp = self.dir / f".tmp-{step:08d}-{os.getpid()}.npz"
        with open(tmp, "wb") as f:
            np.savez(f, **flat)
        meta = dict(metadata, step=step, saved_at=time.time(),
                    leaves=len(flat))
        tmp_meta = self.dir / f".tmp-{step:08d}.json"
        tmp_meta.write_text(json.dumps(meta))
        os.replace(tmp, final)                      # atomic
        os.replace(tmp_meta, self.dir / f"ckpt-{step:08d}.json")
        self._retain()
        return final

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _retain(self) -> None:
        ckpts = self.list_steps()
        for s in ckpts[:-self.keep] if self.keep else []:
            (self.dir / f"ckpt-{s:08d}.npz").unlink(missing_ok=True)
            (self.dir / f"ckpt-{s:08d}.json").unlink(missing_ok=True)

    # -- restore ------------------------------------------------------------
    def list_steps(self) -> List[int]:
        return sorted(int(p.stem.split("-")[1]) for p in
                      self.dir.glob("ckpt-*.npz"))

    def latest_step(self) -> Optional[int]:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, template, step: Optional[int] = None
                ) -> Tuple[Any, Dict]:
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        with np.load(self.dir / f"ckpt-{step:08d}.npz") as z:
            flat = {k: z[k] for k in z.files}
        meta_path = self.dir / f"ckpt-{step:08d}.json"
        meta = json.loads(meta_path.read_text()) if meta_path.exists() else {}
        return _unflatten(template, flat), meta
