"""Fault-tolerant orchestrated training: phys-MCP driving a TPU fleet.

The runner expresses a training job as a stream of ``train_step`` tasks
submitted through the phys-MCP orchestrator over registered
:class:`~repro.substrates.tpu_pod.TpuPodSubstrate` slices:

- the matcher places each work quantum using roofline twins + live telemetry,
- step-time regression (straggler) degrades a slice's snapshot → the matcher
  routes subsequent quanta elsewhere (straggler mitigation),
- invocation/postcondition failures trigger checkpoint-restore fallback on a
  healthy slice (elastic recovery),
- every quantum checkpoints, so the job survives slice loss.

This is the paper's control loop (match → invoke → validate → fallback)
applied to distributed training — DESIGN.md §2's beyond-paper binding.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

from repro.core import Orchestrator, TaskRequest
from repro.substrates.tpu_pod import TpuPodSubstrate


@dataclasses.dataclass
class FleetReport:
    quanta: List[Dict]
    total_steps: int
    fallbacks: int
    placements: Dict[str, int]
    losses: List[float]
    wall_s: float


class FleetRunner:
    def __init__(self, orchestrator: Optional[Orchestrator] = None):
        self.orch = orchestrator or Orchestrator()
        self.slices: Dict[str, TpuPodSubstrate] = {}

    def add_slice(self, substrate: TpuPodSubstrate) -> None:
        self.orch.register(substrate)
        self.slices[substrate.resource_id] = substrate

    def train(self, *, quanta: int = 6, steps_per_quantum: int = 2,
              preferred: Optional[str] = None,
              shared_job: bool = False) -> FleetReport:
        """``shared_job=True`` makes every quantum resume from the latest
        shared checkpoint, so the logical job survives slice loss AND new
        slices joining mid-run (elastic scaling)."""
        t0 = time.time()
        records: List[Dict] = []
        placements: Dict[str, int] = {}
        losses: List[float] = []
        fallbacks = 0
        for q in range(quanta):
            task = TaskRequest(
                function="train_step",
                input_modality="tensor_shards",
                output_modality="tensor_shards",
                payload={"steps": steps_per_quantum,
                         "resume": shared_job},
                required_telemetry=("loss", "step_ms"),
                backend_preference=preferred,
                repeated=True,
            )
            result, trace = self.orch.submit(task)
            rec = {
                "quantum": q,
                "status": result.status,
                "resource": result.resource_id or None,
                "fallback": trace.fallback_used,
                "loss": result.telemetry.get("loss"),
                "step_ms": result.telemetry.get("step_ms"),
                "drift": result.telemetry.get("drift_score"),
            }
            records.append(rec)
            if result.status == "completed":
                placements[result.resource_id] = placements.get(
                    result.resource_id, 0) + 1
                if rec["loss"] is not None:
                    losses.append(float(rec["loss"]))
                if trace.fallback_used:
                    fallbacks += 1
                    # restore the fallback slice from the latest checkpoint
                    self.slices[result.resource_id].reset("restore_checkpoint")
            else:
                fallbacks += 1
        return FleetReport(records, quanta * steps_per_quantum, fallbacks,
                           placements, losses, time.time() - t0)
