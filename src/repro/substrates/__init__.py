from repro.substrates.base import SubstrateAdapter  # noqa: F401
from repro.substrates.chemical import (ChemicalAdapter,  # noqa: F401
                                       ChemicalOdeSurrogate)
from repro.substrates.cortical import (CLClient, CLSimulator,  # noqa: F401
                                       CorticalLabsAdapter)
from repro.substrates.http_fast import FastService, HTTPFastAdapter  # noqa: F401
from repro.substrates.lm_serving import (LmServingAdapter,  # noqa: F401
                                         ServingSurrogate)
from repro.substrates.memristive import (CrossbarMirrorSurrogate,  # noqa: F401
                                         MemristiveAdapter)
from repro.substrates.remote_plane import (RemotePlaneAdapter,  # noqa: F401
                                           federate, federate_all)
from repro.substrates.tpu_pod import (RooflineSurrogate,  # noqa: F401
                                      TpuPodSubstrate)
from repro.substrates.wetware import (WetwareAdapter,  # noqa: F401
                                      WetwareBehavioralSurrogate)


def standard_testbed(orchestrator, *, http_service=None, include_cortical=True):
    """Register the paper's five-backend test bed on an orchestrator.

    Returns dict of adapters keyed by resource id.  ``http_service`` may be a
    running :class:`FastService`; if None one is started (caller stops it).
    """
    adapters = {}
    for a in (ChemicalAdapter(), WetwareAdapter(), MemristiveAdapter()):
        orchestrator.register(a)
        adapters[a.resource_id] = a
    if http_service is None:
        http_service = FastService().start()
    ext = HTTPFastAdapter(http_service.url)
    orchestrator.register(ext)
    adapters[ext.resource_id] = ext
    adapters["_service"] = http_service
    if include_cortical:
        cl = CorticalLabsAdapter()
        orchestrator.register(cl)
        adapters[cl.resource_id] = cl
    return adapters
