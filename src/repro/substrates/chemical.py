"""DNA/chemical backend: ODE-based digital twin behind a chemical adapter
(paper §VI-A).

The twin integrates a small mass-action reaction network (RK4) implementing
a winner-take-all molecular classifier — the kind of computation DNA
strand-displacement systems realize.  Operationally it exercises exactly the
control-plane behaviors the paper targets: slow assay-style timing,
flush/recharge lifecycle, contamination accumulation, convergence telemetry
and strong twin dependence.
"""
from __future__ import annotations

import time
from typing import Dict, Optional

import numpy as np

from repro.core.descriptors import (CapabilityDescriptor, LifecycleSemantics,
                                    Observability, PolicyConstraints,
                                    ResourceDescriptor, SignalSpec,
                                    TimingSemantics)
from repro.core.telemetry import RuntimeSnapshot
from repro.core.twin import TwinState, TwinSurrogate
from repro.substrates.base import SubstrateAdapter

RESOURCE_ID = "chemical-ode"

# simulated assay timing: a real assay runs for seconds-to-minutes; the twin
# integrates the same trajectory numerically and reports simulated latency in
# telemetry while keeping wall-clock cost test-friendly.
SIM_SECONDS = 4.0


class ChemicalODETwin:
    """Mass-action winner-take-all network over n species.

    ds_i/dt = k_cat · w_ij · s_j  −  γ · s_i  −  annihilation(s_i, s_j)
    """

    def __init__(self, n: int = 4, seed: int = 7):
        rng = np.random.default_rng(seed)
        self.n = n
        # weak random cross-coupling + strong autocatalysis: the input drive
        # selects the winner, the annihilation term suppresses the rest
        self.w = 0.1 * rng.uniform(0.0, 1.0, (n, n)) + np.eye(n)
        self.k_cat = 1.2
        self.gamma = 0.35
        self.k_ann = 2.0

    def deriv(self, s, drive):
        prod = self.k_cat * (self.w @ s) + drive
        decay = self.gamma * s
        # pairwise annihilation drives winner-take-all behaviour
        ann = self.k_ann * s * (s.sum() - s)
        return prod - decay - ann

    def integrate(self, s0, t_end: float, dt: float = 0.01):
        drive = np.asarray(s0, np.float64)
        s = drive.copy()
        steps = int(t_end / dt)
        converged_at = t_end
        prev = s.copy()
        for i in range(steps):
            k1 = self.deriv(s, drive)
            k2 = self.deriv(s + 0.5 * dt * k1, drive)
            k3 = self.deriv(s + 0.5 * dt * k2, drive)
            k4 = self.deriv(s + dt * k3, drive)
            s = np.clip(s + dt / 6 * (k1 + 2 * k2 + 2 * k3 + k4), 0.0, 10.0)
            if i % 25 == 0:
                if np.max(np.abs(s - prev)) < 1e-5:
                    converged_at = i * dt
                    break
                prev = s.copy()
        return s, converged_at


class ChemicalOdeSurrogate(TwinSurrogate):
    """Executable ODE twin: integrates the same mass-action network the
    physical assay realizes, with identical parameters and fresh-reagent
    state (no contamination).  Divergence vs the real assay therefore
    measures contamination-induced departure from the nominal dynamics."""

    kind = "ode"
    tolerance = 0.05

    def __init__(self, n: int = 4, seed: int = 7):
        self.model = ChemicalODETwin(n=n, seed=seed)

    def simulate(self, task) -> Dict:
        payload = task.payload if isinstance(task.payload, dict) else {}
        s0 = np.clip(np.asarray(payload.get("concentrations",
                                            [0.25] * self.model.n),
                                np.float64), 0.0, 1.0)
        t0 = time.perf_counter()
        final, conv_t = self.model.integrate(s0, SIM_SECONDS)
        backend_ms = (time.perf_counter() - t0) * 1e3
        return {
            "output": {"concentrations": final.tolist(),
                       "winner": int(np.argmax(final))},
            "telemetry": {
                "convergence_ms": conv_t * 1e3,
                "simulated_assay_ms": SIM_SECONDS * 1e3,
                "contamination": 0.0,
                "calibration_confidence": 1.0,
                "drift_score": 0.0,
                "health_status": "healthy",
                "observation_ms": max(conv_t * 1e3, 600.0),
            },
            "artifacts": {"trajectory_summary": {
                "t_end_s": SIM_SECONDS, "converged_at_s": conv_t}},
            "backend_ms": backend_ms,
        }


class ChemicalAdapter(SubstrateAdapter):
    def __init__(self, resource_id: str = RESOURCE_ID):
        super().__init__()
        self.resource_id = resource_id
        self.twin = ChemicalODETwin()
        self.contamination = 0.0
        self.calibration_confidence = 1.0
        self.invocations_since_flush = 0

    # -- descriptor -----------------------------------------------------------
    def descriptor(self) -> ResourceDescriptor:
        cap = CapabilityDescriptor(
            functions=("assay", "classification"),
            input_signal=SignalSpec("concentration", "float64", (0.0, 1.0),
                                    transduction="pipetting/microfluidic load"),
            output_signal=SignalSpec("concentration", "float64", (0.0, 10.0),
                                     transduction="fluorescence readout"),
            timing=TimingSemantics("slow_seconds", SIM_SECONDS * 1e3,
                                   observation_window_ms=SIM_SECONDS * 1e3,
                                   min_stabilization_ms=500.0,
                                   freshness_ms=300_000.0),
            lifecycle=LifecycleSemantics(
                warmup_ms=200.0, resetable=True,
                reset_modes=("flush", "recharge"), reset_cost_ms=1500.0,
                calibration_interval_s=600.0, recovery_modes=("flush",),
                cooldown_ms=100.0),
            programmability="configurable",
            observability=Observability(
                output_channels=("fluorescence",),
                telemetry_fields=("convergence_ms", "contamination",
                                  "calibration_confidence", "drift_score"),
                drift_indicators=("contamination", "drift_score"),
                twin_linked_fields=("convergence_ms", "drift_score")),
            policy=PolicyConstraints(exclusive=True, max_concurrent=1),
            supports_repeated_invocation=False,
            energy_proxy_mj=0.5,
        )
        return ResourceDescriptor(
            resource_id=self.resource_id, substrate_class="chemical",
            adapter_type="in_process", location="lab",
            twin_binding=f"twin-{self.resource_id}", capability=cap,
            description="ODE-twin DNA/chemical winner-take-all classifier")

    # -- data plane ------------------------------------------------------------
    def prepare(self, session) -> None:
        self._check_prepare_fault()
        # priming: fresh reagents reduce contamination slightly
        self.contamination = max(0.0, self.contamination - 0.02)

    def invoke(self, session) -> Dict:
        payload = session.task.payload or {}
        s0 = np.asarray(payload.get("concentrations",
                                    [0.25] * self.twin.n), np.float64)
        s0 = np.clip(s0, 0.0, 1.0)
        t0 = time.perf_counter()
        final, conv_t = self.twin.integrate(s0, SIM_SECONDS)
        backend_ms = (time.perf_counter() - t0) * 1e3
        self.invocations_since_flush += 1
        self.contamination = min(1.0, self.contamination
                                 + 0.03 * self.invocations_since_flush)
        self.calibration_confidence = max(0.2, 1.0 - 0.5 * self.contamination)
        drift = self.contamination * 0.6
        telemetry = self._apply_telemetry_faults({
            "convergence_ms": conv_t * 1e3,
            "simulated_assay_ms": SIM_SECONDS * 1e3,
            "contamination": round(self.contamination, 4),
            "calibration_confidence": round(self.calibration_confidence, 4),
            "drift_score": round(drift, 4),
            "health_status": "healthy" if drift < 0.5 else "degraded",
            "observation_ms": max(conv_t * 1e3, 600.0),
        })
        return {
            "output": {"concentrations": final.tolist(),
                       "winner": int(np.argmax(final))},
            "telemetry": telemetry,
            "artifacts": {"trajectory_summary": {
                "t_end_s": SIM_SECONDS, "converged_at_s": conv_t}},
            "backend_ms": backend_ms,
            "needs_reset": self.invocations_since_flush >= 3,
        }

    def reset(self, mode: str = "flush") -> None:
        if mode in ("flush", "recharge"):
            self.contamination = 0.0
            self.invocations_since_flush = 0
            self.calibration_confidence = 1.0

    def snapshot(self) -> Optional[RuntimeSnapshot]:
        drift = self.contamination * 0.6
        return RuntimeSnapshot(
            self.resource_id,
            health_status="healthy" if drift < 0.5 else "degraded",
            drift_score=drift, contamination=self.contamination)

    def make_twin(self) -> Optional[TwinState]:
        return TwinState(f"twin-{self.resource_id}", self.resource_id,
                         kind="ode",
                         model={"n": self.twin.n, "k_cat": self.twin.k_cat,
                                "gamma": self.twin.gamma},
                         surrogate=ChemicalOdeSurrogate(n=self.twin.n))
