"""Cortical-Labs-style wetware API path (paper §VI-B, §VIII-A/C).

The paper validates phys-MCP against the public CL API / **CL SDK
Simulator** — i.e. against a session-oriented wetware-facing API surface,
not live tissue.  This module provides the same three layers locally:

    phys-MCP → CorticalLabsAdapter → CLClient → CLSimulator

- :class:`CLSimulator` — session-based API in the CL style: open a session
  against a named culture, upload a stimulation program, run a
  stimulate/record cycle, fetch a structured recording artifact, close.
  Session handling dominates cost (the paper observes 6.9–7.7 s backend vs
  16–50 ms observation; the simulator reproduces that *structure* with a
  scaled-down session cost so benchmarks stay fast, and reports both).
- :class:`CLClient` — thin client wrapper (the CL SDK role).
- :class:`CorticalLabsAdapter` — maps CL primitives into the normalized
  phys-MCP result format, enriching with readiness/health telemetry.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Dict, Optional

import numpy as np

from repro.core.descriptors import (CapabilityDescriptor, LifecycleSemantics,
                                    Observability, PolicyConstraints,
                                    ResourceDescriptor, SignalSpec,
                                    TimingSemantics)
from repro.core.telemetry import RuntimeSnapshot
from repro.core.twin import RecordReplaySurrogate, TwinState
from repro.substrates.base import SubstrateAdapter
from repro.substrates.wetware import SpikeResponseTwin

RESOURCE_ID = "cortical-labs-backend"

_session_ctr = itertools.count(1)


@dataclasses.dataclass
class CLSession:
    session_id: str
    culture_id: str
    opened_at: float
    program: Optional[Dict] = None
    closed: bool = False


class CLSimulator:
    """Local stand-in for the CL SDK Simulator: session + stim/record API."""

    #: emulated session-handling cost (paper: ~7 s; scaled for test speed)
    SESSION_HANDLING_S = 0.25
    #: emulated real-session cost reported in telemetry, for the timing-
    #: structure discussion (backend/session cost >> observation cost)
    REPORTED_SESSION_S = 7.2

    def __init__(self, seed: int = 23):
        self._cultures = {"culture-A": SpikeResponseTwin(seed=seed)}
        self._sessions: Dict[str, CLSession] = {}
        self._health = {"culture-A": 0.92}

    # -- CL-API-shaped surface -------------------------------------------------
    def list_cultures(self):
        return [{"culture_id": c, "health": self._health[c],
                 "electrodes": 64} for c in self._cultures]

    def open_session(self, culture_id: str) -> str:
        if culture_id not in self._cultures:
            raise KeyError(f"unknown culture {culture_id}")
        time.sleep(self.SESSION_HANDLING_S / 2)  # planelint: allow(clock-seam) — emulated CL-API session dwell
        sid = f"cl-session-{next(_session_ctr):04d}"
        self._sessions[sid] = CLSession(sid, culture_id, time.time())  # planelint: allow(clock-seam) — external-API wall stamp
        return sid

    def upload_stim_program(self, session_id: str, program: Dict) -> None:
        self._sessions[session_id].program = dict(program)

    def stim_and_record(self, session_id: str, window_ms: float = 120.0) -> Dict:
        sess = self._sessions[session_id]
        if sess.program is None:
            raise RuntimeError("no stimulation program uploaded")
        time.sleep(self.SESSION_HANDLING_S / 2)  # planelint: allow(clock-seam) — emulated CL-API session dwell
        culture = self._cultures[sess.culture_id]
        t0 = time.perf_counter()
        fp, rate, delay = culture.run(sess.program.get("pattern", [1, 0, 1]),
                                      float(sess.program.get("amplitude", 1.0)),
                                      noise=0.15,
                                      steps=int(window_ms))
        wall_ms = (time.perf_counter() - t0) * 1e3
        self._health[sess.culture_id] = max(
            0.2, self._health[sess.culture_id] - 0.005)
        return {
            "recording_id": f"rec-{session_id}",
            "spike_counts": fp.tolist(),
            "firing_rate_hz": float(rate),
            "response_delay_ms": float(delay),
            # the recording covers window_ms of culture time — that is the
            # authoritative observation span (wall clock runs faster in sim)
            "observation_ms": window_ms,
            "wall_observation_ms": wall_ms,
            "window_ms": window_ms,
            "culture_health": self._health[sess.culture_id],
        }

    def close_session(self, session_id: str) -> None:
        self._sessions[session_id].closed = True


class CLClient:
    """Thin SDK-style client over the simulator (or a real endpoint)."""

    def __init__(self, backend: Optional[CLSimulator] = None):
        self.backend = backend or CLSimulator()

    def discover(self):
        return self.backend.list_cultures()

    def run_screening(self, culture_id: str, pattern, amplitude: float,
                      window_ms: float) -> Dict:
        t0 = time.perf_counter()
        sid = self.backend.open_session(culture_id)
        try:
            self.backend.upload_stim_program(
                sid, {"pattern": list(pattern), "amplitude": amplitude})
            rec = self.backend.stim_and_record(sid, window_ms)
        finally:
            self.backend.close_session(sid)
        rec["session_ms"] = (time.perf_counter() - t0) * 1e3
        rec["session_id"] = sid
        return rec


class CorticalLabsAdapter(SubstrateAdapter):
    """Exposes the CL API path through the same control model as the other
    backends (paper: an existing API-backed integration target, not one of
    the quantitatively evaluated core regimes)."""

    def __init__(self, client: Optional[CLClient] = None,
                 resource_id: str = RESOURCE_ID):
        super().__init__()
        self.client = client or CLClient()
        self.resource_id = resource_id
        self.culture_id = "culture-A"

    def descriptor(self) -> ResourceDescriptor:
        cap = CapabilityDescriptor(
            functions=("screening", "stimulus_response"),
            input_signal=SignalSpec("spikes", "binary_pattern", (0.0, 1.0),
                                    sampling_hz=1000.0,
                                    transduction="CL stimulation program"),
            output_signal=SignalSpec("spikes", "spike_counts", (0.0, 500.0),
                                     transduction="CL recording artifact"),
            timing=TimingSemantics("fast_ms", 50.0,
                                   observation_window_ms=120.0,
                                   min_stabilization_ms=5.0,
                                   freshness_ms=60_000.0),
            lifecycle=LifecycleSemantics(
                warmup_ms=100.0, resetable=True,
                reset_modes=("session_reset", "rest"),
                reset_cost_ms=1000.0, recovery_modes=("rest", "recalibrate"),
                cooldown_ms=200.0),
            programmability="in_situ_adaptive",
            observability=Observability(
                output_channels=("spike_counts", "recording_artifact"),
                telemetry_fields=("firing_rate_hz", "response_delay_ms",
                                  "culture_health", "session_ms",
                                  "observation_ms", "drift_score"),
                drift_indicators=("culture_health",),
                twin_linked_fields=("firing_rate_hz", "culture_health")),
            policy=PolicyConstraints(exclusive=True, requires_supervision=True,
                                     max_stimulation=2.0, biosafety_level=2),
            supports_repeated_invocation=True,
        )
        return ResourceDescriptor(
            resource_id=self.resource_id, substrate_class="wetware",
            adapter_type="external_api", location="sim./lab",
            twin_binding=f"twin-{self.resource_id}", capability=cap,
            description="Cortical-Labs-style wetware API path "
                        "(CL SDK simulator integration target)")

    def prepare(self, session) -> None:
        self._check_prepare_fault()
        cultures = self.client.discover()
        if not cultures:
            raise RuntimeError("no cultures visible through CL API")
        self.culture_id = cultures[0]["culture_id"]

    def invoke(self, session) -> Dict:
        payload = session.task.payload or {}
        rec = self.client.run_screening(
            self.culture_id,
            payload.get("pattern", [1, 0, 1, 1]),
            float(payload.get("amplitude", 1.0)),
            float(payload.get("window_ms", 120.0)))
        health = rec["culture_health"]
        telemetry = self._apply_telemetry_faults({
            "firing_rate_hz": round(rec["firing_rate_hz"], 3),
            "response_delay_ms": round(rec["response_delay_ms"], 3),
            "culture_health": round(health, 4),
            "session_ms": round(rec["session_ms"], 2),
            # reported real-world session cost structure (paper §VIII-C)
            "reported_session_s": CLSimulator.REPORTED_SESSION_S,
            "observation_ms": round(rec["observation_ms"], 3),
            "drift_score": round(max(0.0, 1.0 - health), 4),
            "health_status": "healthy" if health > 0.5 else "degraded",
        })
        return {
            "output": {"responded": rec["firing_rate_hz"] > 1.0,
                       "fingerprint": rec["spike_counts"]},
            "telemetry": telemetry,
            "artifacts": {"recording": {
                "recording_id": rec["recording_id"],
                "format": "spike_counts/v1",
                "channels": len(rec["spike_counts"]),
                "window_ms": rec["window_ms"]}},
            "backend_ms": rec["session_ms"],
            "needs_reset": False,
        }

    def snapshot(self) -> Optional[RuntimeSnapshot]:
        cultures = self.client.discover()
        health = cultures[0]["health"] if cultures else 0.0
        return RuntimeSnapshot(
            self.resource_id,
            health_status="healthy" if health > 0.5 else "degraded",
            drift_score=max(0.0, 1.0 - health), viability=health)

    def make_twin(self) -> Optional[TwinState]:
        # record/replay twin learned from recent recordings: the CL API
        # exposes no culture model, so the twin is what we observed —
        # TwinNotReady until the first real stimulate/record cycle
        return TwinState(f"twin-{self.resource_id}", self.resource_id,
                         kind="record", model={"api": "CL", "sim": True},
                         surrogate=RecordReplaySurrogate())
