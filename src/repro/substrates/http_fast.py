"""Externalized fast backend: HTTP service + adapter (paper §VII-A).

Same fast device-proximate capability profile as the memristive backend but
reached across an explicit software boundary — an HTTP service running in a
separate thread (the paper runs it as a separate same-machine process).
This is NOT a fourth substrate class; it validates that the control-plane
contract survives a real service boundary, and it is the designated fallback
target of the fault campaign.
"""
from __future__ import annotations

import json
import threading
import time
import urllib.request
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Dict, Optional

import numpy as np

from repro.core.descriptors import (CapabilityDescriptor, LifecycleSemantics,
                                    Observability, PolicyConstraints,
                                    ResourceDescriptor, SignalSpec,
                                    TimingSemantics)
from repro.core.telemetry import RuntimeSnapshot
from repro.core.twin import TwinState, TwinSurrogate
from repro.substrates.base import SubstrateAdapter
from repro.substrates.memristive import CrossbarTwin

RESOURCE_ID = "fast-external"


class _Handler(BaseHTTPRequestHandler):
    twin: CrossbarTwin = None  # set by server factory

    def do_POST(self):
        if self.path != "/invoke":
            self.send_error(404)
            return
        length = int(self.headers.get("Content-Length", 0))
        payload = json.loads(self.rfile.read(length) or b"{}")
        x = np.asarray(payload.get("vector", [0.5, 0.5, 0.5, 0.5]), np.float64)
        t0 = time.perf_counter()
        y = self.server.twin.mvm(x[: self.server.twin.g.shape[1]])
        backend_ms = (time.perf_counter() - t0) * 1e3
        body = json.dumps({
            "vector": y.tolist(),
            "backend_ms": backend_ms,
            "drift_score": round(self.server.twin.drift(), 4),
        }).encode()
        self.send_response(200)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def do_GET(self):
        if self.path == "/health":
            body = json.dumps({"status": "ok",
                               "drift_score": round(self.server.twin.drift(),
                                                    4)}).encode()
        elif self.path == "/twin":
            # twin-binding endpoint: the PROGRAMMED (target) conductances,
            # so a control-plane-side mirror surrogate stays synchronized
            # with the service across the software boundary
            body = json.dumps({
                "g_target": self.server.twin.g_target.tolist(),
            }).encode()
        else:
            self.send_error(404)
            return
        self.send_response(200)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def log_message(self, *args):  # quiet
        pass


class FastService:
    """The externalized execution service (own thread, loopback HTTP)."""

    def __init__(self, port: int = 0):
        self.server = ThreadingHTTPServer(("127.0.0.1", port), _Handler)
        self.server.twin = CrossbarTwin(seed=5)
        self.port = self.server.server_address[1]
        self._thread = threading.Thread(target=self.server.serve_forever,
                                        daemon=True)

    def start(self) -> "FastService":
        self._thread.start()
        return self

    def stop(self) -> None:
        self.server.shutdown()
        self.server.server_close()

    @property
    def url(self) -> str:
        return f"http://127.0.0.1:{self.port}"


class HTTPMirrorSurrogate(TwinSurrogate):
    """Mirror twin for the externalized crossbar that RE-FETCHES the
    service's programmed conductances (``GET /twin``) when its cached copy
    ages past the TTL, so a service-side reprogram cannot leave a "valid"
    twin answering with stale weights.  Refresh failures keep the cached
    program (the service being down is exactly when the twin must serve)."""

    REFRESH_TTL_S = 30.0

    def __init__(self, url: str, g_target):
        from repro.substrates.memristive import CrossbarMirrorSurrogate

        self._mirror = CrossbarMirrorSurrogate(g_target)
        self.kind = self._mirror.kind
        self.tolerance = self._mirror.tolerance
        self.url = url
        self._fetched = time.monotonic()  # planelint: allow(clock-seam) — TTL vs real HTTP endpoint
        self._refresh_lock = threading.Lock()

    def _maybe_refresh(self) -> None:
        with self._refresh_lock:
            if time.monotonic() - self._fetched < self.REFRESH_TTL_S:  # planelint: allow(clock-seam) — TTL vs real HTTP endpoint
                return
            # back off even on failure  # planelint: allow(clock-seam)
            self._fetched = time.monotonic()
            try:
                with urllib.request.urlopen(f"{self.url}/twin",
                                            timeout=2) as r:
                    g_target = json.loads(r.read()).get("g_target")
                if g_target is not None:
                    self._mirror.g = np.array(g_target, np.float64)
            except Exception:                              # noqa: BLE001
                pass

    def simulate(self, task) -> Dict:
        self._maybe_refresh()
        return self._mirror.simulate(task)

    def observe(self, task, raw: Dict) -> None:
        self._mirror.observe(task, raw)

    def divergence(self, real_output, twin_output) -> float:
        return self._mirror.divergence(real_output, twin_output)


class HTTPFastAdapter(SubstrateAdapter):
    """Control-plane adapter for the externalized fast backend."""

    def __init__(self, url: str, resource_id: str = RESOURCE_ID):
        super().__init__()
        self.url = url
        self.resource_id = resource_id
        self.last_drift = 0.0

    def descriptor(self) -> ResourceDescriptor:
        cap = CapabilityDescriptor(
            functions=("inference", "mvm"),
            input_signal=SignalSpec("vector", "float32", (-1.0, 1.0)),
            output_signal=SignalSpec("vector", "float32", (-10.0, 10.0)),
            timing=TimingSemantics("fast_ms", 8.0, observation_window_ms=10.0,
                                   freshness_ms=10_000.0),
            lifecycle=LifecycleSemantics(
                warmup_ms=0.0, resetable=True, reset_modes=("reprogram",),
                reset_cost_ms=25.0, recovery_modes=("reprogram",)),
            programmability="tunable",
            observability=Observability(
                output_channels=("vector_out",),
                telemetry_fields=("execution_ms", "drift_score",
                                  "transport_ms"),
                drift_indicators=("drift_score",),
                twin_linked_fields=("drift_score",)),
            policy=PolicyConstraints(exclusive=False, max_concurrent=8),
            supports_repeated_invocation=True,
            energy_proxy_mj=0.001,
        )
        return ResourceDescriptor(
            resource_id=self.resource_id, substrate_class="memristive",
            adapter_type="http", location="edge",
            twin_binding=f"twin-{self.resource_id}", capability=cap,
            description="HTTP-externalized fast vector backend "
                        "(service boundary validation)")

    def prepare(self, session) -> None:
        self._check_prepare_fault()
        with urllib.request.urlopen(f"{self.url}/health", timeout=5) as r:
            if json.loads(r.read()).get("status") != "ok":
                raise RuntimeError("externalized backend unhealthy")

    def invoke(self, session) -> Dict:
        payload = {"vector": list(np.asarray(
            session.task.payload if session.task.payload is not None
            else [0.5, 0.5, 0.5, 0.5], float))}
        data = json.dumps(payload).encode()
        req = urllib.request.Request(f"{self.url}/invoke", data=data,
                                     headers={"Content-Type": "application/json"})
        t0 = time.perf_counter()
        with urllib.request.urlopen(req, timeout=10) as r:
            body = json.loads(r.read())
        rtt_ms = (time.perf_counter() - t0) * 1e3
        backend_ms = float(body.get("backend_ms", 0.0))
        self.last_drift = float(body.get("drift_score", 0.0))
        telemetry = self._apply_telemetry_faults({
            "execution_ms": round(backend_ms, 4),
            "transport_ms": round(rtt_ms - backend_ms, 4),
            "drift_score": self.last_drift,
            "health_status": "healthy",
            "observation_ms": rtt_ms,
        })
        return {
            "output": {"vector": body.get("vector")},
            "telemetry": telemetry,
            "artifacts": {},
            "backend_ms": backend_ms,
            "rtt_ms": rtt_ms,
            "needs_reset": False,
        }

    def snapshot(self) -> Optional[RuntimeSnapshot]:
        return RuntimeSnapshot(self.resource_id, drift_score=self.last_drift)

    def make_twin(self) -> Optional[TwinState]:
        # fetch the service's programmed conductances so the mirror twin is
        # synchronized across the boundary; an unreachable/old service
        # degrades to a metadata-only (non-executable) twin
        surrogate = None
        try:
            with urllib.request.urlopen(f"{self.url}/twin", timeout=5) as r:
                g_target = json.loads(r.read()).get("g_target")
            if g_target is not None:
                surrogate = HTTPMirrorSurrogate(self.url, g_target)
        except Exception:                                  # noqa: BLE001
            surrogate = None
        return TwinState(f"twin-{self.resource_id}", self.resource_id,
                         kind="behavioral", model={"transport": "http"},
                         surrogate=surrogate)
