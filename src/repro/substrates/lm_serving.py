"""LM serving substrate: the serving engine as a first-class plane member.

Exposes ``repro.serving.ServingEngine`` (continuous batching over the
jax/Pallas model stack) through the same descriptor/matcher/twin machinery
as every physical substrate: a task with ``function="generate"`` and
``modality="tokens"`` matches this resource, rides the scheduler/gateway
like any other, and returns per-request TTFT / tokens-per-second telemetry
that the invocation manager feeds onto the ``TelemetryBus``.

The roofline twin becomes a *predictive admission model* here
(``repro.roofline.serving.ServingCostModel``): before a request joins the
waiting queue, its completion time is predicted from the roofline-floored,
measurement-tightened step cost and the engine's current backlog.  A
request that cannot finish inside its deadline budget is refused as a
structured ``DEADLINE`` (:class:`AdmissionRefused` — no breaker penalty, no
lifecycle fault) instead of timing out mid-decode after burning batch
slots.  Admitted requests should therefore never expire mid-decode; the
engine counts any such miss in ``metrics["deadline_expired"]``.

One driver thread owns the decode loop (``ServingEngine.serve_forever``);
``invoke`` is called concurrently by many scheduler workers, each blocking
on its request's completion event.  Prefill jit-compiles once per distinct
prompt length — callers with open-vocabulary length distributions should
quantize prompt lengths client-side (the bench uses a small length set).
"""
from __future__ import annotations

import threading
import time
from typing import Dict, Optional

import numpy as np

from repro.configs import get_config, reduced
from repro.core.descriptors import (CapabilityDescriptor, LifecycleSemantics,
                                    Observability, PolicyConstraints,
                                    ResourceDescriptor, SignalSpec,
                                    TimingSemantics)
from repro.core.errors import AdmissionRefused, ErrorCode
from repro.core.simclock import SYSTEM_CLOCK, Clock
from repro.core.telemetry import RuntimeSnapshot
from repro.core.twin import TwinNotReady, TwinState, TwinSurrogate
from repro.models import paged_support
from repro.roofline.serving import ServingCostModel
from repro.serving.engine import Request, ServingEngine
from repro.substrates.base import SubstrateAdapter

#: generous hard cap on how long one invoke may wait for its tokens (the
#: admission model bounds the realistic wait well below this)
MAX_WAIT_S = 120.0


class ServingSurrogate(TwinSurrogate):
    """Executable serving twin = the admission cost model made answerable.

    It cannot produce real tokens (the surrogate holds no parameters), so a
    twin-served answer carries ``predicted: True`` with the cost model's
    timing estimates; divergence scores the *timing* prediction against
    real serves, which is exactly the fidelity the admission decision
    depends on."""

    kind = "roofline"
    tolerance = 0.5

    def __init__(self, cost: ServingCostModel):
        self.cost = cost

    def observe(self, task, raw: Dict) -> None:
        pass   # the cost model is fed live by the engine's step observers

    def simulate(self, task) -> Dict:
        payload = task.payload if isinstance(task.payload, dict) else {}
        prompt = payload.get("prompt") or []
        max_new = int(payload.get("max_new_tokens", 8))
        if not prompt:
            raise TwinNotReady("serving twin needs a prompt to price")
        pred_ms = self.cost.predict_request_ms(len(prompt), max_new)
        step_ms = self.cost.step_ms()
        ttft_ms = self.cost.prefill_ms(len(prompt))
        tps = 1e3 / max(step_ms, 1e-9)
        return {
            "output": {"predicted": True, "tokens": [],
                       "predicted_total_ms": round(pred_ms, 3)},
            "telemetry": {
                "ttft_ms": round(ttft_ms, 3),
                "tokens_per_s": round(tps, 2),
                "step_ms": round(step_ms, 4),
                "drift_score": 0.0,
                "health_status": "healthy",
                "observation_ms": pred_ms,
            },
            "artifacts": {"cost_model": self.cost.snapshot()},
            "backend_ms": 0.0,
        }

    def divergence(self, real_output, twin_output) -> float:
        r = real_output if isinstance(real_output, dict) else {}
        t = twin_output if isinstance(twin_output, dict) else {}
        real_ms = r.get("total_ms")
        pred_ms = t.get("predicted_total_ms")
        if real_ms is None or pred_ms is None:
            return 1.0
        real_ms, pred_ms = float(real_ms), float(pred_ms)
        return float(min(1.0, abs(real_ms - pred_ms)
                         / max(real_ms, pred_ms, 1e-6)))


class LmServingAdapter(SubstrateAdapter):
    """Continuous-batching LM serving engine behind the substrate surface."""

    def __init__(self, arch: str = "internlm2-20b", *, batch_size: int = 4,
                 max_seq: int = 128, seed: int = 0,
                 max_concurrent: int = 256, safety: Optional[float] = None,
                 calibrate: bool = True, paged: bool = False,
                 page_size: int = 16, pool_pages: Optional[int] = None,
                 prefix_sharing: bool = True,
                 clock: Optional[Clock] = None):
        super().__init__()
        self.arch = arch
        self.resource_id = f"lm-serving-{arch}"
        self.cfg = reduced(get_config(arch))
        self.batch_size = batch_size
        self.max_seq = max_seq
        self.seed = seed
        self.max_concurrent = max_concurrent
        self.calibrate = calibrate
        self.clock = clock if clock is not None else SYSTEM_CLOCK
        self.paged = paged
        self.page_size = page_size
        self.pool_pages = pool_pages
        self.prefix_sharing = prefix_sharing
        kw = {} if safety is None else {"safety": safety}
        if paged and paged_support(self.cfg)[0]:
            max_pages = -(-max_seq // page_size)
            self.pool_pages = (pool_pages if pool_pages is not None
                               else batch_size * max_pages)
            kw.update(page_size=page_size, pool_pages=self.pool_pages)
        self.cost = ServingCostModel(self.cfg, batch_size=batch_size,
                                     max_seq=max_seq, **kw)
        self.engine: Optional[ServingEngine] = None
        self._events: Dict[str, threading.Event] = {}
        self._events_lock = threading.Lock()
        self._stop = threading.Event()
        self._driver: Optional[threading.Thread] = None
        self._req_seq = 0

    # -- descriptor -----------------------------------------------------------
    def descriptor(self) -> ResourceDescriptor:
        step_ms = self.cost.step_ms()
        cap = CapabilityDescriptor(
            functions=("generate", "decode"),
            input_signal=SignalSpec("tokens", "int32_tokens",
                                    (0.0, float(self.cfg.vocab_size))),
            output_signal=SignalSpec("tokens", "int32_tokens",
                                     (0.0, float(self.cfg.vocab_size))),
            timing=TimingSemantics(
                "fast_ms",
                expected_latency_ms=max(
                    self.cost.predict_request_ms(16, 8), 1.0),
                observation_window_ms=max(step_ms, 1.0),
                freshness_ms=600_000.0),
            lifecycle=LifecycleSemantics(
                warmup_ms=2_000.0,        # jit compile of prefill + decode
                resetable=True,
                reset_modes=("flush_queue",),
                reset_cost_ms=100.0,
                recovery_modes=("flush_queue",)),
            programmability="configurable",
            observability=Observability(
                output_channels=("tokens",),
                telemetry_fields=("ttft_ms", "tokens_per_s", "step_ms",
                                  "drift_score"),
                drift_indicators=("drift_score", "step_ms"),
                twin_linked_fields=("step_ms", "ttft_ms")),
            policy=PolicyConstraints(exclusive=False,
                                     max_concurrent=self.max_concurrent),
            supports_repeated_invocation=True,
        )
        kv = (f"paged kv pool={self.pool_pages}x{self.page_size}tok"
              if self.paged and self.pool_pages else "slot-granular kv")
        return ResourceDescriptor(
            resource_id=self.resource_id, substrate_class="lm_serving",
            adapter_type="in_process", location="cloud",
            twin_binding=f"twin-{self.resource_id}", capability=cap,
            description=f"{self.arch} continuous-batching LM serving "
                        f"(batch={self.batch_size}, max_seq={self.max_seq}, "
                        f"{kv}, roofline admission)")

    # -- engine lifecycle -----------------------------------------------------
    def _on_complete(self, r: Request) -> None:
        with self._events_lock:
            ev = self._events.pop(r.request_id, None)
        if ev is not None:
            ev.set()

    def _admission(self, r: Request, engine: ServingEngine) -> None:
        if r.deadline_s is None:
            return
        remaining_ms = (r.deadline_s - self.clock.monotonic()) * 1e3
        backlog = engine.backlog()
        cached = engine.cached_prefix_tokens(r.prompt)
        pred_ms = self.cost.predict_request_ms(
            len(r.prompt), r.max_new_tokens, backlog["decode_tokens"],
            backlog_prefill_tokens=backlog["prefill_tokens"],
            cached_prefix_tokens=cached)
        if pred_ms > remaining_ms:
            raise AdmissionRefused(
                ErrorCode.DEADLINE,
                f"{r.request_id}: predicted completion {pred_ms:.0f}ms "
                f"exceeds remaining deadline budget {remaining_ms:.0f}ms "
                f"(backlog {backlog['decode_tokens']} decode + "
                f"{backlog['prefill_tokens']} prefill tokens)",
                detail={"predicted_ms": round(pred_ms, 1),
                        "remaining_ms": round(remaining_ms, 1),
                        "backlog_tokens": backlog["decode_tokens"],
                        "backlog_prefill_tokens": backlog["prefill_tokens"],
                        "prefix_cached_tokens": cached})

    def prepare(self, session) -> None:
        self._check_prepare_fault()
        if self.engine is not None:
            return
        engine = ServingEngine(self.cfg, batch_size=self.batch_size,
                               max_seq=self.max_seq, seed=self.seed,
                               paged=self.paged, page_size=self.page_size,
                               pool_pages=self.pool_pages,
                               prefix_sharing=self.prefix_sharing,
                               clock=self.clock)
        engine.on_complete = self._on_complete
        engine.admission = self._admission
        engine.on_step_ms = self.cost.observe_step
        engine.on_prefill_ms = self.cost.observe_prefill
        if self.calibrate:
            # compile prefill/decode and seed the cost model with measured
            # step times BEFORE the first real admission decision, so early
            # refusals are priced from observation, not just the roofline
            # floor (the first sample carries compile time; the admission
            # median washes it out as steps accumulate)
            calib = Request("calib-0",
                            np.arange(1, 9, dtype=np.int32) %
                            self.cfg.vocab_size,
                            max_new_tokens=4)
            engine.submit(calib)
            engine.drain()
        self.engine = engine
        self._stop.clear()
        self._driver = threading.Thread(
            target=engine.serve_forever, args=(self._stop,),
            name=f"{self.resource_id}-driver", daemon=True)
        self._driver.start()

    def invoke(self, session) -> Dict:
        payload = session.task.payload if isinstance(session.task.payload,
                                                     dict) else {}
        prompt = np.asarray(payload.get("prompt") or [], np.int32)
        max_new = int(payload.get("max_new_tokens", 8))
        with self._events_lock:
            self._req_seq += 1
            req_id = f"{session.task.task_id}#{self._req_seq}"
            ev = threading.Event()
            self._events[req_id] = ev
        deadline_s = None
        budget_ms = session.task.latency_budget_ms
        if budget_ms is not None:
            deadline_s = self.clock.monotonic() + budget_ms / 1e3
        r = Request(req_id, prompt, max_new_tokens=max_new,
                    deadline_s=deadline_s)
        t0 = time.perf_counter()
        try:
            self.engine.submit(r)
        except AdmissionRefused:
            with self._events_lock:
                self._events.pop(req_id, None)
            raise
        wait_s = MAX_WAIT_S if budget_ms is None \
            else min(MAX_WAIT_S, budget_ms / 1e3 + 30.0)
        if not ev.wait(wait_s):
            with self._events_lock:
                self._events.pop(req_id, None)
            raise RuntimeError(f"{req_id}: serving engine did not complete "
                               f"within {wait_s:.0f}s")
        total_ms = (time.perf_counter() - t0) * 1e3
        step_ms = self.cost.step_ms()
        telemetry = self._apply_telemetry_faults({
            "ttft_ms": round(r.ttft_ms or 0.0, 3),
            "tokens_per_s": round(r.tokens_per_s or 0.0, 2),
            "step_ms": round(step_ms, 4),
            "drift_score": 0.0,
            "health_status": "healthy",
            "observation_ms": total_ms,
            "deadline_expired": bool(r.expired),
            **self.engine.pool_stats(),
        })
        return {
            "output": {"request_id": req_id, "tokens": list(r.generated),
                       "total_ms": round(total_ms, 3)},
            "telemetry": telemetry,
            "artifacts": {"cost_model": self.cost.snapshot()},
            "backend_ms": total_ms,
            "needs_reset": False,
        }

    def reset(self, mode: str = "flush_queue") -> None:
        """Flush queued work and free every slot (runs only while idle —
        the lifecycle manager guarantees no sessions in flight)."""
        if self.engine is None:
            return
        self.engine.flush()

    def close(self) -> None:
        self._stop.set()
        if self.engine is not None:
            self.engine.wake()      # the idle driver parks unbounded
        if self._driver is not None:
            self._driver.join(timeout=2.0)
            self._driver = None

    def snapshot(self) -> Optional[RuntimeSnapshot]:
        if self.engine is None:
            return RuntimeSnapshot(self.resource_id)
        m = self.engine.metrics
        backlog = self.engine.backlog()
        return RuntimeSnapshot(
            self.resource_id,
            health_status="healthy",
            extra={"backlog_tokens": self.engine.backlog_tokens(),
                   "backlog_prefill_tokens": backlog["prefill_tokens"],
                   "live_slots": self.engine.live_slots(),
                   "requests": m["requests"],
                   "deadline_expired": m["deadline_expired"],
                   **self.engine.pool_stats(),
                   **self.cost.snapshot()})

    def make_twin(self) -> Optional[TwinState]:
        return TwinState(f"twin-{self.resource_id}", self.resource_id,
                         kind="roofline",
                         model={"admission": "roofline",
                                **self.cost.snapshot()},
                         surrogate=ServingSurrogate(self.cost))
