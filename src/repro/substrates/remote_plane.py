"""Federated control planes: a whole remote plane as ONE substrate.

:class:`RemotePlaneAdapter` closes the paper's edge→fog→cloud loop: an
entire edge gateway (with however many physical substrates behind it)
registers into a parent — typically cloud — orchestrator as a single
:class:`~repro.substrates.base.SubstrateAdapter`.  Because it is just an
adapter, EVERYTHING the parent control plane knows composes transparently
across the boundary:

- **matching** — the adapter's descriptor aggregates the edge plane's
  resources (union of functions, summed concurrency, fastest timing) for
  one modality profile, so Eq. 1 ranks the remote plane against local
  hardware like any other candidate;
- **circuit breakers** — a dead or flapping edge gateway fails invocations,
  which feed the parent's HealthManager exactly like substrate faults: the
  plane is quarantined, probed, and re-admitted as one unit;
- **twin fallback** — ``make_twin()`` attaches a record/replay surrogate
  that learns from every result crossing back over the wire, so when the
  edge plane is quarantined, opted-in traffic is served from the parent's
  twin of the *plane* (mirroring remote health through result telemetry:
  drift scores in forwarded telemetry drive the shared confidence law).

Tracing stays complete across the hop: the edge plane's own
``OrchestrationTrace`` (which resource it picked, its control overhead) is
carried back verbatim in the invocation artifacts as ``remote_trace``, and
the forwarded task KEEPS its task id — one task, one identity, two planes.

Multi-hop (device → edge → fog → cloud): adapters CHAIN — a fog plane
federates an edge plane which federates a device plane — under three
topology-layer guarantees (``repro.core.topology``):

- **cycle refusal** — ``federate()`` checks the child's transitive
  reachable set (``GET /v1/topology``) against the parent's identity and
  refuses with ``FEDERATION_CYCLE`` before registering;
- **hop budgets** — every forward decrements ``task.hop_budget`` and
  subtracts a wire margin from ``task.deadline_budget_ms``; the parent
  matcher refuses to place a budget-exhausted task on a federated plane
  (surfacing as a structured ``DEADLINE``), and the adapter re-checks as a
  defense line for directed tasks;
- **streaming follower** — ``attach()`` (called by ``federate``) joins
  ONE server-push subscription (``/v1/stream``) per child plane replacing
  the per-call health polling: member health snapshots feed a cached
  aggregate, stream loss pushes a ``failed`` snapshot into the parent bus
  (tripping the parent breaker immediately, no poll-interval lag), and
  registry change-feed events re-aggregate the federated descriptor live —
  fleet membership tracks without ever re-fetching ``discover()``.  The
  subscription is SHARED: all profile adapters of the same (host, port)
  child fan out of a single :class:`_PlaneStreamFollower`, so an N-profile
  child costs one stream connection, not N.

Forwarded execution rides the coalesced wire path (v1.2): ``invoke()``
uses :meth:`ControlPlaneClient.invoke_coalesced`, so N concurrent
federated forwards through one hop share ``/v1/submit_coalesced`` /
``/v1/poll_coalesced`` frames instead of paying 2N round-trips.
"""
from __future__ import annotations

import dataclasses
import random
import threading
import time
from typing import Dict, List, Optional, Tuple

from repro.core.descriptors import (CapabilityDescriptor, LifecycleSemantics,
                                    Observability, PolicyConstraints,
                                    ResourceDescriptor, SignalSpec,
                                    TimingSemantics)
from repro.core.errors import ControlPlaneError, ErrorCode
from repro.core.invocation import InvocationError
from repro.core.telemetry import RuntimeSnapshot
from repro.core.topology import (HOP_WIRE_MARGIN_MS, forward_task,
                                 remaining_budget_ms)
from repro.core.twin import RecordReplaySurrogate, TwinState
from repro.gateway.client import ControlPlaneClient
from repro.gateway.stream import StreamClosed
from repro.substrates.base import SubstrateAdapter

#: wire round-trip margin added to the advertised expected latency so the
#: parent matcher's T term accounts for the extra hop; equals the per-hop
#: deadline-budget decrement so the matcher and the budget math agree
TRANSPORT_MARGIN_MS = HOP_WIRE_MARGIN_MS

_REGIME_ORDER = {"sub_ms": 0, "fast_ms": 1, "slow_seconds": 2}


class _PlaneStreamFollower:
    """ONE ``/v1/stream`` subscription per child plane, fanned out to every
    profile adapter federated from that plane.

    ``federate_all`` registers one adapter per modality profile of the same
    gateway; each used to hold its OWN subscription, so an N-profile child
    cost N idle stream connections and shipped every event N times over the
    wire.  Followers are refcounted per (host, port): ``acquire`` subscribes
    an adapter (starting the loop thread on first use), ``release``
    unsubscribes, and the loop stops — and the registry entry drops — with
    the last adapter.  Per-adapter state (``_stream_ok``, connect counters,
    member snapshot caches, parent registry entries) stays on the adapters;
    the follower only owns the socket and the fan-out."""

    _registry: Dict[Tuple[str, int], "_PlaneStreamFollower"] = {}
    _registry_lock = threading.Lock()

    def __init__(self, client: ControlPlaneClient,
                 key: Tuple[str, int]) -> None:
        self._client = client
        self._key = key
        self._lock = threading.Lock()
        self._subscribers: List["RemotePlaneAdapter"] = []
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._connected = False
        self._active = None          # live TelemetryStream, for interrupt

    @classmethod
    def acquire(cls, adapter: "RemotePlaneAdapter") -> "_PlaneStreamFollower":
        key = (adapter.client._host, adapter.client._port)
        with cls._registry_lock:
            follower = cls._registry.get(key)
            if follower is None or follower._stop.is_set():
                follower = cls(adapter.client, key)
                cls._registry[key] = follower
            follower._subscribe(adapter)
            return follower

    def release(self, adapter: "RemotePlaneAdapter") -> None:
        with self._lock:
            try:
                self._subscribers.remove(adapter)
            except ValueError:
                pass
            if self._subscribers:
                return
        with type(self)._registry_lock:
            if type(self)._registry.get(self._key) is self:
                del type(self)._registry[self._key]
        self._stop.set()
        # interrupt a reader parked in the chunked stream: idle heartbeats
        # are consumed inside the iterator without yielding, so the loop's
        # stop check alone cannot wake it
        with self._lock:
            active = self._active
        if active is not None:
            try:
                active.close()
            except Exception:                              # noqa: BLE001
                pass
        thread = self._thread
        if thread is not None and thread is not threading.current_thread():
            thread.join(timeout=5.0)

    def _subscribe(self, adapter: "RemotePlaneAdapter") -> None:
        with self._lock:
            if adapter not in self._subscribers:
                self._subscribers.append(adapter)
            connected = self._connected
            if self._thread is None:
                self._thread = threading.Thread(
                    target=self._run, daemon=True,
                    name=f"phys-mcp-follow-{self._key[0]}:{self._key[1]}")
                self._thread.start()
        if connected:
            # late-joining profile adapters see the live stream immediately
            # (the connect fan-out may also reach them — the connect counter
            # only ever undercounts if we skip, never breaks if we double)
            adapter._on_follower_connect()

    def _fanout(self) -> List["RemotePlaneAdapter"]:
        with self._lock:
            return list(self._subscribers)

    def _run(self) -> None:
        """Follower loop (one per child plane): cursor=0 requests the
        synthetic registry baseline (current fleet), then live events;
        every event goes to every subscribed profile adapter, which filter
        by their own modality profile."""
        stop = self._stop
        backoff = RemotePlaneAdapter.STREAM_BACKOFF_MIN_S
        while not stop.is_set():
            stream = None
            try:
                stream = self._client.stream(
                    cursor=0, kinds=("registry", "health", "breaker"),
                    heartbeat_s=RemotePlaneAdapter.STREAM_HEARTBEAT_S)
                connected_at = time.time()  # planelint: allow(clock-seam) — wall stamp of a real federation stream
                with self._lock:
                    self._connected = True
                    self._active = stream
                if stop.is_set():
                    return
                for adapter in self._fanout():
                    adapter._on_follower_connect()
                backoff = RemotePlaneAdapter.STREAM_BACKOFF_MIN_S
                for entry in stream:
                    if stop.is_set():
                        return
                    for adapter in self._fanout():
                        adapter._on_stream_event(entry, connected_at)
                # orderly end (max_s or gateway close): treat as loss and
                # resubscribe — the plane may still be alive
            except (StreamClosed, ControlPlaneError, OSError):
                pass
            finally:
                with self._lock:
                    self._connected = False
                    self._active = None
                if stream is not None:
                    stream.close()
            if stop.is_set():
                return
            for adapter in self._fanout():
                adapter._mark_down()
            stop.wait(backoff * (0.5 + random.random()))
            backoff = min(RemotePlaneAdapter.STREAM_BACKOFF_MAX_S,
                          backoff * 2)


class RemotePlaneAdapter(SubstrateAdapter):
    """One remote control plane, adapted into a parent plane's fleet.

    ``modality`` selects which (input, output) modality profile of the
    remote fleet this adapter advertises (a plane with both vector and
    concentration resources federates as one adapter per profile; see
    :func:`federate_all`).  Default: the profile with the most remote
    resources behind it.
    """

    #: remote execution bound for tasks carrying no latency budget: the
    #: deadline is FORWARDED so the remote scheduler abandons queued work
    #: past it, keeping both planes' view of "this task is over" aligned
    #: (an unbounded forward would time out client-side while the edge
    #: keeps executing — and the parent's fallback would double-execute)
    DEFAULT_INVOKE_DEADLINE_S = 120.0

    def __init__(self, client_or_url, resource_id: Optional[str] = None,
                 plane: Optional[str] = None,
                 modality: Optional[Tuple[str, str]] = None,
                 fleet: Optional[List[ResourceDescriptor]] = None,
                 invoke_deadline_s: float = DEFAULT_INVOKE_DEADLINE_S,
                 topology: Optional[Dict] = None):
        super().__init__()
        # -- streaming follower state (attach() starts it); initialized
        # first because descriptor aggregation below reads under the lock
        self._parent = None                    # parent Orchestrator
        self._fleet_lock = threading.Lock()
        self._member_snaps: Dict[str, Dict] = {}
        self._stream_ok = False
        self._follower: Optional[_PlaneStreamFollower] = None
        self._stream_connects = 0
        self.invoke_deadline_s = invoke_deadline_s
        self.client = (client_or_url
                       if isinstance(client_or_url, ControlPlaneClient)
                       else ControlPlaneClient(client_or_url))
        if plane is None or fleet is None:
            # fail fast: the plane must be up at federation time; callers
            # federating several profiles of one plane pass the already-
            # fetched fleet + plane name to skip repeat round-trips
            health = self.client.health()
            plane = plane or health.get("plane", "remote")
            fleet = fleet if fleet is not None else self.client.discover()
        if topology is None:
            topology = self.client.topology()
        #: the child plane's identity + transitive reachable set (cycle
        #: detection happens in federate(), against the parent's topology)
        self.child_plane_id: str = topology["plane_id"]
        self.child_reachable = frozenset(topology.get("reachable")
                                         or (self.child_plane_id,))
        self.plane = plane
        self.resource_id = resource_id or f"plane-{self.plane}"
        self._remote_descs = list(fleet)
        if not self._remote_descs:
            raise ControlPlaneError(ErrorCode.NO_MATCH,
                                    "remote plane exposes no resources")
        self.modality = modality or self._dominant_modality()
        if not self._profile():
            raise ControlPlaneError(
                ErrorCode.NO_MATCH,
                f"remote plane {self.plane!r} has no "
                f"{self.modality[0]}->{self.modality[1]} resources")
        self.last_transport_ms = 0.0
        self.last_remote_resource: Optional[str] = None

    # -- descriptor aggregation ----------------------------------------------
    def _profile(self) -> List[ResourceDescriptor]:
        with self._fleet_lock:
            descs = list(self._remote_descs)
        return [d for d in descs
                if (d.capability.input_signal.modality,
                    d.capability.output_signal.modality) == self.modality]

    def _dominant_modality(self) -> Tuple[str, str]:
        """Most-populated (input, output) modality pair, ties broken
        lexicographically so the default profile is deterministic whatever
        order the remote plane registered its fleet.  Planes with several
        profiles usually want ``federate_all`` (every profile) or an
        explicit ``modality=`` instead of this default."""
        counts: Dict[Tuple[str, str], int] = {}
        with self._fleet_lock:
            descs = list(self._remote_descs)
        for d in descs:
            key = (d.capability.input_signal.modality,
                   d.capability.output_signal.modality)
            counts[key] = counts.get(key, 0) + 1
        return min(counts, key=lambda k: (-counts[k], k))

    def descriptor(self) -> ResourceDescriptor:
        """Aggregate the remote profile into one capability: the plane can
        do the UNION of what its members do, absorb the SUM of their
        concurrency, and answer as fast as its FASTEST member (plus a wire
        margin) — the remote matcher handles per-member placement."""
        members = self._profile()
        caps = [d.capability for d in members]
        functions = tuple(sorted({f for c in caps for f in c.functions}))
        telemetry = tuple(sorted({f for c in caps
                                  for f in c.observability.telemetry_fields}))
        drift = tuple(sorted({f for c in caps
                              for f in c.observability.drift_indicators}))
        fastest = min(caps, key=lambda c: c.timing.expected_latency_ms)
        regime = min((c.timing.latency_regime for c in caps),
                     key=lambda r: _REGIME_ORDER.get(r, 1))
        lo = min(c.input_signal.admissible_range[0] for c in caps)
        hi = max(c.input_signal.admissible_range[1] for c in caps)
        out_lo = min(c.output_signal.admissible_range[0] for c in caps)
        out_hi = max(c.output_signal.admissible_range[1] for c in caps)
        cap = CapabilityDescriptor(
            functions=functions,
            input_signal=SignalSpec(self.modality[0],
                                    fastest.input_signal.encoding, (lo, hi)),
            output_signal=SignalSpec(self.modality[1],
                                     fastest.output_signal.encoding,
                                     (out_lo, out_hi)),
            timing=TimingSemantics(
                regime,
                fastest.timing.expected_latency_ms + TRANSPORT_MARGIN_MS,
                observation_window_ms=max(c.timing.observation_window_ms
                                          for c in caps),
                freshness_ms=min(c.timing.freshness_ms for c in caps)),
            # lifecycle belongs to the remote plane's members; crossing the
            # boundary the only affordance is reconnecting to the gateway
            lifecycle=LifecycleSemantics(warmup_ms=0.0, resetable=True,
                                         reset_modes=("reconnect",),
                                         recovery_modes=("reconnect",)),
            programmability="configurable",
            observability=Observability(
                output_channels=("remote",),
                telemetry_fields=telemetry + ("transport_ms",
                                              "remote_resource_id"),
                drift_indicators=drift,
                twin_linked_fields=drift),
            # per-member policy (supervision, tenancy, safety) is enforced
            # by the remote plane itself on every forwarded task
            policy=PolicyConstraints(
                exclusive=False,
                max_concurrent=sum(max(1, c.policy.max_concurrent)
                                   for c in caps)),
            supports_repeated_invocation=any(c.supports_repeated_invocation
                                             for c in caps),
            energy_proxy_mj=fastest.energy_proxy_mj,
        )
        location = members[0].location if members else "edge"
        return ResourceDescriptor(
            resource_id=self.resource_id,
            substrate_class="federated_plane",
            adapter_type="http", location=location,
            twin_binding=f"twin-{self.resource_id}", capability=cap,
            description=f"federated control plane '{self.plane}' "
                        f"({len(members)} member substrates, "
                        f"modality {self.modality[0]}->{self.modality[1]})")

    # -- data-plane surface ---------------------------------------------------
    def prepare(self, session) -> None:
        # no liveness round-trip here: invoke() on a dead plane fails fast
        # with the same GatewayError one line later, and a per-session
        # health check would double the wire RTTs on the federated hot path
        self._check_prepare_fault()

    def invoke(self, session) -> Dict:
        # strip placement directives that only meant something on THIS
        # plane: the remote matcher owns placement among its members, and
        # twin decisions stay with the parent (a silently twin-served
        # federated result would corrupt the parent's provenance accounting)
        task = session.task.clone(backend_preference=None, twin_mode=None)
        # one federation hop: decrement the hop budget (stamping the
        # default on first forward), subtract the wire margin from the
        # remaining deadline budget, append this plane to the route.  The
        # parent matcher normally refuses exhausted tasks before they get
        # here; this is the defense line for directed placements.
        via = (self._parent.topology.plane_id if self._parent is not None
               else self.resource_id)
        try:
            task = forward_task(task, via, margin_ms=TRANSPORT_MARGIN_MS)
        except ControlPlaneError as e:
            raise InvocationError("invoke", e.message)
        remaining_ms = remaining_budget_ms(task)
        t0 = time.perf_counter()
        # coalesced wire path: concurrent forwards through this adapter (or
        # any sibling sharing the client) ride shared submit/poll frames —
        # per-hop wire cost amortises across in-flight tasks
        result, remote_trace = self.client.invoke_coalesced(
            task, deadline_s=(remaining_ms / 1e3 if remaining_ms is not None
                              else self.invoke_deadline_s))
        rtt_ms = (time.perf_counter() - t0) * 1e3
        backend_ms = float(result.timing_ms.get("backend_ms", 0.0))
        self.last_transport_ms = max(
            0.0, rtt_ms - result.timing_ms.get("total_ms", backend_ms))
        self.last_remote_resource = result.resource_id
        telemetry = dict(result.telemetry)
        telemetry.update({
            "remote_resource_id": result.resource_id,
            "remote_plane": self.plane,
            # deeper hops know the FULL route (their forwarded task carries
            # ours as a prefix); only stamp our own view when this was the
            # final hop
            "hop_route": telemetry.get("hop_route") or list(task.route),
            "remote_control_overhead_ms": round(
                remote_trace.control_overhead_ms, 4),
            "transport_ms": round(self.last_transport_ms, 4),
            "observation_ms": telemetry.get("observation_ms", rtt_ms),
        })
        telemetry = self._apply_telemetry_faults(telemetry)
        artifacts = dict(result.artifacts)
        # the complete cross-boundary trace: the remote plane's own
        # placement record rides home with the result
        artifacts["remote_trace"] = remote_trace.to_wire()
        artifacts["remote_session_id"] = result.session_id
        return {
            "output": result.output,
            "telemetry": telemetry,
            "artifacts": artifacts,
            "backend_ms": backend_ms,
            "rtt_ms": rtt_ms,
            "needs_reset": False,
        }

    def reset(self, mode: str = "reconnect") -> None:
        """Re-arm after a breaker reopen.  Nothing to do on this side: the
        client reconnects lazily on the next request, and the streaming
        follower (if attached) reconnects on its own backoff schedule —
        fleet changes arrive over the descriptor change feed, so no
        re-fetch happens here either."""

    def _aggregate(self, member_snaps: Dict[str, Dict]) -> RuntimeSnapshot:
        """Fold member snapshots into the plane's aggregate.  The child's
        own matcher routes around sick members, so the plane FAILS only
        when every member has (one failed crossbar among healthy peers
        degrades the plane, it does not quarantine it), serves at its
        healthiest member's drift, and absorbs the summed backlog."""
        statuses, drifts, depth = [], [], 0
        for snap in member_snaps.values():
            if not snap:
                continue
            statuses.append(snap.get("health_status", "healthy"))
            drifts.append(float(snap.get("drift_score", 0.0)))
            depth += int(snap.get("queue_depth", 0))
        if statuses and all(s == "failed" for s in statuses):
            health = "failed"
        elif any(s != "healthy" for s in statuses):
            health = "degraded"
        else:
            health = "healthy"
        return RuntimeSnapshot(self.resource_id, health_status=health,
                               drift_score=round(min(drifts, default=0.0), 4),
                               queue_depth=depth,
                               extra={"plane": self.plane,
                                      "members": len(statuses)})

    def snapshot(self) -> Optional[RuntimeSnapshot]:
        """Aggregate remote health.  With the streaming follower attached
        this is WIRE-FREE: the cache is fed by pushed member snapshots, and
        a broken stream reports failed/down (which the parent matcher
        treats as inadmissible even before the breaker trips).  Unattached
        adapters keep the one-shot HTTP aggregation."""
        if self._follower is not None:
            with self._fleet_lock:
                ok, snaps = self._stream_ok, dict(self._member_snaps)
            if not ok:
                return RuntimeSnapshot(self.resource_id,
                                       health_status="failed",
                                       readiness="down", drift_score=1.0)
            return self._aggregate(snaps)
        try:
            health = self.client.health()
        except Exception:                                  # noqa: BLE001
            return RuntimeSnapshot(self.resource_id, health_status="failed",
                                   readiness="down", drift_score=1.0)
        return self._aggregate(health.get("resources") or {})

    def make_twin(self) -> Optional[TwinState]:
        """Record/replay twin OF THE PLANE: learns from every forwarded
        result, mirrors remote health through the forwarded drift scores
        (the shared confidence law consumes them from result telemetry),
        and serves opted-in traffic when the plane is quarantined."""
        return TwinState(f"twin-{self.resource_id}", self.resource_id,
                         kind="record",
                         model={"plane": self.plane,
                                "members": len(self._remote_descs)},
                         surrogate=RecordReplaySurrogate(capacity=64))

    # -- streaming follower ---------------------------------------------------
    #: reconnect backoff bounds (seconds); jittered so a fleet of parents
    #: does not stampede a recovering child
    STREAM_BACKOFF_MIN_S, STREAM_BACKOFF_MAX_S = 0.2, 2.0
    #: follower heartbeat interval — bounds dead-plane detection latency
    STREAM_HEARTBEAT_S = 1.0
    #: ignore replayed health/breaker ring events older than this before
    #: the (re)connect: history must not re-trip a recovered breaker
    STREAM_STALE_S = 2.0

    def attach(self, parent_orchestrator) -> "RemotePlaneAdapter":
        """Wire this adapter into its parent plane: remember the parent
        (route stamping, registry re-aggregation, bus access) and join the
        child plane's shared streaming follower (one ``/v1/stream``
        subscription per (host, port), however many profile adapters ride
        it).  Called by :func:`federate`; idempotent."""
        self._parent = parent_orchestrator
        if self._follower is None:
            self._follower = _PlaneStreamFollower.acquire(self)
        return self

    def close(self) -> None:
        """Detach from the shared streaming follower (the parent keeps
        whatever state it has already learned).  The follower itself stops
        with its LAST subscriber — sibling profile adapters of the same
        child plane keep streaming."""
        follower, self._follower = self._follower, None
        if follower is not None:
            follower.release(self)

    def _mark_down(self) -> None:
        with self._fleet_lock:
            self._stream_ok = False
        if self._parent is not None:
            # the failed snapshot is what trips the parent breaker the
            # moment the stream breaks — no poll interval in the loop
            self._parent.bus.update_snapshot(RuntimeSnapshot(
                self.resource_id, health_status="failed", readiness="down",
                drift_score=1.0, extra={"plane": self.plane,
                                        "stream": "lost"}))

    def _on_follower_connect(self) -> None:
        """Shared follower (re)connected: resume wire-free aggregation.
        The connect counter makes reconnect behaviour observable (tests
        assert the follower re-subscribed after a gateway restart)."""
        with self._fleet_lock:
            self._stream_ok = True
            self._stream_connects += 1
            snaps = dict(self._member_snaps)
        if self._parent is not None:
            # plane reachable again; member health streams in live
            self._parent.bus.update_snapshot(self._aggregate(snaps))

    def _on_stream_event(self, entry: Dict, connected_at: float) -> None:
        kind = entry.get("kind")
        stale = entry.get("timestamp", connected_at) \
            < connected_at - self.STREAM_STALE_S
        if kind == "registry" and not stale:
            self._apply_registry_event(entry)
        elif kind == "health" and not stale:
            fields = dict(entry.get("fields") or {})
            with self._fleet_lock:
                self._member_snaps[entry["resource_id"]] = fields
                snaps = dict(self._member_snaps)
            if self._parent is not None:
                self._parent.bus.update_snapshot(self._aggregate(snaps))
        # breaker transitions of members need no parent-side action: the
        # child's own matcher routes around them, and member snapshots
        # already carry the resulting health

    def _apply_registry_event(self, entry: Dict) -> None:
        """Descriptor change feed: keep the remote fleet view — and the
        parent's aggregated descriptor — current without any re-fetch."""
        fields = entry.get("fields") or {}
        try:
            desc = ResourceDescriptor.from_dict(fields.get("descriptor")
                                                or {})
        except (TypeError, ValueError, KeyError):
            return
        with self._fleet_lock:
            before = [d for d in self._remote_descs
                      if d.resource_id != desc.resource_id]
            if fields.get("action") == "unregister":
                changed = len(before) != len(self._remote_descs)
                self._remote_descs = before
                # drop the member's cached health with it: a ghost entry
                # would skew the aggregate forever (stale degraded status,
                # or diluting the all-members-failed check)
                self._member_snaps.pop(desc.resource_id, None)
                snaps = dict(self._member_snaps)
            else:
                changed = True
                self._remote_descs = before + [desc]
                snaps = None
        if snaps is not None and self._parent is not None:
            self._parent.bus.update_snapshot(self._aggregate(snaps))
        profile_member = (desc.capability.input_signal.modality,
                          desc.capability.output_signal.modality) \
            == self.modality
        if not (changed and profile_member and self._parent is not None):
            return
        registry = self._parent.registry
        if self._profile():
            # re-aggregate in place: same resource_id + adapter, fresh
            # capability union (epoch bump invalidates matcher caches)
            registry.register(self.descriptor(), self)
        elif registry.get(self.resource_id) is not None:
            # last member of this profile left: the plane no longer serves
            # this modality — withdraw until the feed re-adds a member
            registry.unregister(self.resource_id)


def federate(parent_orchestrator, client_or_url, **kw) -> RemotePlaneAdapter:
    """Register one remote plane (its dominant modality profile) into a
    parent orchestrator; returns the (attached) adapter.

    Refuses with ``FEDERATION_CYCLE`` when the parent is already reachable
    THROUGH the child — a plane transitively re-registering itself would
    forward tasks in a loop."""
    adapter = RemotePlaneAdapter(client_or_url, **kw)
    parent_orchestrator.topology.add_child(adapter.child_plane_id,
                                           adapter.child_reachable)
    parent_orchestrator.register(adapter)
    return adapter.attach(parent_orchestrator)


def federate_all(parent_orchestrator, client_or_url,
                 plane: Optional[str] = None) -> List[RemotePlaneAdapter]:
    """Register EVERY modality profile of a remote plane, one adapter per
    (input, output) modality pair — the full fleet federates.  One health
    check + one discovery + one topology fetch serve all profiles, and all
    profile adapters share ONE streaming-follower subscription to the
    child plane (each filters fan-out events by its own modality)."""
    client = (client_or_url if isinstance(client_or_url, ControlPlaneClient)
              else ControlPlaneClient(client_or_url))
    plane = plane or client.health().get("plane", "remote")
    fleet = client.discover()
    if not fleet:
        raise ControlPlaneError(ErrorCode.NO_MATCH,
                                "remote plane exposes no resources")
    topology = client.topology()
    parent_orchestrator.topology.add_child(
        topology["plane_id"],
        topology.get("reachable") or (topology["plane_id"],))
    profiles = sorted({(d.capability.input_signal.modality,
                        d.capability.output_signal.modality) for d in fleet})
    adapters = []
    for pair in profiles:
        adapter = RemotePlaneAdapter(
            client, plane=plane, modality=pair, fleet=fleet,
            topology=topology,
            resource_id=f"plane-{plane}-{pair[0]}-{pair[1]}")
        parent_orchestrator.register(adapter)
        adapters.append(adapter.attach(parent_orchestrator))
    return adapters
