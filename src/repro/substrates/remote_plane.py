"""Federated control planes: a whole remote plane as ONE substrate.

:class:`RemotePlaneAdapter` closes the paper's edge→fog→cloud loop: an
entire edge gateway (with however many physical substrates behind it)
registers into a parent — typically cloud — orchestrator as a single
:class:`~repro.substrates.base.SubstrateAdapter`.  Because it is just an
adapter, EVERYTHING the parent control plane knows composes transparently
across the boundary:

- **matching** — the adapter's descriptor aggregates the edge plane's
  resources (union of functions, summed concurrency, fastest timing) for
  one modality profile, so Eq. 1 ranks the remote plane against local
  hardware like any other candidate;
- **circuit breakers** — a dead or flapping edge gateway fails invocations,
  which feed the parent's HealthManager exactly like substrate faults: the
  plane is quarantined, probed, and re-admitted as one unit;
- **twin fallback** — ``make_twin()`` attaches a record/replay surrogate
  that learns from every result crossing back over the wire, so when the
  edge plane is quarantined, opted-in traffic is served from the parent's
  twin of the *plane* (mirroring remote health through result telemetry:
  drift scores in forwarded telemetry drive the shared confidence law).

Tracing stays complete across the hop: the edge plane's own
``OrchestrationTrace`` (which resource it picked, its control overhead) is
carried back verbatim in the invocation artifacts as ``remote_trace``, and
the forwarded task KEEPS its task id — one task, one identity, two planes.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

from repro.core.descriptors import (CapabilityDescriptor, LifecycleSemantics,
                                    Observability, PolicyConstraints,
                                    ResourceDescriptor, SignalSpec,
                                    TimingSemantics)
from repro.core.errors import ControlPlaneError, ErrorCode
from repro.core.telemetry import RuntimeSnapshot
from repro.core.twin import RecordReplaySurrogate, TwinState
from repro.gateway.client import ControlPlaneClient
from repro.substrates.base import SubstrateAdapter

#: wire round-trip margin added to the advertised expected latency so the
#: parent matcher's T term accounts for the extra hop
TRANSPORT_MARGIN_MS = 5.0

_REGIME_ORDER = {"sub_ms": 0, "fast_ms": 1, "slow_seconds": 2}


class RemotePlaneAdapter(SubstrateAdapter):
    """One remote control plane, adapted into a parent plane's fleet.

    ``modality`` selects which (input, output) modality profile of the
    remote fleet this adapter advertises (a plane with both vector and
    concentration resources federates as one adapter per profile; see
    :func:`federate_all`).  Default: the profile with the most remote
    resources behind it.
    """

    #: remote execution bound for tasks carrying no latency budget: the
    #: deadline is FORWARDED so the remote scheduler abandons queued work
    #: past it, keeping both planes' view of "this task is over" aligned
    #: (an unbounded forward would time out client-side while the edge
    #: keeps executing — and the parent's fallback would double-execute)
    DEFAULT_INVOKE_DEADLINE_S = 120.0

    def __init__(self, client_or_url, resource_id: Optional[str] = None,
                 plane: Optional[str] = None,
                 modality: Optional[Tuple[str, str]] = None,
                 fleet: Optional[List[ResourceDescriptor]] = None,
                 invoke_deadline_s: float = DEFAULT_INVOKE_DEADLINE_S):
        super().__init__()
        self.invoke_deadline_s = invoke_deadline_s
        self.client = (client_or_url
                       if isinstance(client_or_url, ControlPlaneClient)
                       else ControlPlaneClient(client_or_url))
        if plane is None or fleet is None:
            # fail fast: the plane must be up at federation time; callers
            # federating several profiles of one plane pass the already-
            # fetched fleet + plane name to skip repeat round-trips
            health = self.client.health()
            plane = plane or health.get("plane", "remote")
            fleet = fleet if fleet is not None else self.client.discover()
        self.plane = plane
        self.resource_id = resource_id or f"plane-{self.plane}"
        self._remote_descs = list(fleet)
        if not self._remote_descs:
            raise ControlPlaneError(ErrorCode.NO_MATCH,
                                    "remote plane exposes no resources")
        self.modality = modality or self._dominant_modality()
        if not self._profile():
            raise ControlPlaneError(
                ErrorCode.NO_MATCH,
                f"remote plane {self.plane!r} has no "
                f"{self.modality[0]}->{self.modality[1]} resources")
        self.last_transport_ms = 0.0
        self.last_remote_resource: Optional[str] = None

    # -- descriptor aggregation ----------------------------------------------
    def _profile(self) -> List[ResourceDescriptor]:
        return [d for d in self._remote_descs
                if (d.capability.input_signal.modality,
                    d.capability.output_signal.modality) == self.modality]

    def _dominant_modality(self) -> Tuple[str, str]:
        """Most-populated (input, output) modality pair, ties broken
        lexicographically so the default profile is deterministic whatever
        order the remote plane registered its fleet.  Planes with several
        profiles usually want ``federate_all`` (every profile) or an
        explicit ``modality=`` instead of this default."""
        counts: Dict[Tuple[str, str], int] = {}
        for d in self._remote_descs:
            key = (d.capability.input_signal.modality,
                   d.capability.output_signal.modality)
            counts[key] = counts.get(key, 0) + 1
        return min(counts, key=lambda k: (-counts[k], k))

    def descriptor(self) -> ResourceDescriptor:
        """Aggregate the remote profile into one capability: the plane can
        do the UNION of what its members do, absorb the SUM of their
        concurrency, and answer as fast as its FASTEST member (plus a wire
        margin) — the remote matcher handles per-member placement."""
        members = self._profile()
        caps = [d.capability for d in members]
        functions = tuple(sorted({f for c in caps for f in c.functions}))
        telemetry = tuple(sorted({f for c in caps
                                  for f in c.observability.telemetry_fields}))
        drift = tuple(sorted({f for c in caps
                              for f in c.observability.drift_indicators}))
        fastest = min(caps, key=lambda c: c.timing.expected_latency_ms)
        regime = min((c.timing.latency_regime for c in caps),
                     key=lambda r: _REGIME_ORDER.get(r, 1))
        lo = min(c.input_signal.admissible_range[0] for c in caps)
        hi = max(c.input_signal.admissible_range[1] for c in caps)
        out_lo = min(c.output_signal.admissible_range[0] for c in caps)
        out_hi = max(c.output_signal.admissible_range[1] for c in caps)
        cap = CapabilityDescriptor(
            functions=functions,
            input_signal=SignalSpec(self.modality[0],
                                    fastest.input_signal.encoding, (lo, hi)),
            output_signal=SignalSpec(self.modality[1],
                                     fastest.output_signal.encoding,
                                     (out_lo, out_hi)),
            timing=TimingSemantics(
                regime,
                fastest.timing.expected_latency_ms + TRANSPORT_MARGIN_MS,
                observation_window_ms=max(c.timing.observation_window_ms
                                          for c in caps),
                freshness_ms=min(c.timing.freshness_ms for c in caps)),
            # lifecycle belongs to the remote plane's members; crossing the
            # boundary the only affordance is reconnecting to the gateway
            lifecycle=LifecycleSemantics(warmup_ms=0.0, resetable=True,
                                         reset_modes=("reconnect",),
                                         recovery_modes=("reconnect",)),
            programmability="configurable",
            observability=Observability(
                output_channels=("remote",),
                telemetry_fields=telemetry + ("transport_ms",
                                              "remote_resource_id"),
                drift_indicators=drift,
                twin_linked_fields=drift),
            # per-member policy (supervision, tenancy, safety) is enforced
            # by the remote plane itself on every forwarded task
            policy=PolicyConstraints(
                exclusive=False,
                max_concurrent=sum(max(1, c.policy.max_concurrent)
                                   for c in caps)),
            supports_repeated_invocation=any(c.supports_repeated_invocation
                                             for c in caps),
            energy_proxy_mj=fastest.energy_proxy_mj,
        )
        location = members[0].location if members else "edge"
        return ResourceDescriptor(
            resource_id=self.resource_id,
            substrate_class="federated_plane",
            adapter_type="http", location=location,
            twin_binding=f"twin-{self.resource_id}", capability=cap,
            description=f"federated control plane '{self.plane}' "
                        f"({len(members)} member substrates, "
                        f"modality {self.modality[0]}->{self.modality[1]})")

    # -- data-plane surface ---------------------------------------------------
    def prepare(self, session) -> None:
        # no liveness round-trip here: invoke() on a dead plane fails fast
        # with the same GatewayError one line later, and a per-session
        # health check would double the wire RTTs on the federated hot path
        self._check_prepare_fault()

    def invoke(self, session) -> Dict:
        # strip placement directives that only meant something on THIS
        # plane: the remote matcher owns placement among its members, and
        # twin decisions stay with the parent (a silently twin-served
        # federated result would corrupt the parent's provenance accounting)
        task = session.task.clone(backend_preference=None, twin_mode=None)
        t0 = time.perf_counter()
        result, remote_trace = self.client.invoke(
            task, deadline_s=(task.latency_budget_ms / 1e3
                              if task.latency_budget_ms
                              else self.invoke_deadline_s))
        rtt_ms = (time.perf_counter() - t0) * 1e3
        backend_ms = float(result.timing_ms.get("backend_ms", 0.0))
        self.last_transport_ms = max(
            0.0, rtt_ms - result.timing_ms.get("total_ms", backend_ms))
        self.last_remote_resource = result.resource_id
        telemetry = dict(result.telemetry)
        telemetry.update({
            "remote_resource_id": result.resource_id,
            "remote_plane": self.plane,
            "remote_control_overhead_ms": round(
                remote_trace.control_overhead_ms, 4),
            "transport_ms": round(self.last_transport_ms, 4),
            "observation_ms": telemetry.get("observation_ms", rtt_ms),
        })
        telemetry = self._apply_telemetry_faults(telemetry)
        artifacts = dict(result.artifacts)
        # the complete cross-boundary trace: the remote plane's own
        # placement record rides home with the result
        artifacts["remote_trace"] = remote_trace.to_wire()
        artifacts["remote_session_id"] = result.session_id
        return {
            "output": result.output,
            "telemetry": telemetry,
            "artifacts": artifacts,
            "backend_ms": backend_ms,
            "rtt_ms": rtt_ms,
            "needs_reset": False,
        }

    def reset(self, mode: str = "reconnect") -> None:
        """Re-arm after a breaker reopen.  Nothing to do on this side: the
        client reconnects lazily on the next request, and the parent's
        aggregate descriptor is fixed at federation time — tracking remote
        fleet changes live is the ROADMAP "descriptor change feed" item,
        and a refresh here would be invisible to the parent registry
        anyway (it never re-reads ``descriptor()``)."""

    def snapshot(self) -> Optional[RuntimeSnapshot]:
        """Aggregate remote health: worst member status, max drift, summed
        queue depth; an unreachable plane reports failed/down (which the
        parent matcher treats as inadmissible even before the breaker
        trips)."""
        try:
            health = self.client.health()
        except Exception:                                  # noqa: BLE001
            return RuntimeSnapshot(self.resource_id, health_status="failed",
                                   readiness="down", drift_score=1.0)
        worst, drift, depth = "healthy", 0.0, 0
        rank = {"healthy": 0, "degraded": 1, "failed": 2}
        for snap in (health.get("resources") or {}).values():
            if not snap:
                continue
            if rank.get(snap.get("health_status"), 0) > rank[worst]:
                worst = snap["health_status"]
            drift = max(drift, float(snap.get("drift_score", 0.0)))
            depth += int(snap.get("queue_depth", 0))
        return RuntimeSnapshot(self.resource_id, health_status=worst,
                               drift_score=round(drift, 4),
                               queue_depth=depth,
                               extra={"plane": self.plane})

    def make_twin(self) -> Optional[TwinState]:
        """Record/replay twin OF THE PLANE: learns from every forwarded
        result, mirrors remote health through the forwarded drift scores
        (the shared confidence law consumes them from result telemetry),
        and serves opted-in traffic when the plane is quarantined."""
        return TwinState(f"twin-{self.resource_id}", self.resource_id,
                         kind="record",
                         model={"plane": self.plane,
                                "members": len(self._remote_descs)},
                         surrogate=RecordReplaySurrogate(capacity=64))


def federate(parent_orchestrator, client_or_url, **kw) -> RemotePlaneAdapter:
    """Register one remote plane (its dominant modality profile) into a
    parent orchestrator; returns the adapter."""
    adapter = RemotePlaneAdapter(client_or_url, **kw)
    parent_orchestrator.register(adapter)
    return adapter


def federate_all(parent_orchestrator, client_or_url,
                 plane: Optional[str] = None) -> List[RemotePlaneAdapter]:
    """Register EVERY modality profile of a remote plane, one adapter per
    (input, output) modality pair — the full fleet federates.  One health
    check + one discovery serve all profiles."""
    client = (client_or_url if isinstance(client_or_url, ControlPlaneClient)
              else ControlPlaneClient(client_or_url))
    plane = plane or client.health().get("plane", "remote")
    fleet = client.discover()
    if not fleet:
        raise ControlPlaneError(ErrorCode.NO_MATCH,
                                "remote plane exposes no resources")
    profiles = sorted({(d.capability.input_signal.modality,
                        d.capability.output_signal.modality) for d in fleet})
    adapters = []
    for pair in profiles:
        adapter = RemotePlaneAdapter(
            client, plane=plane, modality=pair, fleet=fleet,
            resource_id=f"plane-{plane}-{pair[0]}-{pair[1]}")
        parent_orchestrator.register(adapter)
        adapters.append(adapter)
    return adapters
