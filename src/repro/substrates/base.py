"""Substrate adapter interface (data plane, paper §IV-A).

The data plane is deliberately NOT uniform across substrates — a chemical
backend consumes concentrations, a wetware backend stimulation patterns —
but every adapter exposes the same software surface so the control plane can
drive it: ``descriptor()``, ``prepare()``, ``invoke()``, ``reset()``,
``snapshot()``, ``make_twin()``.

``invoke`` returns a RAW dict (output / telemetry / artifacts / backend_ms /
needs_reset); normalization into the stable client-visible result shape is
the invocation manager's job, keeping adapters substrate-idiomatic.

``make_twin`` returns the adapter's digital-twin binding.  Since PR 3 the
twin should be EXECUTABLE: attach a
:class:`~repro.core.twin.TwinSurrogate` whose ``simulate(task)`` returns
the same raw dict shape as ``invoke`` — the control plane uses it for
shadow comparison, twin-served fallback and speculation (see
``repro.core.twin_executor``).  A metadata-only twin (``surrogate=None``)
remains legal; it simply opts the resource out of twin serving.
"""
from __future__ import annotations

import abc
import time
from typing import Dict, Optional

from repro.core.descriptors import ResourceDescriptor
from repro.core.telemetry import RuntimeSnapshot
from repro.core.twin import TwinState


class SubstrateAdapter(abc.ABC):
    """Base class for all data-plane adapters."""

    def __init__(self):
        self._faults: set = set()

    # -- control-plane surface ------------------------------------------------
    @abc.abstractmethod
    def descriptor(self) -> ResourceDescriptor:
        ...

    @abc.abstractmethod
    def prepare(self, session) -> None:
        """Warm-up / priming / calibration for a session."""

    @abc.abstractmethod
    def invoke(self, session) -> Dict:
        """Execute; returns raw dict with keys output/telemetry/artifacts/
        backend_ms/needs_reset."""

    def reset(self, mode: str = "soft") -> None:
        pass

    def snapshot(self) -> Optional[RuntimeSnapshot]:
        return RuntimeSnapshot(self.descriptor().resource_id)

    def make_twin(self) -> Optional[TwinState]:
        """Digital-twin binding for this substrate (None = no twin).
        Adapters should attach an executable surrogate
        (``TwinState.surrogate``) so the twin plane can shadow, serve
        fallback and speculate — see the module docstring."""
        return None

    # -- fault injection (Table IV campaign) ----------------------------------
    def inject_fault(self, fault: str) -> None:
        self._faults.add(fault)

    def clear_faults(self) -> None:
        self._faults.clear()

    def _check_prepare_fault(self) -> None:
        if "prepare_failure" in self._faults:
            raise RuntimeError(
                f"{type(self).__name__}: injected preparation failure")

    def _apply_telemetry_faults(self, telemetry: Dict) -> Dict:
        if "drop_telemetry" in self._faults:
            # drop a drift indicator the contract may require
            telemetry = {k: v for k, v in telemetry.items()
                         if k not in ("drift_score",)}
        return telemetry


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e3
