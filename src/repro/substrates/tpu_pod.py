"""TPU pod-slice substrate: the control plane's beyond-paper binding.

A registered resource is a (architecture × mesh geometry × sharding recipe ×
precision) tuple.  Its capability descriptor carries the roofline terms
derived from the AOT-compiled dry-run artifact (``benchmarks/results/dryrun``)
— i.e. the *digital twin is the compiled cost model* (DESIGN.md §2), the
high-fidelity end of the paper's twin spectrum:

- twin confidence     — decays when measured step telemetry diverges from
                        the roofline prediction (drift),
- lifecycle           — COMPILING = warm-up, checkpoint-restore = reset,
- timing contract     — roofline step-time lower bound × slack,
- telemetry contract  — loss / grad-norm / tokens-per-second / step-time.

``invoke`` executes real jitted train steps of a *reduced* same-family
config on the local device mesh (this container is CPU-only; the full
configs exist via the dry-run path).  Step-time regression beyond the
straggler threshold marks the substrate DEGRADED, which the matcher sees —
the paper's drift-aware placement, applied to a TPU fleet.
"""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Dict, Optional

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.core.descriptors import (CapabilityDescriptor, LifecycleSemantics,
                                    Observability, PolicyConstraints,
                                    ResourceDescriptor, SignalSpec,
                                    TimingSemantics)
from repro.core.telemetry import RuntimeSnapshot
from repro.core.twin import TwinNotReady, TwinState, TwinSurrogate
from repro.substrates.base import SubstrateAdapter
from repro.training.checkpoint import CheckpointManager
from repro.training.data import SyntheticTokenDataset
from repro.training.train_step import build_train_step, init_train_state

DRYRUN_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"

STRAGGLER_FACTOR = 2.0       # step slower than 2x median => degraded


def load_dryrun_record(arch: str, shape: str = "train_4k",
                       mesh: str = "pod256", recipe: str = "baseline"
                       ) -> Optional[Dict]:
    p = DRYRUN_DIR / f"{arch}__{shape}__{mesh}__{recipe}.json"
    if not p.exists():
        return None
    rec = json.loads(p.read_text())
    return rec if rec.get("status") == "ok" else None


class RooflineSurrogate(TwinSurrogate):
    """Executable roofline twin: the compiled cost model (dry-run artifact)
    plus last-observed training metrics.  Step time is predicted from the
    median of observed steps (falling back to the roofline lower bound), so
    the twin tightens as real telemetry arrives — the high-fidelity end of
    the paper's twin spectrum, now answering instead of only scoring."""

    kind = "roofline"
    tolerance = 0.5

    def __init__(self, roofline: Optional[Dict], *, steps_per_invoke: int,
                 batch: int, seq: int):
        self.roofline = dict(roofline or {})
        self.steps_per_invoke = steps_per_invoke
        self.batch, self.seq = batch, seq
        self._step_ms: list = []
        self._last: Dict = {}

    def observe(self, task, raw: Dict) -> None:
        tele = raw.get("telemetry") or {}
        out = raw.get("output") or {}
        if "step_ms" in tele:
            self._step_ms.append(float(tele["step_ms"]))
            del self._step_ms[:-32]
        self._last = {"step": out.get("step"), "loss": out.get("loss"),
                      "grad_norm": tele.get("grad_norm")}

    def simulate(self, task) -> Dict:
        payload = task.payload if isinstance(task.payload, dict) else {}
        n_steps = int(payload.get("steps", self.steps_per_invoke))
        if self._step_ms:
            step_ms = float(np.median(self._step_ms))
        elif self.roofline.get("step_time_lb_s"):
            step_ms = float(self.roofline["step_time_lb_s"]) * 1e3
        else:
            raise TwinNotReady("roofline twin has neither a dry-run record "
                               "nor observed step telemetry")
        last_step = int(self._last.get("step") or 0)
        loss = self._last.get("loss")
        loss = float(loss) if loss is not None else float("nan")
        grad_norm = self._last.get("grad_norm")
        grad_norm = float(grad_norm) if grad_norm is not None \
            else float("nan")
        tokens_per_s = self.batch * self.seq / max(step_ms / 1e3, 1e-9)
        return {
            "output": {"step": last_step + n_steps, "loss": loss},
            "telemetry": {
                "loss": loss,
                "grad_norm": grad_norm,
                "tokens_per_s": round(tokens_per_s, 1),
                "step_ms": round(step_ms, 3),
                "drift_score": 0.0,
                "health_status": "healthy",
                "observation_ms": step_ms * n_steps,
            },
            "artifacts": {"roofline_twin": dict(self.roofline) or None},
            "backend_ms": 0.0,
        }

    def divergence(self, real_output, twin_output) -> float:
        r = real_output if isinstance(real_output, dict) else {}
        t = twin_output if isinstance(twin_output, dict) else {}
        s_real, s_twin = r.get("step"), t.get("step")
        if s_real is None or s_twin is None:
            step_err = 1.0
        else:
            step_err = min(1.0, abs(int(s_real) - int(s_twin))
                           / max(abs(int(s_real)), 1))
        l_real, l_twin = r.get("loss"), t.get("loss")
        try:
            l_real, l_twin = float(l_real), float(l_twin)
            if np.isnan(l_real) and np.isnan(l_twin):
                loss_err = 0.0
            elif np.isnan(l_real) or np.isnan(l_twin):
                loss_err = 1.0
            else:
                loss_err = min(1.0, abs(l_real - l_twin)
                               / max(abs(l_real), abs(l_twin), 1e-6))
        except (TypeError, ValueError):
            loss_err = 1.0
        return float(0.5 * step_err + 0.5 * loss_err)


class TpuPodSubstrate(SubstrateAdapter):
    def __init__(self, arch: str, *, shape: str = "train_4k",
                 mesh_tag: str = "pod256", recipe: str = "baseline",
                 steps_per_invoke: int = 3, batch: int = 4, seq: int = 64,
                 ckpt_dir: Optional[str] = None, seed: int = 0):
        super().__init__()
        self.arch = arch
        self.shape = shape
        self.mesh_tag = mesh_tag
        self.recipe = recipe
        self.resource_id = f"tpu-{arch}-{mesh_tag}-{recipe}"
        self.record = load_dryrun_record(arch, shape, mesh_tag, recipe)
        self.steps_per_invoke = steps_per_invoke
        self.cfg = reduced(get_config(arch))
        self.batch, self.seq = batch, seq
        self._state = None
        self._step_fn = None
        self._data = SyntheticTokenDataset(self.cfg.vocab_size, seq, batch,
                                           seed=seed)
        self._step = 0
        self._step_times: list = []
        self._compiled = False
        self._ckpt = (CheckpointManager(ckpt_dir, keep=2)
                      if ckpt_dir is not None else None)
        self._injected_slowdown = 0.0

    # -- descriptor -----------------------------------------------------------
    def descriptor(self) -> ResourceDescriptor:
        rec = self.record or {}
        roof = rec.get("roofline", {})
        step_lb_ms = roof.get("step_time_lb_s", 0.1) * 1e3
        mem = rec.get("memory", {})
        cap = CapabilityDescriptor(
            functions=("train", "train_step"),
            input_signal=SignalSpec("tensor_shards", "int32_tokens",
                                    (0.0, float(self.cfg.vocab_size))),
            output_signal=SignalSpec("tensor_shards", "metrics", (0.0, 1e9)),
            timing=TimingSemantics(
                "fast_ms", expected_latency_ms=max(step_lb_ms, 1.0),
                observation_window_ms=step_lb_ms * self.steps_per_invoke,
                freshness_ms=600_000.0),
            lifecycle=LifecycleSemantics(
                warmup_ms=float(rec.get("compile_seconds", 10.0)) * 1e3,
                resetable=True,
                reset_modes=("restore_checkpoint", "rescale"),
                reset_cost_ms=2_000.0,
                recovery_modes=("restore_checkpoint",)),
            programmability="configurable",
            observability=Observability(
                output_channels=("metrics",),
                telemetry_fields=("loss", "grad_norm", "tokens_per_s",
                                  "step_ms", "drift_score"),
                drift_indicators=("drift_score", "step_ms"),
                twin_linked_fields=("step_ms", "drift_score")),
            policy=PolicyConstraints(exclusive=True, max_concurrent=1),
            supports_repeated_invocation=True,
        )
        return ResourceDescriptor(
            resource_id=self.resource_id, substrate_class="tpu_pod",
            adapter_type="in_process", location="cloud",
            twin_binding=f"twin-{self.resource_id}", capability=cap,
            description=f"{self.arch} on {rec.get('mesh', self.mesh_tag)} "
                        f"mesh, recipe={self.recipe} "
                        f"(fits={mem.get('fits', 'n/a')})")

    # -- data plane -------------------------------------------------------------
    def prepare(self, session) -> None:
        self._check_prepare_fault()
        if not self._compiled:
            t0 = time.perf_counter()
            self._state = init_train_state(self.cfg)
            self._step_fn = jax.jit(build_train_step(self.cfg),
                                    donate_argnums=0)
            # warm-up = compilation (lifecycle cost, visible in telemetry)
            batch = {k: jax.numpy.asarray(v)
                     for k, v in self._data.batch_at(0).items()}
            self._state, _ = self._step_fn(self._state, batch)
            self._compile_ms = (time.perf_counter() - t0) * 1e3
            self._compiled = True

    def invoke(self, session) -> Dict:
        payload = session.task.payload or {}
        # elastic/shared-job mode: if the shared checkpoint directory has a
        # newer step than this slice (another slice advanced the job, or
        # this slice just joined), resume from it before training
        if payload.get("resume") and self._ckpt is not None:
            latest = self._ckpt.latest_step()
            if latest is not None and latest > self._step \
                    and self._state is not None:
                self._state, _ = self._ckpt.restore(self._state, latest)
                self._step = latest
        n_steps = int(payload.get("steps", self.steps_per_invoke))
        t0 = time.perf_counter()
        metrics = {}
        for _ in range(n_steps):
            batch = {k: jax.numpy.asarray(v)
                     for k, v in self._data.batch_at(self._step).items()}
            ts = time.perf_counter()
            self._state, metrics = self._step_fn(self._state, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            if self._injected_slowdown:
                time.sleep(self._injected_slowdown)  # planelint: allow(clock-seam) — fault injection: real stall on the jax path
            self._step_times.append((time.perf_counter() - ts) * 1e3)
            self._step += 1
        backend_ms = (time.perf_counter() - t0) * 1e3
        step_ms = float(np.mean(self._step_times[-n_steps:]))
        med = float(np.median(self._step_times)) if self._step_times else step_ms
        drift = max(0.0, min(1.0, step_ms / max(med, 1e-9) / STRAGGLER_FACTOR
                             - 0.5))
        tokens_per_s = self.batch * self.seq / max(step_ms / 1e3, 1e-9)
        if self._ckpt is not None and payload.get("checkpoint", True):
            self._ckpt.save(self._step, self._state,
                            {"loss": metrics.get("loss", float("nan"))})
        telemetry = self._apply_telemetry_faults({
            "loss": metrics.get("loss", float("nan")),
            "grad_norm": metrics.get("grad_norm", float("nan")),
            "tokens_per_s": round(tokens_per_s, 1),
            "step_ms": round(step_ms, 3),
            "drift_score": round(drift, 4),
            "health_status": "degraded" if drift > 0.5 else "healthy",
            "observation_ms": backend_ms,
        })
        return {
            "output": {"step": self._step,
                       "loss": metrics.get("loss", float("nan"))},
            "telemetry": telemetry,
            "artifacts": {"roofline_twin": (self.record or {}).get("roofline"),
                          "checkpoint_step": (self._ckpt.latest_step()
                                              if self._ckpt else None)},
            "backend_ms": backend_ms,
            "needs_reset": False,
        }

    def reset(self, mode: str = "restore_checkpoint") -> None:
        if mode == "restore_checkpoint" and self._ckpt is not None \
                and self._state is not None:
            step = self._ckpt.latest_step()
            if step is not None:
                self._state, _ = self._ckpt.restore(self._state, step)
                self._step = step
        self._injected_slowdown = 0.0
        self._step_times.clear()

    # fault hooks used by the fleet tests ------------------------------------
    def inject_straggler(self, seconds: float) -> None:
        self._injected_slowdown = seconds

    def snapshot(self) -> Optional[RuntimeSnapshot]:
        if not self._step_times:
            return RuntimeSnapshot(self.resource_id)
        med = float(np.median(self._step_times))
        last = self._step_times[-1]
        drift = max(0.0, min(1.0, last / max(med, 1e-9) / STRAGGLER_FACTOR - 0.5))
        return RuntimeSnapshot(
            self.resource_id,
            health_status="degraded" if drift > 0.5 else "healthy",
            drift_score=round(drift, 4))

    def make_twin(self) -> Optional[TwinState]:
        roof = (self.record or {}).get("roofline", {})
        return TwinState(f"twin-{self.resource_id}", self.resource_id,
                         kind="roofline", model=dict(roof),
                         surrogate=RooflineSurrogate(
                             roof, steps_per_invoke=self.steps_per_invoke,
                             batch=self.batch, seq=self.seq))
