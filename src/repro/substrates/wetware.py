"""Biological/wetware backend: synthetic spike-response twin (paper §VI-B).

A leaky-integrate-and-fire population responds to a stimulation pattern;
usefulness depends on *health and observability*, not equilibration: the
adapter exposes ms-scale timing, viability-sensitive state and rest/
recalibrate recovery — the state-sensitive contrast case to the chemical
backend.  Requires human supervision by policy (R7), which the fault
campaign's reject scenario exercises.
"""
from __future__ import annotations

import time
from typing import Dict, Optional

import numpy as np

from repro.core.descriptors import (CapabilityDescriptor, LifecycleSemantics,
                                    Observability, PolicyConstraints,
                                    ResourceDescriptor, SignalSpec,
                                    TimingSemantics)
from repro.core.telemetry import RuntimeSnapshot
from repro.core.twin import TwinState, TwinSurrogate
from repro.substrates.base import SubstrateAdapter

RESOURCE_ID = "wetware-synthetic"


class SpikeResponseTwin:
    """LIF population: stimulation pattern -> spike counts / response delay."""

    def __init__(self, n_neurons: int = 64, seed: int = 11):
        rng = np.random.default_rng(seed)
        self.n = n_neurons
        self.w_in = rng.normal(0.8, 0.2, (n_neurons,))
        self.w_rec = rng.normal(0.0, 0.35 / np.sqrt(n_neurons),
                                (n_neurons, n_neurons))
        self.tau = 12.0          # ms
        self.v_th = 1.0

    def run(self, pattern, amplitude: float, noise: float, steps: int = 120,
            dt: float = 1.0, seed: int = 0):
        rng = np.random.default_rng(seed)
        pattern = np.asarray(pattern, np.float64)
        v = np.zeros(self.n)
        spikes = np.zeros((steps, self.n), bool)
        stim = np.zeros(steps)
        stim[:len(pattern)] = pattern * amplitude
        first_spike = None
        for t in range(steps):
            inp = self.w_in * stim[t] + self.w_rec @ spikes[t - 1].astype(float)
            v = v + dt / self.tau * (-v) + inp * dt / self.tau
            v = v + noise * rng.normal(size=self.n) * 0.05
            fired = v >= self.v_th
            spikes[t] = fired
            v = np.where(fired, 0.0, v)
            if first_spike is None and fired.any():
                first_spike = t * dt
        rate = spikes.mean() * 1e3 / dt      # Hz per neuron
        fingerprint = spikes.sum(0)          # per-neuron counts
        return fingerprint, rate, (first_spike if first_spike is not None
                                   else float(steps) * dt)


class WetwareBehavioralSurrogate(TwinSurrogate):
    """Behavioral twin of the LIF population: same synaptic weights (same
    construction seed), nominal noise tracked from observed telemetry.

    Spike trains from different noise realizations never match
    elementwise, so divergence compares the behavioral summary — response
    presence and total spike-count mass — not raw fingerprints; the
    declared tolerance reflects trial-to-trial biological variability.
    """

    kind = "behavioral"
    tolerance = 0.5

    def __init__(self, n_neurons: int = 64, seed: int = 11):
        self.model = SpikeResponseTwin(n_neurons=n_neurons, seed=seed)
        self._noise = 0.2
        self._viability = 1.0
        self._runs = 0

    def observe(self, task, raw: Dict) -> None:
        tele = raw.get("telemetry") or {}
        if "noise_level" in tele:
            self._noise = float(tele["noise_level"])
        if "viability" in tele:
            self._viability = float(tele["viability"])

    def simulate(self, task) -> Dict:
        payload = task.payload if isinstance(task.payload, dict) else {}
        pattern = payload.get("pattern", [1, 0, 1, 1])
        amplitude = float(payload.get("amplitude", 1.0))
        self._runs += 1
        t0 = time.perf_counter()
        fp, rate, delay = self.model.run(pattern, amplitude, self._noise,
                                         seed=self._runs)
        backend_ms = (time.perf_counter() - t0) * 1e3
        drift = max(0.0, round(1.0 - self._viability + 0.2 * self._noise, 4))
        return {
            "output": {"fingerprint": fp.tolist(),
                       "responded": bool(rate > 1.0)},
            "telemetry": {
                "firing_rate_hz": round(float(rate), 3),
                "response_delay_ms": round(float(delay), 3),
                "noise_level": round(self._noise, 4),
                "viability": round(self._viability, 4),
                "drift_score": drift,
                "health_status": ("healthy" if self._viability > 0.5
                                  else "degraded"),
                "observation_ms": 120.0,
            },
            "artifacts": {"recording": {"channels": self.model.n,
                                        "duration_ms": 120}},
            "backend_ms": backend_ms,
        }

    def divergence(self, real_output, twin_output) -> float:
        r = real_output if isinstance(real_output, dict) else {}
        t = twin_output if isinstance(twin_output, dict) else {}
        resp = 0.0 if bool(r.get("responded")) == bool(t.get("responded")) \
            else 1.0
        f_real = np.asarray(r.get("fingerprint", []), np.float64)
        f_twin = np.asarray(t.get("fingerprint", []), np.float64)
        if f_real.size and f_real.shape == f_twin.shape:
            s_real, s_twin = float(f_real.sum()), float(f_twin.sum())
            mass = abs(s_real - s_twin) / max(s_real, s_twin, 1.0)
        else:
            mass = 1.0
        return float(min(1.0, 0.5 * resp + 0.5 * mass))


class WetwareAdapter(SubstrateAdapter):
    def __init__(self, resource_id: str = RESOURCE_ID):
        super().__init__()
        self.resource_id = resource_id
        self.twin = SpikeResponseTwin()
        self.viability = 1.0
        self.noise = 0.2
        self.sessions_since_rest = 0

    def descriptor(self) -> ResourceDescriptor:
        cap = CapabilityDescriptor(
            functions=("screening", "stimulus_response"),
            input_signal=SignalSpec("spikes", "binary_pattern", (0.0, 1.0),
                                    sampling_hz=1000.0,
                                    transduction="MEA stimulation"),
            output_signal=SignalSpec("spikes", "spike_counts", (0.0, 500.0),
                                     transduction="MEA recording"),
            timing=TimingSemantics("fast_ms", 40.0, observation_window_ms=120.0,
                                   min_stabilization_ms=5.0,
                                   freshness_ms=30_000.0),
            lifecycle=LifecycleSemantics(
                warmup_ms=50.0, resetable=True, reset_modes=("rest",),
                reset_cost_ms=500.0, calibration_interval_s=120.0,
                recovery_modes=("rest", "recalibrate"), cooldown_ms=50.0),
            programmability="in_situ_adaptive",
            observability=Observability(
                output_channels=("spike_counts", "firing_rate"),
                telemetry_fields=("firing_rate_hz", "response_delay_ms",
                                  "noise_level", "viability", "drift_score"),
                drift_indicators=("noise_level", "drift_score"),
                twin_linked_fields=("firing_rate_hz", "drift_score")),
            policy=PolicyConstraints(exclusive=True, requires_supervision=True,
                                     max_stimulation=2.0, biosafety_level=2),
            supports_repeated_invocation=True,
            energy_proxy_mj=0.02,
        )
        return ResourceDescriptor(
            resource_id=self.resource_id, substrate_class="wetware",
            adapter_type="in_process", location="lab",
            twin_binding=f"twin-{self.resource_id}", capability=cap,
            description="synthetic spike-response wetware twin "
                        "(health/viability-aware closed loop)")

    def prepare(self, session) -> None:
        self._check_prepare_fault()
        self.sessions_since_rest += 1

    def invoke(self, session) -> Dict:
        payload = session.task.payload or {}
        pattern = payload.get("pattern", [1, 0, 1, 1])
        amplitude = float(payload.get("amplitude", 1.0))
        t0 = time.perf_counter()
        fp, rate, delay = self.twin.run(pattern, amplitude, self.noise,
                                        seed=self.sessions_since_rest)
        backend_ms = (time.perf_counter() - t0) * 1e3
        # repeated stimulation degrades viability slightly
        self.viability = max(0.2, self.viability - 0.01)
        self.noise = min(1.0, self.noise + 0.01)
        drift = round(1.0 - self.viability + 0.2 * self.noise, 4)
        telemetry = self._apply_telemetry_faults({
            "firing_rate_hz": round(float(rate), 3),
            "response_delay_ms": round(float(delay), 3),
            "noise_level": round(self.noise, 4),
            "viability": round(self.viability, 4),
            "drift_score": max(0.0, drift),
            "health_status": "healthy" if self.viability > 0.5 else "degraded",
            "observation_ms": 120.0,
        })
        return {
            "output": {"fingerprint": fp.tolist(),
                       "responded": bool(rate > 1.0)},
            "telemetry": telemetry,
            "artifacts": {"recording": {"channels": self.twin.n,
                                        "duration_ms": 120}},
            "backend_ms": backend_ms,
            "needs_reset": self.sessions_since_rest >= 5,
        }

    def reset(self, mode: str = "rest") -> None:
        if mode == "rest":
            self.sessions_since_rest = 0
            self.viability = min(1.0, self.viability + 0.2)
        elif mode == "recalibrate":
            self.noise = 0.2

    def snapshot(self) -> Optional[RuntimeSnapshot]:
        return RuntimeSnapshot(
            self.resource_id,
            health_status="healthy" if self.viability > 0.5 else "degraded",
            drift_score=max(0.0, 1.0 - self.viability),
            viability=self.viability)

    def make_twin(self) -> Optional[TwinState]:
        return TwinState(f"twin-{self.resource_id}", self.resource_id,
                         kind="behavioral",
                         model={"n_neurons": self.twin.n, "tau": self.twin.tau},
                         surrogate=WetwareBehavioralSurrogate(
                             n_neurons=self.twin.n))
