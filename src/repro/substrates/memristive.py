"""Memristive/photonic backend: low-latency vector/tensor twin (paper §VI-C).

Device-like: a conductance-programmed crossbar MVM executed in JAX, with
calibration drift (conductance relaxation), reprogramming overhead and an
energy proxy.  This backend is the prototype's main vehicle for fallback /
drift-triggered recovery demonstrations — even accelerator-like substrates
benefit from an explicit control plane.
"""
from __future__ import annotations

import time
from typing import Dict, Optional

import numpy as np

from repro.core.descriptors import (CapabilityDescriptor, LifecycleSemantics,
                                    Observability, PolicyConstraints,
                                    ResourceDescriptor, SignalSpec,
                                    TimingSemantics)
from repro.core.telemetry import RuntimeSnapshot
from repro.core.twin import TwinState, TwinSurrogate
from repro.substrates.base import SubstrateAdapter

RESOURCE_ID = "memristive-local"


class CrossbarTwin:
    """4x4..NxN conductance crossbar with relaxation drift."""

    def __init__(self, n: int = 4, seed: int = 3):
        rng = np.random.default_rng(seed)
        self.g_target = rng.uniform(0.1, 1.0, (n, n))
        self.g = self.g_target.copy()
        self.relax = 0.015            # per-invocation conductance relaxation

    def mvm(self, x):
        y = self.g @ np.asarray(x, np.float64)
        # conductance relaxation toward mid-range = drift
        self.g = self.g + self.relax * (0.5 - self.g)
        return y

    def drift(self) -> float:
        return float(np.mean(np.abs(self.g - self.g_target))
                     / np.mean(self.g_target))

    def reprogram(self) -> None:
        self.g = self.g_target.copy()


class CrossbarMirrorSurrogate(TwinSurrogate):
    """Behavioral mirror of the programmed crossbar: the TARGET conductances
    with no relaxation.  Measured divergence vs the real device is therefore
    exactly the accumulated conductance drift — the canonical twin-fidelity
    signal."""

    kind = "behavioral"
    tolerance = 0.25

    def __init__(self, g_target):
        self.g = np.array(g_target, np.float64)

    def simulate(self, task) -> Dict:
        x = np.asarray(task.payload if task.payload is not None
                       else [0.5, 0.5, 0.5, 0.5], np.float64)
        x = x[: self.g.shape[1]]
        t0 = time.perf_counter()
        y = self.g @ x
        backend_ms = (time.perf_counter() - t0) * 1e3
        return {
            "output": {"vector": y.tolist()},
            "telemetry": {
                "execution_ms": round(backend_ms, 4),
                "drift_score": 0.0,
                "energy_proxy_mj": 0.0,
                "transport_ms": 0.0,
                "health_status": "healthy",
                "observation_ms": backend_ms,
            },
            "artifacts": {},
            "backend_ms": backend_ms,
        }


class MemristiveAdapter(SubstrateAdapter):
    def __init__(self, resource_id: str = RESOURCE_ID):
        super().__init__()
        self.resource_id = resource_id
        self.twin = CrossbarTwin()

    def descriptor(self) -> ResourceDescriptor:
        cap = CapabilityDescriptor(
            functions=("inference", "mvm"),
            input_signal=SignalSpec("vector", "float32", (-1.0, 1.0)),
            output_signal=SignalSpec("vector", "float32", (-10.0, 10.0)),
            timing=TimingSemantics("fast_ms", 2.0, observation_window_ms=5.0,
                                   freshness_ms=10_000.0),
            lifecycle=LifecycleSemantics(
                warmup_ms=1.0, resetable=True,
                reset_modes=("reprogram", "reset"), reset_cost_ms=20.0,
                calibration_interval_s=60.0,
                recovery_modes=("reprogram",), cooldown_ms=0.0),
            programmability="tunable",
            observability=Observability(
                output_channels=("vector_out",),
                telemetry_fields=("execution_ms", "drift_score",
                                  "energy_proxy_mj"),
                drift_indicators=("drift_score",),
                twin_linked_fields=("drift_score",)),
            policy=PolicyConstraints(exclusive=False, max_concurrent=4),
            supports_repeated_invocation=True,
            energy_proxy_mj=0.001,
        )
        return ResourceDescriptor(
            resource_id=self.resource_id, substrate_class="memristive",
            adapter_type="in_process", location="device/edge",
            twin_binding=f"twin-{self.resource_id}", capability=cap,
            description="conductance-crossbar MVM twin with relaxation drift")

    def prepare(self, session) -> None:
        self._check_prepare_fault()

    def invoke(self, session) -> Dict:
        x = np.asarray(session.task.payload if session.task.payload is not None
                       else [0.5, 0.5, 0.5, 0.5], np.float64)
        x = x[: self.twin.g.shape[1]]
        t0 = time.perf_counter()
        y = self.twin.mvm(x)
        backend_ms = (time.perf_counter() - t0) * 1e3
        drift = round(self.twin.drift(), 4)
        telemetry = self._apply_telemetry_faults({
            "execution_ms": round(backend_ms, 4),
            "drift_score": drift,
            "energy_proxy_mj": 0.001 * len(x),
            "health_status": "healthy" if drift < 0.5 else "degraded",
            "observation_ms": backend_ms,
        })
        return {
            "output": {"vector": y.tolist()},
            "telemetry": telemetry,
            "artifacts": {},
            "backend_ms": backend_ms,
            "needs_reset": drift > 0.6,
        }

    def reset(self, mode: str = "reprogram") -> None:
        self.twin.reprogram()

    def snapshot(self) -> Optional[RuntimeSnapshot]:
        d = self.twin.drift()
        return RuntimeSnapshot(
            self.resource_id,
            health_status="healthy" if d < 0.5 else "degraded",
            drift_score=round(d, 4))

    def make_twin(self) -> Optional[TwinState]:
        return TwinState(f"twin-{self.resource_id}", self.resource_id,
                         kind="behavioral",
                         model={"n": int(self.twin.g.shape[0])},
                         surrogate=CrossbarMirrorSurrogate(self.twin.g_target))
