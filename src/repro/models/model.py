"""Config → model: parameter specs, train loss, prefill/decode steps.

Public surface used by the launcher, dry-run, tests and benchmarks:

- :func:`model_specs`        — ParamSpec pytree for an arch
- :func:`loss_fn`            — full train loss (chunked cross-entropy + MoE aux)
- :func:`build_prefill_step` / :func:`build_decode_step`
- :func:`count_params`       — analytic N (and active-N for MoE)
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import common as cm
from repro.models.transformer import (_PAGED_MIXER_LEAVES, LayerDef, Stack,
                                      build_layer_defs)
from repro.distributed.ctx import constrain


def _decoder(cfg) -> Stack:
    return Stack(cfg)


def _encoder(cfg) -> Stack:
    defs = [LayerDef("attn", "dense")] * cfg.encoder_layers
    return Stack(cfg, bidirectional=True, defs=defs)


def model_specs(cfg) -> dict:
    dt = jnp.dtype(cfg.param_dtype)
    s = {
        "embed": cm.ParamSpec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                              dt, "small"),
        "decoder": _decoder(cfg).specs(),
        "final_norm": cm.norm_spec(cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        s["unembed"] = cm.ParamSpec((cfg.d_model, cfg.vocab_size),
                                    ("embed", "vocab"), dt)
    if cfg.family == "encdec":
        s["encoder"] = _encoder(cfg).specs()
        s["enc_norm"] = cm.norm_spec(cfg, cfg.d_model)
    return s


def count_params(cfg, active_only: bool = False, include_embed: bool = True) -> int:
    total = 0
    m = cfg.moe
    for spec in cm.tree_specs(model_specs(cfg)):
        n = int(np.prod(spec.shape))
        if not include_embed and "vocab" in spec.axes:
            continue
        if active_only and m is not None and "expert" in spec.axes:
            n = int(n * m.top_k / m.num_experts)
        total += n
    return total


def _sinusoid(positions, d_model: int):
    """Whisper-style sinusoidal position embedding; positions: (S,) or scalar."""
    pos = jnp.atleast_1d(positions).astype(jnp.float32)
    half = d_model // 2
    freq = jnp.exp(-np.log(10000.0) * jnp.arange(half, dtype=jnp.float32)
                   / max(half - 1, 1))
    ang = pos[:, None] * freq[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _embed_tokens(cfg, params, tokens):
    x = params["embed"][tokens]
    return constrain(x.astype(jnp.dtype(cfg.compute_dtype)),
                     ("batch", "act_seq", None))


def _logit_kernel(cfg, params):
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["unembed"]


def chunked_xent(cfg, features, kernel, labels, mask=None):
    """Cross-entropy without materializing (B,S,V) logits.

    features: (B,S,d); kernel: (d,V); labels: (B,S) int32.
    Scans over sequence chunks of cfg.xent_chunk.
    """
    B, S, d = features.shape
    C = cfg.xent_chunk if S % cfg.xent_chunk == 0 else S
    n = S // C
    f = features.reshape(B, n, C, d).transpose(1, 0, 2, 3)
    l = labels.reshape(B, n, C).transpose(1, 0, 2)
    if mask is None:
        mask = jnp.ones_like(labels, jnp.float32)
    mk = mask.reshape(B, n, C).transpose(1, 0, 2)

    def body(acc, blk):
        fb, lb, mb = blk
        logits = jnp.einsum("bcd,dv->bcv", fb, kernel).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lb[..., None], axis=-1)[..., 0]
        loss = jnp.sum((lse - gold) * mb)
        return (acc[0] + loss, acc[1] + jnp.sum(mb)), None

    # recompute logits in backward — never materialize (B,S,V)
    body = jax.checkpoint(body)
    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32),
                                        jnp.zeros((), jnp.float32)), (f, l, mk))
    return tot / jnp.maximum(cnt, 1.0)


AUX_WEIGHT = 0.01


def loss_fn(cfg, params, batch):
    """batch: {tokens, labels[, frames][, image_embeds]} → (loss, metrics)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = jnp.arange(S, dtype=jnp.int32)
    x = _embed_tokens(cfg, params, tokens)
    ctx = None
    if cfg.family == "encdec":
        enc_x = batch["frames"].astype(x.dtype)
        enc_pos = jnp.arange(enc_x.shape[1], dtype=jnp.int32)
        enc_x = enc_x + _sinusoid(enc_pos, cfg.d_model).astype(x.dtype)
        ctx, _ = _encoder(cfg).train(params["encoder"], enc_x, enc_pos)
        ctx = cm.apply_norm(cfg, params["enc_norm"], ctx)
        x = x + _sinusoid(positions, cfg.d_model).astype(x.dtype)
    elif cfg.family == "vision":
        ctx = batch["image_embeds"].astype(x.dtype)
    feats, aux = _decoder(cfg).train(params["decoder"], x, positions, ctx)
    feats = cm.apply_norm(cfg, params["final_norm"], feats)
    xent = chunked_xent(cfg, feats, _logit_kernel(cfg, params), batch["labels"])
    loss = xent + AUX_WEIGHT * aux
    return loss, {"xent": xent, "moe_aux": aux}


# ---------------------------------------------------------------------------
# serving


def build_prefill_step(cfg):
    dec = _decoder(cfg)

    def prefill_step(params, batch):
        tokens = batch["tokens"]
        S = tokens.shape[1]
        positions = jnp.arange(S, dtype=jnp.int32)
        x = _embed_tokens(cfg, params, tokens)
        ctx = None
        if cfg.family == "encdec":
            enc_x = batch["frames"].astype(x.dtype)
            enc_pos = jnp.arange(enc_x.shape[1], dtype=jnp.int32)
            enc_x = enc_x + _sinusoid(enc_pos, cfg.d_model).astype(x.dtype)
            ctx, _ = _encoder(cfg).train(params["encoder"], enc_x, enc_pos)
            ctx = cm.apply_norm(cfg, params["enc_norm"], ctx)
            x = x + _sinusoid(positions, cfg.d_model).astype(x.dtype)
        elif cfg.family == "vision":
            ctx = batch["image_embeds"].astype(x.dtype)
        feats, cache, _ = dec.prefill(params["decoder"], x, positions, ctx)
        feats = cm.apply_norm(cfg, params["final_norm"], feats[:, -1:])
        logits = jnp.einsum("bsd,dv->bsv", feats,
                            _logit_kernel(cfg, params)).astype(jnp.float32)
        return cache, logits[:, 0]

    return prefill_step


def build_decode_step(cfg):
    dec = _decoder(cfg)

    def decode_step(params, cache, token, pos):
        """token: (B,1) int32; pos: () or (B,) int32 — absolute position(s)
        of `token` (a (B,) vector puts each row on its own timeline)."""
        x = _embed_tokens(cfg, params, token)
        if cfg.family == "encdec":
            pe = _sinusoid(pos, cfg.d_model).astype(x.dtype)
            x = x + (pe[:, None] if jnp.ndim(pos) == 1 else pe[None])
        feats, cache, _ = dec.decode(params["decoder"], x, cache, pos)
        feats = cm.apply_norm(cfg, params["final_norm"], feats)
        logits = jnp.einsum("bsd,dv->bsv", feats,
                            _logit_kernel(cfg, params)).astype(jnp.float32)
        return cache, logits[:, 0]

    return decode_step


def decode_cache(cfg, batch: int, seq_len: int, abstract: bool = False):
    return _decoder(cfg).cache(batch, seq_len, abstract)


# ---------------------------------------------------------------------------
# paged serving (block-granular KV pool + prefix reuse)


def decode_cache_paged(cfg, batch: int, seq_len: int, pool_pages: int,
                       page_size: int, abstract: bool = False):
    """Decode cache with attn/mla leaves in ``(pool_pages+1, page_size, ...)``
    pool layout (row 0 = null page); resident leaves stay ``(batch, ...)``."""
    return _decoder(cfg).paged_cache(batch, seq_len, pool_pages, page_size,
                                     abstract)


def paged_cache_flags(cfg):
    """Cache-structured bool tree marking pool-layout leaves."""
    return _decoder(cfg).paged_flags()


def paged_support(cfg):
    """-> (any_paged, prefix_ok): whether the arch has pageable cache
    leaves at all, and whether prefix-cache reuse is sound for it (every
    mixer pageable, no cross-attention, no encoder/image context)."""
    defs = build_layer_defs(cfg)
    any_paged = any(d.mixer in _PAGED_MIXER_LEAVES for d in defs)
    prefix_ok = (cfg.family not in ("encdec", "vision")
                 and all(d.mixer in _PAGED_MIXER_LEAVES and not d.cross
                         for d in defs))
    return any_paged, prefix_ok


def _past_seq_len(past) -> int:
    """Static prefix length from a past tree's leaf shapes (trace-time)."""
    for path, leaf in jax.tree_util.tree_flatten_with_path(past)[0]:
        name = None
        for p in reversed(path):
            if isinstance(p, jax.tree_util.DictKey):
                name = p.key
                break
        if name in ("k", "v"):
            return int(leaf.shape[-3])
        if name in ("c_kv", "k_rope"):
            return int(leaf.shape[-2])
    raise ValueError("past tree has no recognizable KV leaf")


def build_prefill_past_step(cfg):
    """Suffix-only prefill against an already-cached prefix.

    ``past`` is a cache-structured tree of the prefix's K/V (latents for
    MLA) at batch 1; its static leaf shapes carry the prefix length, so the
    jit specializes per (suffix_len, prefix_len) pair.  Only archs where
    :func:`paged_support` reports ``prefix_ok`` may use this.
    """
    dec = _decoder(cfg)

    def prefill_past_step(params, batch, past):
        tokens = batch["tokens"]
        S = tokens.shape[1]
        past_len = _past_seq_len(past)
        positions = past_len + jnp.arange(S, dtype=jnp.int32)
        x = _embed_tokens(cfg, params, tokens)
        feats, cache, _ = dec.prefill(params["decoder"], x, positions, None,
                                      past=past, past_len=past_len)
        feats = cm.apply_norm(cfg, params["final_norm"], feats[:, -1:])
        logits = jnp.einsum("bsd,dv->bsv", feats,
                            _logit_kernel(cfg, params)).astype(jnp.float32)
        return cache, logits[:, 0]

    return prefill_past_step


def build_decode_step_paged(cfg, page_size: int):
    dec = _decoder(cfg)

    def decode_step(params, cache, token, pos, tables):
        """token: (B,1) int32; pos: (B,) absolute positions; tables:
        (B, max_pages) int32 page ids (0 = unallocated/null)."""
        x = _embed_tokens(cfg, params, token)
        if cfg.family == "encdec":
            pe = _sinusoid(pos, cfg.d_model).astype(x.dtype)
            x = x + (pe[:, None] if jnp.ndim(pos) == 1 else pe[None])
        feats, cache, _ = dec.decode(params["decoder"], x, cache, pos,
                                     tables=tables, page_size=page_size)
        feats = cm.apply_norm(cfg, params["final_norm"], feats)
        logits = jnp.einsum("bsd,dv->bsv", feats,
                            _logit_kernel(cfg, params)).astype(jnp.float32)
        return cache, logits[:, 0]

    return decode_step
