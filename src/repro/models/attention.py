"""Grouped-query attention with chunked (query-blocked) softmax.

The chunked path is the memory-critical design decision of the whole model
substrate (DESIGN.md §5.1): scores are only ever materialized for one query
block at a time — ``(B, chunk, H, T)`` instead of ``(B, S, H, T)`` — which is
what lets the 32k-prefill cells fit the 16 GB/chip HBM budget. The same
function is the pure-jnp oracle for the Pallas flash-attention kernel
(``repro.kernels.flash_attention``).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import common as cm

NEG_INF = -1e30


def attn_specs(cfg, *, bias: Optional[bool] = None, cross: bool = False) -> dict:
    """Param specs for one (cross-)attention layer."""
    d, h, k, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    if cross:
        k = h  # cross-attn layers use full MHA over image/encoder tokens
    dt = jnp.dtype(cfg.param_dtype)
    use_bias = cfg.qkv_bias if bias is None else bias
    # NOTE (EXPERIMENTS.md §Perf H1d, refuted): sharding hd when heads don't
    # divide converts the grad all-reduce into a reduce-scatter but costs
    # MORE in weight all-gathers under remat (qwen: collective 17.5->19.9s);
    # heads replicate instead and the matcher's roofline twin sees the cost.
    s = {
        "wq": cm.ParamSpec((d, h, hd), ("embed", "heads", None), dt),
        "wk": cm.ParamSpec((d, k, hd), ("embed", "kv_heads", None), dt),
        "wv": cm.ParamSpec((d, k, hd), ("embed", "kv_heads", None), dt),
        "wo": cm.ParamSpec((h, hd, d), ("heads", None, "embed"), dt),
    }
    if use_bias:
        s["bq"] = cm.ParamSpec((h, hd), ("heads", None), jnp.float32, "zeros")
        s["bk"] = cm.ParamSpec((k, hd), ("kv_heads", None), jnp.float32, "zeros")
        s["bv"] = cm.ParamSpec((k, hd), ("kv_heads", None), jnp.float32, "zeros")
    return s


def project_qkv(p: dict, x, xkv=None, sp_constrain: bool = False):
    """(B,S,d) -> q (B,S,H,hd), k/v (B,T,K,hd)."""
    from repro.distributed.ctx import constrain_qkv

    xkv = x if xkv is None else xkv
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("btd,dgk->btgk", xkv, p["wk"])
    v = jnp.einsum("btd,dgk->btgk", xkv, p["wv"])
    if "bq" in p:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    if sp_constrain:
        q = constrain_qkv(q)
        k = constrain_qkv(k)
        v = constrain_qkv(v)
    return q, k, v


def out_proj(p: dict, o):
    from repro.distributed.ctx import constrain_residual

    y = jnp.einsum("bshk,hkd->bsd", o, p["wo"]).astype(o.dtype)
    return constrain_residual(y)


def _block_attend(q_blk, k, v, row_pos, col_pos, *, causal, window, kv_valid):
    """Attention for one query block against the full key range.

    q_blk: (B, C, K, G, hd) fp-compute; k/v: (B, T, K, hd);
    row_pos: (C,) / (B, C) and col_pos: (T,) / (B, T) absolute positions
    (2-D when each batch row sits on its own timeline — continuous batching);
    kv_valid: (T,) / (B, T) bool or None.  Returns (B, C, K, G, hd).
    """
    hd = q_blk.shape[-1]
    scores = jnp.einsum("bckgh,btkh->bckgt", q_blk, k).astype(jnp.float32)
    scores = scores / np.sqrt(hd)
    row = row_pos if row_pos.ndim == 2 else row_pos[None]          # (Bm, C)
    col = col_pos if col_pos.ndim == 2 else col_pos[None]          # (Bm, T)
    mask = jnp.ones((max(row.shape[0], col.shape[0]),
                     row.shape[1], col.shape[1]), jnp.bool_)       # (Bm, C, T)
    if causal:
        mask &= col[:, None, :] <= row[:, :, None]
    if window is not None:
        mask &= col[:, None, :] > (row[:, :, None] - window)
    if kv_valid is not None:
        kvv = kv_valid if kv_valid.ndim == 2 else kv_valid[None]
        mask &= kvv[:, None, :]
    scores = jnp.where(mask[:, :, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q_blk.dtype)
    return jnp.einsum("bckgt,btkh->bckgh", probs, v)


def pallas_attention(cfg, q, k, v, *, causal: bool):
    """Route through the Pallas flash kernel (TPU target; interpret on CPU).

    Only sound for from-scratch causal/bidirectional attention without
    windows/offsets — callers gate on that.
    """
    from repro.kernels.flash_attention.ops import mha

    interpret = jax.default_backend() != "tpu"
    return mha(q, k, v, causal=causal, interpret=interpret)


def chunked_attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
                      chunk: int = 1024, q_offset: int = 0,
                      kv_valid=None, cfg=None):
    """GQA attention, scanning over query blocks of size ``chunk``.

    q: (B, S, H, hd); k, v: (B, T, K, hd) with H = K*G.
    ``q_offset`` places the query block inside the KV timeline (prefill with a
    pre-existing cache / decode).  Exact — no approximation; block size only
    bounds the live score buffer.

    When ``cfg.use_pallas`` is set and the call is kernel-compatible, the
    Pallas flash kernel takes over (kernels are a selectable first-class
    layer, not a fork of the model).
    """
    if (cfg is not None and cfg.use_pallas and window is None
            and q_offset == 0 and kv_valid is None
            and q.shape[1] == k.shape[1]):
        return pallas_attention(cfg, q, k, v, causal=causal)
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    qg = q.reshape(B, S, K, G, hd)
    col_pos = jnp.arange(T, dtype=jnp.int32)

    if S <= chunk:
        row_pos = q_offset + jnp.arange(S, dtype=jnp.int32)
        o = _block_attend(qg, k, v, row_pos, col_pos, causal=causal,
                          window=window, kv_valid=kv_valid)
        return o.reshape(B, S, H, hd)

    pad = (-S) % chunk
    if pad:
        qg = jnp.pad(qg, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
    nb = (S + pad) // chunk
    qb = qg.reshape(B, nb, chunk, K, G, hd).transpose(1, 0, 2, 3, 4, 5)

    def body(_, blk):
        i, qi = blk
        row_pos = q_offset + i * chunk + jnp.arange(chunk, dtype=jnp.int32)
        oi = _block_attend(qi, k, v, row_pos, col_pos, causal=causal,
                           window=window, kv_valid=kv_valid)
        return None, oi

    # flash-style recompute: without this, scan saves every block's softmax
    # for backward — i.e. the full (B,S,H,T) attention matrix
    body = jax.checkpoint(body)
    _, ob = jax.lax.scan(body, None, (jnp.arange(nb, dtype=jnp.int32), qb))
    o = ob.transpose(1, 0, 2, 3, 4, 5).reshape(B, nb * chunk, K, G, hd)
    if pad:
        o = o[:, :S]
    return o.reshape(B, S, H, hd)


def self_attention(cfg, p: dict, x, positions, *, causal=True,
                   window: Optional[int] = None):
    """Full-sequence self-attention (train / encoder)."""
    from repro.distributed.sp_attention import maybe_sp_attention_fused
    from repro.distributed.sp_block import sp_gqa_block

    blk = sp_gqa_block(cfg, p, x, positions, causal=causal, window=window,
                       with_cache=False)
    if blk is not None:
        return blk[0]
    q, k, v = project_qkv(p, x, sp_constrain=True)
    if cfg.family != "encdec":  # whisper uses absolute pos-emb, not RoPE
        q = cm.rope(q, positions, cfg.rope_theta)
        k = cm.rope(k, positions, cfg.rope_theta)
    y = maybe_sp_attention_fused(q, k, v, p["wo"], causal=causal,
                                 window=window, chunk=cfg.attn_chunk)
    if y is not None:
        return y
    o = chunked_attention(q, k, v, causal=causal, window=window,
                          chunk=cfg.attn_chunk, cfg=cfg)
    return out_proj(p, o)


def prefill_attention(cfg, p: dict, x, positions, *, window: Optional[int] = None,
                      past: Optional[dict] = None, past_len: int = 0):
    """Self-attention that also returns the KV cache (ring-buffered if local).

    With ``past`` (k/v of an already-cached prefix, (B, past_len, K, hd)),
    only the suffix is computed: queries at ``positions`` (absolute, i.e.
    ``past_len + arange(S)``) attend over concat(past, suffix) and the
    returned cache covers the *suffix only* — the prefix's pages already
    hold its K/V.
    """
    from repro.distributed.sp_attention import maybe_sp_attention_fused
    from repro.distributed.sp_block import sp_gqa_block

    if past is not None:
        q, k, v = project_qkv(p, x, sp_constrain=True)
        if cfg.family != "encdec":
            q = cm.rope(q, positions, cfg.rope_theta)
            k = cm.rope(k, positions, cfg.rope_theta)
        k_all = jnp.concatenate([past["k"].astype(k.dtype), k], axis=1)
        v_all = jnp.concatenate([past["v"].astype(v.dtype), v], axis=1)
        o = chunked_attention(q, k_all, v_all, causal=True, window=window,
                              chunk=cfg.attn_chunk, q_offset=past_len)
        return out_proj(p, o), {"k": k, "v": v}

    blk = sp_gqa_block(cfg, p, x, positions, causal=True, window=window,
                       with_cache=True)
    if blk is not None:
        y, cache = blk
        if window is not None and cache["k"].shape[1] > window:
            cache = {"k": cache["k"][:, -window:], "v": cache["v"][:, -window:]}
        return y, cache
    q, k, v = project_qkv(p, x, sp_constrain=True)
    if cfg.family != "encdec":
        q = cm.rope(q, positions, cfg.rope_theta)
        k = cm.rope(k, positions, cfg.rope_theta)
    y = maybe_sp_attention_fused(q, k, v, p["wo"], causal=True,
                                 window=window, chunk=cfg.attn_chunk)
    if y is None:
        o = chunked_attention(q, k, v, causal=True, window=window,
                              chunk=cfg.attn_chunk)
        y = out_proj(p, o)
    if window is not None and k.shape[1] > window:
        k, v = k[:, -window:], v[:, -window:]
    return y, {"k": k, "v": v}


def decode_attention(cfg, p: dict, x, cache: dict, pos, *,
                     window: Optional[int] = None):
    """One-token decode against a (B, T, K, hd) cache.

    Global attention: cache holds T = max_seq slots, slot ``pos`` is written.
    Local attention: cache is a ring buffer of ``window`` slots.
    ``pos`` is a scalar (the whole batch at one absolute position) or a
    (B,) vector (continuous batching: each row on its own timeline).
    """
    q, k_new, v_new = project_qkv(p, x)           # (B, 1, ., .)
    pos = jnp.asarray(pos, jnp.int32)
    per_row = pos.ndim == 1
    posv = pos[:, None] if per_row else jnp.full((1,), pos, jnp.int32)
    if cfg.family != "encdec":
        q = cm.rope(q, posv, cfg.rope_theta)
        k_new = cm.rope(k_new, posv, cfg.rope_theta)
    k_cache, v_cache = cache["k"], cache["v"]
    T = k_cache.shape[1]
    slot = pos % jnp.int32(T) if window is not None else pos
    if per_row:
        b = jnp.arange(q.shape[0])
        k_cache = k_cache.at[b, slot].set(k_new[:, 0].astype(k_cache.dtype))
        v_cache = v_cache.at[b, slot].set(v_new[:, 0].astype(v_cache.dtype))
    else:
        k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new.astype(k_cache.dtype), slot, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new.astype(v_cache.dtype), slot, axis=1)
    idx = jnp.arange(T, dtype=jnp.int32)
    if window is None:
        col_pos = idx
        kv_valid = (idx[None, :] <= pos[:, None]) if per_row else (idx <= pos)
    else:
        # ring buffer: slot i holds absolute position p with p % T == i, the
        # largest such p <= pos
        prow = pos[:, None] if per_row else pos
        col_pos = prow - ((prow - idx) % jnp.int32(T))    # (B, T) or (T,)
        kv_valid = col_pos >= 0
    B, _, H, hd = q.shape
    K = k_cache.shape[2]
    qg = q.reshape(B, 1, K, H // K, hd)
    o = _block_attend(qg, k_cache, v_cache, posv, col_pos, causal=True,
                      window=window, kv_valid=kv_valid)
    o = o.reshape(B, 1, H, hd)
    return out_proj(p, o), {"k": k_cache, "v": v_cache}


def paged_decode_attention(cfg, p: dict, x, cache: dict, pos, tables, *,
                           page_size: int):
    """One-token decode against a block-granular paged KV pool.

    cache k/v: (num_pages+1, page_size, K, hd) — row 0 is the null page
    that dead batch rows write into and no one reads.
    tables: (B, max_pages) int32 page ids (0 where unallocated) — the
    per-row page-index vectors generalizing the per-row position vectors.
    pos: (B,) per-row absolute positions.  The engine guarantees every
    position <= pos[b] is backed by a real page in row b's table, and that
    the write page (block ``pos // page_size``) is private to row b —
    shared prefix pages are immutable by construction.
    """
    q, k_new, v_new = project_qkv(p, x)           # (B, 1, ., .)
    pos = jnp.asarray(pos, jnp.int32)
    posv = pos[:, None]
    if cfg.family != "encdec":
        q = cm.rope(q, posv, cfg.rope_theta)
        k_new = cm.rope(k_new, posv, cfg.rope_theta)
    k_pool, v_pool = cache["k"], cache["v"]
    B = q.shape[0]
    b = jnp.arange(B)
    pid = tables[b, pos // jnp.int32(page_size)]  # (B,) write page per row
    off = pos % jnp.int32(page_size)
    k_pool = k_pool.at[pid, off].set(k_new[:, 0].astype(k_pool.dtype))
    v_pool = v_pool.at[pid, off].set(v_new[:, 0].astype(v_pool.dtype))
    K, hd = k_pool.shape[-2], k_pool.shape[-1]
    T = tables.shape[1] * page_size
    k = k_pool[tables].reshape(B, T, K, hd)       # gather through the table
    v = v_pool[tables].reshape(B, T, K, hd)
    idx = jnp.arange(T, dtype=jnp.int32)
    kv_valid = idx[None, :] <= pos[:, None]
    H = q.shape[2]
    qg = q.reshape(B, 1, K, H // K, hd)
    o = _block_attend(qg, k, v, posv, idx, causal=True, window=None,
                      kv_valid=kv_valid)
    o = o.reshape(B, 1, H, hd)
    return out_proj(p, o), {"k": k_pool, "v": v_pool}


def cross_attention(cfg, p: dict, x, kv_cache: dict):
    """Cross-attention against precomputed encoder/image K,V (full MHA)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if "bq" in p:
        q = q + p["bq"].astype(q.dtype)
    o = chunked_attention(q, kv_cache["k"], kv_cache["v"], causal=False,
                          chunk=cfg.attn_chunk)
    return out_proj(p, o)


def cross_kv(p: dict, ctx):
    """Precompute cross-attention K,V from encoder/image embeddings."""
    k = jnp.einsum("btd,dgk->btgk", ctx, p["wk"])
    v = jnp.einsum("btd,dgk->btgk", ctx, p["wv"])
    if "bk" in p:
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    return {"k": k, "v": v}
