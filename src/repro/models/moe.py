"""Routed mixture-of-experts with sort-based capacity dispatch.

Dispatch strategy (DESIGN.md §5): the classic GShard one-hot-einsum dispatch
materializes a (tokens, experts, capacity) tensor, which at the assigned
sizes (65k tokens/shard × 160 experts × ~3k capacity) is terabytes. We
instead use the *sort-based* dropless-style formulation:

1. route: top-k expert ids per token,
2. flatten (token, choice) pairs and stable-sort by expert id,
3. compute each pair's slot within its expert via a running count,
4. scatter token activations into an (E, C, d) buffer (overflow → dropped,
   weight zeroed — capacity_factor controls the drop rate),
5. per-expert batched GEMM (E-sharded on the "expert"/model axis),
6. gather outputs back per (token, choice) and combine with router weights.

All shapes are static; the sort is O(T·k log) and the buffers are
E-sharded, which is what makes the 160-expert DeepSeek cell fit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.ctx import constrain
from repro.models import common as cm


def moe_specs(cfg) -> dict:
    m = cfg.moe
    d, f, e = cfg.d_model, m.expert_d_ff, m.num_experts
    dt = jnp.dtype(cfg.param_dtype)
    s = {
        "router": cm.ParamSpec((d, e), ("embed", None), jnp.float32, "small"),
        "w_gate": cm.ParamSpec((e, d, f), ("expert", "embed", "mlp"), dt),
        "w_up": cm.ParamSpec((e, d, f), ("expert", "embed", "mlp"), dt),
        "w_down": cm.ParamSpec((e, f, d), ("expert", "mlp", "embed"), dt),
    }
    if m.num_shared_experts:
        fs = m.shared_ff
        s["shared"] = {
            "w_gate": cm.ParamSpec((d, fs), ("embed", "mlp"), dt),
            "w_up": cm.ParamSpec((d, fs), ("embed", "mlp"), dt),
            "w_down": cm.ParamSpec((fs, d), ("mlp", "embed"), dt),
        }
    return s


def _route(cfg, p, x2d):
    """x2d: (T, d) -> probs (T, k), ids (T, k), aux load-balance loss."""
    m = cfg.moe
    logits = jnp.einsum("td,de->te", x2d.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, m.top_k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)       # renormalize
    # Switch-style load-balance aux loss: E * sum_e f_e * P_e
    density = jnp.mean(jax.nn.one_hot(top_i[:, 0], m.num_experts), axis=0)
    mean_prob = jnp.mean(probs, axis=0)
    aux = m.num_experts * jnp.sum(density * mean_prob)
    return top_p, top_i, aux


def moe_ffn(cfg, p: dict, x):
    """x: (B, S, d) -> (B, S, d), plus aux loss (returned via dict)."""
    from repro.distributed.sp_moe import sp_moe

    sp = sp_moe(cfg, p, x)       # explicit EP dispatch when sharded (H2)
    if sp is not None:
        y, aux = sp
        if "shared" in p:
            y = y + _shared_experts(cfg, p["shared"], x)
        return y, aux
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    x2d = x.reshape(T, d)
    top_p, top_i, aux = _route(cfg, p, x2d)

    k = m.top_k
    E = m.num_experts
    cap = int(max(1, round(T * k / E * m.capacity_factor)))
    # pad capacity to the 128-lane boundary so the expert GEMM is MXU-aligned
    cap = -(-cap // 128) * 128 if cap > 128 else cap

    flat_e = top_i.reshape(T * k)                                 # expert id / pair
    order = jnp.argsort(flat_e, stable=True)                      # sort pairs by expert
    sorted_e = flat_e[order]
    # slot of each sorted pair within its expert = rank - start_of_expert
    starts = jnp.searchsorted(sorted_e, jnp.arange(E, dtype=sorted_e.dtype))
    slot = jnp.arange(T * k, dtype=jnp.int32) - starts[sorted_e].astype(jnp.int32)
    keep = slot < cap                                             # overflow drops
    slot_c = jnp.minimum(slot, cap - 1)

    tok = (order // k).astype(jnp.int32)                          # source token / pair
    buf = jnp.zeros((E, cap, d), x.dtype)
    upd = jnp.where(keep[:, None], x2d[tok], 0)
    buf = buf.at[sorted_e, slot_c].add(upd, mode="drop")
    buf = constrain(buf, ("expert", None, None))

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])) * \
        jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"]).astype(x.dtype)
    out_buf = constrain(out_buf, ("expert", None, None))

    gathered = out_buf[sorted_e, slot_c]                          # (T*k, d)
    gathered = jnp.where(keep[:, None], gathered, 0)
    pair_w = top_p.reshape(T * k)[order].astype(x.dtype)
    contrib = gathered * pair_w[:, None]
    y2d = jnp.zeros((T, d), x.dtype).at[tok].add(contrib)

    if "shared" in p:
        y2d = y2d + _shared_experts(cfg, p["shared"], x2d).reshape(T, d)

    return y2d.reshape(B, S, d), aux


def _shared_experts(cfg, sp: dict, x):
    """Always-on shared experts (DeepSeek/Moonlight).

    Same weight layout as the dense FFN, so the explicit-collective
    Megatron/ZeRO-3 block applies directly (H2d — without it the shared
    experts re-introduce the full-seq gather + dx all-reduce per layer)."""
    if x.ndim == 3:
        from repro.distributed.sp_ffn import sp_ffn

        y = sp_ffn(cfg, sp, x)
        if y is not None:
            return y
    h = jax.nn.silu(jnp.einsum("...d,df->...f", x, sp["w_gate"])) * \
        jnp.einsum("...d,df->...f", x, sp["w_up"])
    if h.ndim == 3:
        h = constrain(h, ("batch", None, "mlp"))
    y = jnp.einsum("...f,fd->...d", h, sp["w_down"]).astype(x.dtype)
    if y.ndim == 3:
        y = constrain(y, ("batch", "act_seq", None))
    return y
