"""Generic decoder/encoder stack over heterogeneous layer kinds.

The stack is described by a list of :class:`LayerDef` (mixer kind × FFN kind ×
optional cross-attention), which is factored into

    prefix layers  +  (cycle of length c) × reps  +  suffix layers

so that the repeated cycle runs under a single ``jax.lax.scan`` with stacked
parameters — HLO size and compile time stay flat in depth (96-layer nemotron
compiles like a 1-layer model). Prefix covers e.g. the dense first layer of
the MoE archs; suffix covers pattern remainders (recurrentgemma's 38 = 12×3+2).

Three modes share the same layer application:
- ``train``   — full sequence, no cache,
- ``prefill`` — full sequence, emits the decode cache,
- ``decode``  — one token against the cache.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import common as cm
from repro.models import ffn as ffn_mod
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import rwkv6 as rwkv_mod
from repro.distributed.ctx import constrain, constrain_cache


@dataclasses.dataclass(frozen=True)
class LayerDef:
    mixer: str              # attn | local_attn | recurrent | rwkv | mla | cross_only
    ffn: str                # dense | moe | rwkv_cm
    cross: bool = False     # additional cross-attn (whisper decoder)


def build_layer_defs(cfg) -> List[LayerDef]:
    if cfg.family == "rwkv":
        return [LayerDef("rwkv", "rwkv_cm")] * cfg.num_layers
    if cfg.family == "vision":
        e = cfg.cross_attn_every
        return [LayerDef("cross_only" if (i % e) == e - 1 else "attn", "dense")
                for i in range(cfg.num_layers)]
    if cfg.family == "encdec":
        return [LayerDef("attn", "dense", cross=True)] * cfg.num_layers
    if cfg.moe is not None:
        mixer = "mla" if cfg.mla is not None else "attn"
        f = cfg.moe.first_moe_layer
        return [LayerDef(mixer, "dense" if i < f else "moe")
                for i in range(cfg.num_layers)]
    kinds = cfg.layer_kinds()
    return [LayerDef(k, "dense") for k in kinds]


def factor_layers(cfg, defs: List[LayerDef]) -> Tuple[List, List, int, List]:
    """-> (prefix_defs, cycle_defs, reps, suffix_defs)."""
    prefix_len = 0
    if cfg.moe is not None:
        prefix_len = cfg.moe.first_moe_layer
    cyc_len = 1
    if cfg.family == "hybrid":
        cyc_len = len(cfg.block_pattern)
    elif cfg.family == "vision":
        cyc_len = cfg.cross_attn_every
    body = defs[prefix_len:]
    reps = len(body) // cyc_len
    cycle = body[:cyc_len] if reps else []
    suffix = body[reps * cyc_len:]
    for i, d in enumerate(body[: reps * cyc_len]):
        assert d == cycle[i % cyc_len], f"non-cyclic layer structure at {i}"
    return defs[:prefix_len], cycle, reps, suffix


# ---------------------------------------------------------------------------
# per-layer specs


def layer_specs(cfg, ld: LayerDef) -> dict:
    s = {"ln1": cm.norm_spec(cfg, cfg.d_model)}
    if ld.mixer in ("attn", "local_attn"):
        s["mixer"] = attn.attn_specs(cfg)
    elif ld.mixer == "mla":
        s["mixer"] = mla_mod.mla_specs(cfg)
    elif ld.mixer == "recurrent":
        s["mixer"] = rglru_mod.rglru_specs(cfg)
    elif ld.mixer == "rwkv":
        s["mixer"] = rwkv_mod.rwkv_specs(cfg)
    elif ld.mixer == "cross_only":
        s["mixer"] = attn.attn_specs(cfg, cross=True)
        s["xgate"] = cm.ParamSpec((1,), (None,), jnp.float32, "zeros")
    if ld.cross:
        s["ln_cross"] = cm.norm_spec(cfg, cfg.d_model)
        s["cross"] = attn.attn_specs(cfg, cross=True)
    s["ln2"] = cm.norm_spec(cfg, cfg.d_model)
    if ld.ffn == "dense":
        s["ffn"] = ffn_mod.ffn_specs(cfg)
    elif ld.ffn == "moe":
        s["ffn"] = moe_mod.moe_specs(cfg)
    elif ld.ffn == "rwkv_cm":
        s["ffn"] = ffn_mod.rwkv_channel_mix_specs(cfg)
    return s


def stack_specs(tree, n: int):
    return jax.tree.map(
        lambda s: cm.ParamSpec((n,) + s.shape, ("layers",) + s.axes, s.dtype,
                               s.init, s.scale),
        tree, is_leaf=cm.is_spec)


# ---------------------------------------------------------------------------
# caches


def layer_cache(cfg, ld: LayerDef, batch: int, seq_len: int, abstract: bool):
    """Decode-cache template for one layer (None if the layer is stateless)."""
    K, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    pdt = jnp.dtype(cfg.param_dtype)

    def mk(shape, dtype):
        if abstract:
            return jax.ShapeDtypeStruct(shape, dtype)
        return jnp.zeros(shape, dtype)

    c = {}
    if ld.mixer == "attn":
        c = {"k": mk((batch, seq_len, K, hd), pdt), "v": mk((batch, seq_len, K, hd), pdt)}
    elif ld.mixer == "local_attn":
        w = min(cfg.local_window, seq_len)
        c = {"k": mk((batch, w, K, hd), pdt), "v": mk((batch, w, K, hd), pdt)}
    elif ld.mixer == "mla":
        a = cfg.mla
        c = {"c_kv": mk((batch, seq_len, a.kv_lora_rank), pdt),
             "k_rope": mk((batch, seq_len, a.qk_rope_head_dim), pdt)}
    elif ld.mixer == "recurrent":
        r = cfg.recurrent
        c = {"h": mk((batch, r.lru_width), jnp.float32),
             "conv": mk((batch, r.conv_width - 1, r.lru_width), jnp.float32)}
    elif ld.mixer == "rwkv":
        c = {"s": mk((batch, cfg.num_heads, cfg.rwkv.head_dim, cfg.rwkv.head_dim),
                     jnp.float32),
             "ts_tm": mk((batch, cfg.d_model), pdt),
             "ts_cm": mk((batch, cfg.d_model), pdt)}
    elif ld.mixer == "cross_only":
        t = cfg.num_image_tokens
        c = {"ck": mk((batch, t, cfg.num_heads, hd), pdt),
             "cv": mk((batch, t, cfg.num_heads, hd), pdt)}
    if ld.cross:
        t = cfg.encoder_frames
        # cross-attention layers are full MHA (attn_specs(cross=True))
        c["cross_k"] = mk((batch, t, cfg.num_heads, hd), pdt)
        c["cross_v"] = mk((batch, t, cfg.num_heads, hd), pdt)
    return c


def stack_cache(tree, n: int, abstract: bool):
    def f(x):
        if isinstance(x, jax.ShapeDtypeStruct):
            return jax.ShapeDtypeStruct((n,) + x.shape, x.dtype)
        return jnp.broadcast_to(x, (n,) + x.shape)
    return jax.tree.map(f, tree)


#: cache leaves that page (global, unbounded-growth KV); every other leaf is
#: *resident* — bounded per-row state (ring-buffer window, recurrent/rwkv
#: carries, precomputed cross K/V) that stays slot-granular
_PAGED_MIXER_LEAVES = {"attn": ("k", "v"), "mla": ("c_kv", "k_rope")}


def layer_cache_paged(cfg, ld: LayerDef, batch: int, seq_len: int,
                      pool_pages: int, page_size: int, abstract: bool):
    """Like :func:`layer_cache`, but pageable leaves take the pool layout
    ``(pool_pages + 1, page_size, ...)`` — row 0 is the null/trash page —
    shared across batch rows via per-row page tables.  Resident leaves keep
    their slot-granular ``(batch, ...)`` layout."""
    c = layer_cache(cfg, ld, batch, seq_len, abstract)
    pdt = jnp.dtype(cfg.param_dtype)

    def mk(shape, dtype):
        if abstract:
            return jax.ShapeDtypeStruct(shape, dtype)
        return jnp.zeros(shape, dtype)

    if ld.mixer == "attn":
        K, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        c["k"] = mk((pool_pages + 1, page_size, K, hd), pdt)
        c["v"] = mk((pool_pages + 1, page_size, K, hd), pdt)
    elif ld.mixer == "mla":
        a = cfg.mla
        c["c_kv"] = mk((pool_pages + 1, page_size, a.kv_lora_rank), pdt)
        c["k_rope"] = mk((pool_pages + 1, page_size, a.qk_rope_head_dim), pdt)
    return c


def layer_paged_flags(cfg, ld: LayerDef) -> dict:
    """Cache-structured tree of bools: True on pageable leaves."""
    paged = _PAGED_MIXER_LEAVES.get(ld.mixer, ())
    base = layer_cache(cfg, ld, 1, 2, abstract=True)
    return {name: name in paged for name in base}


# ---------------------------------------------------------------------------
# layer application


def _mixer_train(cfg, ld, p, x, positions, ctx, states):
    """Full-seq mixer. states: dict with optional rwkv/recurrent carries."""
    h = cm.apply_norm(cfg, p["ln1"], x)
    new_state = None
    if ld.mixer == "attn":
        causal = not states.get("bidirectional", False)
        out = attn.self_attention(cfg, p["mixer"], h, positions, causal=causal)
    elif ld.mixer == "local_attn":
        out = attn.self_attention(cfg, p["mixer"], h, positions,
                                  window=cfg.local_window)
    elif ld.mixer == "mla":
        out = mla_mod.mla_attention(cfg, p["mixer"], h, positions)
    elif ld.mixer == "recurrent":
        out, new_state = rglru_mod.rglru_block(cfg, p["mixer"], h)
    elif ld.mixer == "rwkv":
        out, s, last = rwkv_mod.rwkv_time_mix(cfg, p["mixer"], h,
                                              want_state=False)
        new_state = (s, last)
    elif ld.mixer == "cross_only":
        out = attn.cross_attention(cfg, p["mixer"], h,
                                   attn.cross_kv(p["mixer"], ctx))
        out = out * jnp.tanh(p["xgate"]).astype(out.dtype)
    x = x + out
    if ld.cross:
        hc = cm.apply_norm(cfg, p["ln_cross"], x)
        x = x + attn.cross_attention(cfg, p["cross"], hc,
                                     attn.cross_kv(p["cross"], ctx))
    return x, new_state


def _ffn_apply(cfg, ld, p, x, aux, ts_prev=None):
    h = cm.apply_norm(cfg, p["ln2"], x)
    if ld.ffn == "moe":
        out, a = moe_mod.moe_ffn(cfg, p["ffn"], h)
        aux = aux + a
    elif ld.ffn == "rwkv_cm":
        if ts_prev is None:
            prev = jnp.pad(h, ((0, 0), (1, 0), (0, 0)))[:, :-1]
        else:
            prev = jnp.concatenate([ts_prev[:, None], h[:, :-1]], axis=1)
        out = ffn_mod.rwkv_channel_mix(cfg, p["ffn"], h, prev)
    else:
        out = ffn_mod.ffn(cfg, p["ffn"], h)
    return x + out, aux


def apply_layer_train(cfg, ld, p, x, positions, ctx, aux, bidirectional=False):
    x = constrain(x, ("batch", "act_seq", None))
    x, _ = _mixer_train(cfg, ld, p, x, positions, ctx,
                        {"bidirectional": bidirectional})
    x, aux = _ffn_apply(cfg, ld, p, x, aux)
    return x, aux


def apply_layer_prefill(cfg, ld, p, x, positions, ctx, aux,
                        past=None, past_len=0):
    """Train-path compute + emit decode cache.

    ``past`` (prefix-cache reuse) carries this layer's already-computed
    prefix K/V (or latents); only attn/mla mixers support it — the engine
    gates prefix sharing to stacks made purely of those."""
    x = constrain(x, ("batch", "act_seq", None))
    cache = {}
    h = cm.apply_norm(cfg, p["ln1"], x)
    if past is not None and ld.mixer not in _PAGED_MIXER_LEAVES:
        raise ValueError(f"prefix reuse unsupported for mixer {ld.mixer!r}")
    if ld.mixer == "attn":
        out, kv = attn.prefill_attention(cfg, p["mixer"], h, positions,
                                         past=past, past_len=past_len)
        # right-pad the cache to the cell's full seq_len is done by caller
        cache.update(kv)
    elif ld.mixer == "local_attn":
        out, kv = attn.prefill_attention(cfg, p["mixer"], h, positions,
                                         window=cfg.local_window)
        cache.update(kv)
    elif ld.mixer == "mla":
        out, kv = mla_mod.mla_prefill(cfg, p["mixer"], h, positions,
                                      past=past, past_len=past_len)
        cache.update(kv)
    elif ld.mixer == "recurrent":
        out, (hf, conv) = rglru_mod.rglru_block(cfg, p["mixer"], h)
        cache.update({"h": hf, "conv": conv})
    elif ld.mixer == "rwkv":
        out, s, last = rwkv_mod.rwkv_time_mix(cfg, p["mixer"], h)
        cache.update({"s": s, "ts_tm": last})
    elif ld.mixer == "cross_only":
        ckv = attn.cross_kv(p["mixer"], ctx)
        out = attn.cross_attention(cfg, p["mixer"], h, ckv)
        out = out * jnp.tanh(p["xgate"]).astype(out.dtype)
        cache.update({"ck": ckv["k"], "cv": ckv["v"]})
    x = x + out
    if ld.cross:
        hc = cm.apply_norm(cfg, p["ln_cross"], x)
        ckv = attn.cross_kv(p["cross"], ctx)
        x = x + attn.cross_attention(cfg, p["cross"], hc, ckv)
        cache.update({"cross_k": ckv["k"], "cross_v": ckv["v"]})
    h2 = cm.apply_norm(cfg, p["ln2"], x)
    if ld.ffn == "rwkv_cm":
        prev = jnp.pad(h2, ((0, 0), (1, 0), (0, 0)))[:, :-1]
        x = x + ffn_mod.rwkv_channel_mix(cfg, p["ffn"], h2, prev)
        cache["ts_cm"] = h2[:, -1]
    else:
        x, aux = _ffn_apply(cfg, ld, p, x, aux)
    return x, constrain_cache(cache), aux


def apply_layer_decode(cfg, ld, p, x, cache, pos, aux,
                       tables=None, page_size=None):
    """x: (B,1,d). Returns (x, new_cache).

    With ``tables`` (paged serving), attn/mla leaves live in a shared page
    pool gathered through per-row page tables; resident mixers are
    untouched — they keep per-row state and the per-row ``pos`` vector."""
    x = constrain(x, ("batch", "act_seq", None))
    h = cm.apply_norm(cfg, p["ln1"], x)
    new_cache = dict(cache)
    if ld.mixer == "attn":
        if tables is not None:
            out, kv = attn.paged_decode_attention(
                cfg, p["mixer"], h, {"k": cache["k"], "v": cache["v"]}, pos,
                tables, page_size=page_size)
        else:
            out, kv = attn.decode_attention(
                cfg, p["mixer"], h, {"k": cache["k"], "v": cache["v"]}, pos)
        new_cache.update(kv)
    elif ld.mixer == "local_attn":
        out, kv = attn.decode_attention(cfg, p["mixer"], h,
                                        {"k": cache["k"], "v": cache["v"]}, pos,
                                        window=cfg.local_window)
        new_cache.update(kv)
    elif ld.mixer == "mla":
        if tables is not None:
            out, kv = mla_mod.mla_paged_decode(
                cfg, p["mixer"], h,
                {"c_kv": cache["c_kv"], "k_rope": cache["k_rope"]}, pos,
                tables, page_size=page_size)
        else:
            out, kv = mla_mod.mla_decode(cfg, p["mixer"], h,
                                         {"c_kv": cache["c_kv"],
                                          "k_rope": cache["k_rope"]}, pos)
        new_cache.update(kv)
    elif ld.mixer == "recurrent":
        out, hf, conv = rglru_mod.rglru_decode(cfg, p["mixer"], h,
                                               cache["h"], cache["conv"])
        new_cache.update({"h": hf, "conv": conv})
    elif ld.mixer == "rwkv":
        out, s, last = rwkv_mod.rwkv_decode(cfg, p["mixer"], h, cache["s"],
                                            cache["ts_tm"])
        new_cache.update({"s": s, "ts_tm": last})
    elif ld.mixer == "cross_only":
        out = attn.cross_attention(cfg, p["mixer"], h,
                                   {"k": cache["ck"], "v": cache["cv"]})
        out = out * jnp.tanh(p["xgate"]).astype(out.dtype)
    x = x + out
    if ld.cross:
        hc = cm.apply_norm(cfg, p["ln_cross"], x)
        x = x + attn.cross_attention(cfg, p["cross"], hc,
                                     {"k": cache["cross_k"], "v": cache["cross_v"]})
    h2 = cm.apply_norm(cfg, p["ln2"], x)
    if ld.ffn == "rwkv_cm":
        prev = cache["ts_cm"][:, None]
        x = x + ffn_mod.rwkv_channel_mix(cfg, p["ffn"], h2, prev)
        new_cache["ts_cm"] = h2[:, 0]
    elif ld.ffn == "moe":
        out, a = moe_mod.moe_ffn(cfg, p["ffn"], h2)
        x = x + out
        aux = aux + a
    else:
        x = x + ffn_mod.ffn(cfg, p["ffn"], h2)
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# stack


class Stack:
    """Factored layer stack bound to a config (decoder by default)."""

    def __init__(self, cfg, bidirectional: bool = False,
                 defs: Optional[List[LayerDef]] = None):
        self.cfg = cfg
        self.bidirectional = bidirectional
        self.defs = defs if defs is not None else build_layer_defs(cfg)
        self.prefix, self.cycle, self.reps, self.suffix = factor_layers(cfg, self.defs)

    # -- specs --------------------------------------------------------------
    def specs(self) -> dict:
        s = {}
        if self.prefix:
            s["prefix"] = {str(i): layer_specs(self.cfg, d)
                           for i, d in enumerate(self.prefix)}
        if self.reps:
            s["blocks"] = {str(i): stack_specs(layer_specs(self.cfg, d), self.reps)
                           for i, d in enumerate(self.cycle)}
        if self.suffix:
            s["suffix"] = {str(i): layer_specs(self.cfg, d)
                           for i, d in enumerate(self.suffix)}
        return s

    def cache(self, batch: int, seq_len: int, abstract: bool = False) -> dict:
        c = {}
        if self.prefix:
            c["prefix"] = {str(i): layer_cache(self.cfg, d, batch, seq_len, abstract)
                           for i, d in enumerate(self.prefix)}
        if self.reps:
            c["blocks"] = {str(i): stack_cache(
                layer_cache(self.cfg, d, batch, seq_len, abstract), self.reps, abstract)
                for i, d in enumerate(self.cycle)}
        if self.suffix:
            c["suffix"] = {str(i): layer_cache(self.cfg, d, batch, seq_len, abstract)
                           for i, d in enumerate(self.suffix)}
        return c

    def paged_cache(self, batch: int, seq_len: int, pool_pages: int,
                    page_size: int, abstract: bool = False) -> dict:
        """Decode cache with pageable leaves in pool layout (null page at
        row 0); ``seq_len`` still sizes the resident leaves."""
        def lc(d):
            return layer_cache_paged(self.cfg, d, batch, seq_len,
                                     pool_pages, page_size, abstract)
        c = {}
        if self.prefix:
            c["prefix"] = {str(i): lc(d) for i, d in enumerate(self.prefix)}
        if self.reps:
            c["blocks"] = {str(i): stack_cache(lc(d), self.reps, abstract)
                           for i, d in enumerate(self.cycle)}
        if self.suffix:
            c["suffix"] = {str(i): lc(d) for i, d in enumerate(self.suffix)}
        return c

    def paged_flags(self) -> dict:
        """Cache-structured bool tree: True on pageable (pool-layout) leaves.
        Matches :meth:`cache`'s tree structure exactly (bools under
        ``blocks`` are not layer-stacked — a leaf's pagedness is uniform
        across the scanned cycle repetitions)."""
        c = {}
        if self.prefix:
            c["prefix"] = {str(i): layer_paged_flags(self.cfg, d)
                           for i, d in enumerate(self.prefix)}
        if self.reps:
            c["blocks"] = {str(i): layer_paged_flags(self.cfg, d)
                           for i, d in enumerate(self.cycle)}
        if self.suffix:
            c["suffix"] = {str(i): layer_paged_flags(self.cfg, d)
                           for i, d in enumerate(self.suffix)}
        return c

    # -- forward ------------------------------------------------------------
    def train(self, p: dict, x, positions, ctx=None):
        cfg = self.cfg
        aux = jnp.zeros((), jnp.float32)
        for i, d in enumerate(self.prefix):
            x, aux = apply_layer_train(cfg, d, p["prefix"][str(i)], x, positions,
                                       ctx, aux, self.bidirectional)
        if self.reps:
            def body(carry, bp):
                x, aux = carry
                for i, d in enumerate(self.cycle):
                    x, aux = apply_layer_train(cfg, d, bp[str(i)], x, positions,
                                               ctx, aux, self.bidirectional)
                return (x, aux), None
            body = cm.maybe_remat(body, cfg.remat_policy)
            (x, aux), _ = jax.lax.scan(body, (x, aux), p["blocks"])
        for i, d in enumerate(self.suffix):
            x, aux = apply_layer_train(cfg, d, p["suffix"][str(i)], x, positions,
                                       ctx, aux, self.bidirectional)
        return x, aux

    def prefill(self, p: dict, x, positions, ctx=None, past=None, past_len=0):
        """``past`` (prefix-cache reuse): a cache-structured tree of this
        stack's prefix K/V at length ``past_len``; only the suffix in ``x``
        is computed and the emitted cache covers that suffix."""
        cfg = self.cfg
        aux = jnp.zeros((), jnp.float32)
        caches = {}
        if self.prefix:
            caches["prefix"] = {}
            for i, d in enumerate(self.prefix):
                x, c, aux = apply_layer_prefill(
                    cfg, d, p["prefix"][str(i)], x, positions, ctx, aux,
                    past=None if past is None else past["prefix"][str(i)],
                    past_len=past_len)
                caches["prefix"][str(i)] = c
        if self.reps:
            def body(carry, scanned):
                x, aux = carry
                bp, bpast = scanned if past is not None else (scanned, None)
                cs = {}
                for i, d in enumerate(self.cycle):
                    x, c, aux = apply_layer_prefill(
                        cfg, d, bp[str(i)], x, positions, ctx, aux,
                        past=None if bpast is None else bpast[str(i)],
                        past_len=past_len)
                    cs[str(i)] = c
                return (x, aux), cs
            body = cm.maybe_remat(body, cfg.remat_policy)
            scanned = (p["blocks"] if past is None
                       else (p["blocks"], past["blocks"]))
            (x, aux), caches["blocks"] = jax.lax.scan(body, (x, aux), scanned)
        if self.suffix:
            caches["suffix"] = {}
            for i, d in enumerate(self.suffix):
                x, c, aux = apply_layer_prefill(
                    cfg, d, p["suffix"][str(i)], x, positions, ctx, aux,
                    past=None if past is None else past["suffix"][str(i)],
                    past_len=past_len)
                caches["suffix"][str(i)] = c
        return x, caches, aux

    def decode(self, p: dict, x, caches: dict, pos, tables=None,
               page_size=None):
        cfg = self.cfg
        aux = jnp.zeros((), jnp.float32)
        new = {}
        if self.prefix:
            new["prefix"] = {}
            for i, d in enumerate(self.prefix):
                x, c, aux = apply_layer_decode(cfg, d, p["prefix"][str(i)], x,
                                               caches["prefix"][str(i)], pos, aux,
                                               tables=tables, page_size=page_size)
                new["prefix"][str(i)] = c
        if self.reps:
            def body(carry, scanned):
                x, aux = carry
                bp, bc = scanned
                ncs = {}
                for i, d in enumerate(self.cycle):
                    x, c, aux = apply_layer_decode(cfg, d, bp[str(i)], x,
                                                   bc[str(i)], pos, aux,
                                                   tables=tables,
                                                   page_size=page_size)
                    ncs[str(i)] = c
                return (x, aux), ncs
            (x, aux), new["blocks"] = jax.lax.scan(
                body, (x, aux), (p["blocks"], caches["blocks"]))
        if self.suffix:
            new["suffix"] = {}
            for i, d in enumerate(self.suffix):
                x, c, aux = apply_layer_decode(cfg, d, p["suffix"][str(i)], x,
                                               caches["suffix"][str(i)], pos, aux,
                                               tables=tables, page_size=page_size)
                new["suffix"][str(i)] = c
        return x, new, aux
