"""RWKV-6 "Finch" time-mix (arXiv:2404.05892) — data-dependent decay.

Recurrence per head (state S ∈ R^{hd×hd}, fp32):

    S_t = diag(w_t) · S_{t-1} + k_tᵀ v_t
    y_t = r_t · (S_{t-1} + diag(u) · k_tᵀ v_t)

with per-channel, per-token decay  w_t = exp(-exp(w0 + lora_w(x̃_t))) ∈ (0,1).

Training uses the *chunked* parallel form (chunk length ``CHUNK``): within a
chunk the pairwise decay exponent  cum_{t-1} − cum_j  (j < t) is materialized
explicitly — it is always ≤ 0, so ``exp`` never overflows; this is the
numerically-exact variant of the flash-linear-attention chunked algorithm and
is also the oracle for the Pallas kernel (``repro.kernels.rwkv6``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.ctx import constrain_qkv, constrain_residual
from repro.models import common as cm

CHUNK = 32
_MIX = 5  # w, k, v, r, g


def rwkv_specs(cfg) -> dict:
    d, h = cfg.d_model, cfg.num_heads
    hd = cfg.rwkv.head_dim
    dl, ml, gl = cfg.rwkv.decay_lora, cfg.rwkv.mix_lora, cfg.rwkv.gate_lora
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "mu_x": cm.ParamSpec((d,), ("embed",), jnp.float32, "small"),
        "mu_5": cm.ParamSpec((_MIX, d), (None, "embed"), jnp.float32, "small"),
        "tm_w1": cm.ParamSpec((d, _MIX * ml), ("embed", "lora"), dt),
        "tm_w2": cm.ParamSpec((_MIX, ml, d), (None, "lora", "embed"), dt, "small"),
        "w0": cm.ParamSpec((d,), ("embed",), jnp.float32, "decay"),
        "td_w1": cm.ParamSpec((d, dl), ("embed", "lora"), dt),
        "td_w2": cm.ParamSpec((dl, d), ("lora", "embed"), dt, "small"),
        "u": cm.ParamSpec((h, hd), ("heads", None), jnp.float32, "small"),
        "w_r": cm.ParamSpec((d, h, hd), ("embed", "heads", None), dt),
        "w_k": cm.ParamSpec((d, h, hd), ("embed", "heads", None), dt),
        "w_v": cm.ParamSpec((d, h, hd), ("embed", "heads", None), dt),
        "w_g": cm.ParamSpec((d, gl), ("embed", "lora"), dt),
        "w_g2": cm.ParamSpec((gl, h, hd), ("lora", "heads", None), dt),
        "ln_x": cm.ParamSpec((h, hd), ("heads", None), jnp.float32, "zeros"),
        "ln_x_b": cm.ParamSpec((h, hd), ("heads", None), jnp.float32, "zeros"),
        "w_o": cm.ParamSpec((h, hd, d), ("heads", None, "embed"), dt),
    }


def _projections(cfg, p, x, x_prev):
    """Token-shift mixing + r/k/v/g/decay projections.

    x, x_prev: (B, S, d).  Returns r,k,v,g: (B,S,H,hd); lw: (B,S,H,hd) fp32
    (log-decay, ≤ 0).
    """
    B, S, d = x.shape
    h, hd = cfg.num_heads, cfg.rwkv.head_dim
    sx = (x_prev - x).astype(x.dtype)
    xx = x + sx * p["mu_x"].astype(x.dtype)
    m = jnp.tanh(jnp.einsum("bsd,dl->bsl", xx, p["tm_w1"]))
    m = m.reshape(B, S, _MIX, -1)
    deltas = jnp.einsum("bsfl,fld->bsfd", m, p["tm_w2"])          # (B,S,5,d)
    mixed = x[:, :, None, :] + sx[:, :, None, :] * (
        p["mu_5"].astype(x.dtype)[None, None] + deltas)
    xw, xk, xv, xr, xg = [mixed[:, :, i] for i in range(_MIX)]

    r = constrain_qkv(jnp.einsum("bsd,dhk->bshk", xr, p["w_r"]))
    k = constrain_qkv(jnp.einsum("bsd,dhk->bshk", xk, p["w_k"]))
    v = constrain_qkv(jnp.einsum("bsd,dhk->bshk", xv, p["w_v"]))
    g = jax.nn.silu(jnp.einsum("bsl,lhk->bshk", jnp.tanh(
        jnp.einsum("bsd,dl->bsl", xg, p["w_g"])), p["w_g2"]))
    w_raw = p["w0"].astype(jnp.float32) + jnp.einsum(
        "bsl,ld->bsd", jnp.tanh(jnp.einsum("bsd,dl->bsl", xw, p["td_w1"])),
        p["td_w2"]).astype(jnp.float32)
    lw = -jnp.exp(w_raw).reshape(B, S, h, hd)                     # log w_t ≤ 0
    return r, k, v, g, lw


def _chunk_scan(r, k, v, lw, u, state):
    """Chunked linear recurrence.  r,k,v: (B,S,H,hd) compute dtype;
    lw: (B,S,H,hd) fp32; u: (H,hd); state: (B,H,hd,hd) fp32."""
    B, S, H, hd = r.shape
    C = CHUNK if S % CHUNK == 0 else (S if S < CHUNK else 1)
    n = S // C
    rf = r.astype(jnp.float32).reshape(B, n, C, H, hd).transpose(1, 0, 2, 3, 4)
    kf = k.astype(jnp.float32).reshape(B, n, C, H, hd).transpose(1, 0, 2, 3, 4)
    vf = v.astype(jnp.float32).reshape(B, n, C, H, hd).transpose(1, 0, 2, 3, 4)
    lwf = lw.reshape(B, n, C, H, hd).transpose(1, 0, 2, 3, 4)

    tri = jnp.tril(jnp.ones((C, C), jnp.bool_), k=-1)             # strict lower

    def body(S_c, blk):
        rc, kc, vc, lwc = blk                                     # (B,C,H,hd)
        cum = jnp.cumsum(lwc, axis=1)                             # inclusive
        # pairwise exponent cum_{t-1} - cum_j  (t > j): always ≤ 0
        expn = (cum - lwc)[:, :, None] - cum[:, None, :]          # (B,t,j,H,hd)
        expn = jnp.where(tri[None, :, :, None, None], expn, -jnp.inf)
        pair = jnp.exp(expn)
        A = jnp.einsum("bthd,btjhd,bjhd->bhtj", rc, pair, kc)
        diag = jnp.einsum("bthd,hd,bthd->bth", rc, u, kc)
        A = A + jnp.einsum("bth,tj->bhtj", diag, jnp.eye(C, dtype=jnp.float32))
        y = jnp.einsum("bhtj,bjhd->bthd", A, vc)
        # cross-chunk read: r_t decayed to chunk start
        y = y + jnp.einsum("bthd,bhde->bthe", rc * jnp.exp(cum - lwc), S_c)
        # state update
        dec_k = jnp.exp(cum[:, -1:, :, :] - cum)                  # ≤ 1
        S_n = S_c * jnp.exp(cum[:, -1])[:, :, :, None] + jnp.einsum(
            "bjhd,bjhe->bhde", kc * dec_k, vc)
        return S_n, y

    # recompute the pairwise-decay block in backward (it dwarfs r/k/v)
    body = jax.checkpoint(body)
    state, ys = jax.lax.scan(body, state, (rf, kf, vf, lwf))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd)
    return y.astype(r.dtype), state


def _readout(cfg, p, y, g, x_dtype):
    """Per-head groupnorm → gate → output projection."""
    yf = y.astype(jnp.float32)
    mu = jnp.mean(yf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(yf - mu), axis=-1, keepdims=True)
    yn = (yf - mu) * jax.lax.rsqrt(var + 64e-5)
    yn = yn * (1.0 + p["ln_x"]) + p["ln_x_b"]
    out = (yn.astype(x_dtype) * g.astype(x_dtype))
    y = jnp.einsum("bshk,hkd->bsd", out, p["w_o"]).astype(x_dtype)
    return constrain_residual(y) if y.ndim == 3 else y


def rwkv_time_mix(cfg, p: dict, x, x_prev=None, state=None,
                  want_state: bool = True):
    """Full-sequence time-mix. Returns (out, final_state, last_x).

    ``want_state=False`` (train path — the final state is discarded) allows
    routing through the Pallas chunked-recurrence kernel when enabled.
    """
    B, S, d = x.shape
    h, hd = cfg.num_heads, cfg.rwkv.head_dim
    if x_prev is None:
        x_prev_seq = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:  # continuing from a cached last token
        x_prev_seq = jnp.concatenate([x_prev[:, None, :], x[:, :-1]], axis=1)
    r, k, v, g, lw = _projections(cfg, p, x, x_prev_seq)
    if state is None:
        state = jnp.zeros((B, h, hd, hd), jnp.float32)
    use_kernel = (cfg.use_pallas and not want_state and S % CHUNK == 0
                  and x_prev is None)
    if use_kernel:
        from repro.kernels.rwkv6.ops import time_mix_scan

        y = time_mix_scan(r, k, v, lw, p["u"].astype(jnp.float32),
                          chunk=CHUNK,
                          interpret=jax.default_backend() != "tpu")
    else:
        y, state = _chunk_scan(r, k, v, lw, p["u"].astype(jnp.float32), state)
    return _readout(cfg, p, y, g, x.dtype), state, x[:, -1]


def rwkv_decode(cfg, p: dict, x1, state, x_prev):
    """Single-token decode. x1: (B,1,d); state: (B,H,hd,hd) fp32; x_prev: (B,d)."""
    B = x1.shape[0]
    h, hd = cfg.num_heads, cfg.rwkv.head_dim
    r, k, v, g, lw = _projections(cfg, p, x1, x_prev[:, None, :])
    rf, kf, vf = (t.astype(jnp.float32)[:, 0] for t in (r, k, v))  # (B,H,hd)
    w = jnp.exp(lw[:, 0])                                          # (B,H,hd)
    u = p["u"].astype(jnp.float32)
    kv = jnp.einsum("bhd,bhe->bhde", kf, vf)
    y = jnp.einsum("bhd,bhde->bhe", rf, state + u[None, :, :, None] * kv)
    state = state * w[..., None] + kv
    out = _readout(cfg, p, y[:, None].astype(x1.dtype), g, x1.dtype)
    return out, state, x1[:, 0]
