"""Model building blocks shared across architectures.

Parameter system
----------------
Models are pure-functional: a model definition produces a pytree of
:class:`ParamSpec` leaves (shape, dtype, *logical axes*, initializer).
``init_params`` materializes the tree; ``logical_axes`` extracts the parallel
axes tree which ``repro.distributed.sharding`` maps onto a mesh via a
:class:`~repro.distributed.sharding.ShardingRecipe`.

Logical axis names used throughout:

- ``"vocab"``   — embedding-table rows / logits dim  → tensor-parallel axis
- ``"embed"``   — d_model dim of weight matrices     → FSDP axis
- ``"heads"``   — attention heads                    → tensor-parallel axis
- ``"kv_heads"``— KV heads (GQA)                     → tensor-parallel axis
- ``"mlp"``     — FFN hidden dim                     → tensor-parallel axis
- ``"expert"``  — MoE expert index                   → expert-parallel axis
- ``"qkv"``, ``"lora"``, ``"conv"``, ``None``        — unsharded small dims
- ``"layers"``  — scan-stacked layer dim             — never sharded
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    dtype: Any = jnp.bfloat16
    init: str = "normal"             # normal | zeros | ones | decay | small
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_specs(tree):
    return jax.tree.leaves(tree, is_leaf=is_spec)


def _init_leaf(key, spec: ParamSpec):
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "decay":
        # log-decay init for recurrences: a in (0.9, 0.999)
        u = jax.random.uniform(key, spec.shape, jnp.float32, 0.9, 0.999)
        return jnp.log(-jnp.log(u)).astype(spec.dtype)
    fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
    std = spec.scale / math.sqrt(max(fan_in, 1))
    if spec.init == "small":
        std = 0.02 * spec.scale
    return (std * jax.random.normal(key, spec.shape, jnp.float32)).astype(spec.dtype)


def init_params(specs, seed: int = 0):
    """Materialize a ParamSpec pytree into arrays (deterministic per-leaf keys)."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    root = jax.random.PRNGKey(seed)
    keys = jax.random.split(root, len(leaves))
    vals = [_init_leaf(k, s) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(specs):
    """ShapeDtypeStruct tree for AOT lowering — never allocates."""
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs,
                        is_leaf=is_spec)


def logical_axes(specs):
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=is_spec)


def param_count(specs) -> int:
    return int(sum(np.prod(s.shape) for s in tree_specs(specs)))


# ---------------------------------------------------------------------------
# numerics


def rmsnorm(x, w, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


def layernorm(x, w, b=None, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps) * (1.0 + w.astype(jnp.float32))
    if b is not None:
        y = y + b.astype(jnp.float32)
    return y.astype(dt)


def norm_spec(cfg, dim: int, axes=("embed",)) -> dict:
    s = {"scale": ParamSpec((dim,), axes, jnp.float32, "zeros")}
    if cfg.norm == "layernorm":
        s["bias"] = ParamSpec((dim,), axes, jnp.float32, "zeros")
    return s


def apply_norm(cfg, p: dict, x):
    if cfg.norm == "layernorm":
        return layernorm(x, p["scale"], p.get("bias"))
    return rmsnorm(x, p["scale"])


def rope(x, positions, theta: float = 10000.0, rotary_dim: Optional[int] = None):
    """Rotary position embedding over the trailing head-dim.

    x: (..., seq, heads, head_dim) or (..., seq, head_dim); positions:
    (seq,) shared across the batch, or (batch, seq) when each row sits on
    its own timeline (continuous batching).
    """
    hd = x.shape[-1]
    rd = rotary_dim or hd
    half = rd // 2
    freq = (theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half))
    positions = jnp.atleast_1d(positions)
    ang = positions[..., None].astype(jnp.float32) * freq          # (..., seq, half)
    if x.ndim == 4:                                                # (B, S, H, hd)
        ang = ang[..., None, :]                                    # (..., S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:rd]
    xr = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    if rd < hd:
        xr = jnp.concatenate([xr, x[..., rd:]], axis=-1)
    return xr.astype(x.dtype)


def dense_spec(d_in: int, d_out: int, axes, dtype, bias: bool = False,
               bias_axis: Optional[str] = None, init: str = "normal",
               scale: float = 1.0) -> dict:
    s = {"kernel": ParamSpec((d_in, d_out), axes, dtype, init, scale)}
    if bias:
        s["bias"] = ParamSpec((d_out,), (bias_axis,), jnp.float32, "zeros")
    return s


def dense(p: dict, x, dims: str = "...a,ab->...b"):
    y = jnp.einsum(dims, x, p["kernel"])
    if "bias" in p:
        y = y + p["bias"].astype(y.dtype)
    return y.astype(x.dtype)


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


ACTIVATIONS: dict = {
    "gelu": gelu,
    "silu": jax.nn.silu,
    "squared_relu": lambda x: jnp.square(jax.nn.relu(x)),
    "relu": jax.nn.relu,
}


def remat_policy(name: str):
    """Map config remat names to jax checkpoint policies (hillclimb axis)."""
    cp = jax.checkpoint_policies
    return {
        "nothing": None,                              # no remat
        "dots": cp.checkpoint_dots,                   # save matmul outputs
        "dots_no_batch": cp.checkpoint_dots_with_no_batch_dims,
        "full": cp.nothing_saveable,                  # recompute everything
        # save the EP-exchanged buffers + expert-GEMM hidden so backward
        # neither re-runs the all_to_alls nor re-gathers expert weights
        "moe": cp.save_only_these_names("moe_bufe", "moe_h"),
    }[name]


def maybe_remat(fn, policy_name: str):
    if policy_name == "nothing":
        return fn
    return jax.checkpoint(fn, policy=remat_policy(policy_name))
