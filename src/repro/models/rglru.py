"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

    r_t = σ(W_a x_t + b_a)                  recurrence gate
    i_t = σ(W_x x_t + b_x)                  input gate
    a_t = exp(-c · softplus(Λ) ⊙ r_t)       per-channel decay
    h_t = a_t ⊙ h_{t-1} + √(1 − a_t²) ⊙ (i_t ⊙ x_t)

Block layout: linear-in (d→w) ∥ gelu gate branch, causal depthwise conv
(width 4), RG-LRU, gated multiply, linear-out (w→d).  The diagonal linear
recurrence is evaluated with ``jax.lax.associative_scan`` during training —
O(log S) depth — and one sequential step during decode (O(1) state: the
reason recurrentgemma-9b runs the long_500k cell).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.ctx import constrain_hidden, constrain_residual
from repro.models import common as cm


def rglru_specs(cfg) -> dict:
    d = cfg.d_model
    w = cfg.recurrent.lru_width
    cw = cfg.recurrent.conv_width
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "w_in": cm.ParamSpec((d, w), ("embed", "mlp"), dt),
        "w_gate_in": cm.ParamSpec((d, w), ("embed", "mlp"), dt),
        "conv_w": cm.ParamSpec((cw, w), ("conv", "mlp"), dt, "small"),
        "conv_b": cm.ParamSpec((w,), ("mlp",), jnp.float32, "zeros"),
        "lam": cm.ParamSpec((w,), ("mlp",), jnp.float32, "decay"),
        "w_a": cm.ParamSpec((w, w), ("mlp", "mlp"), dt, "small"),
        "b_a": cm.ParamSpec((w,), ("mlp",), jnp.float32, "zeros"),
        "w_x": cm.ParamSpec((w, w), ("mlp", "mlp"), dt, "small"),
        "b_x": cm.ParamSpec((w,), ("mlp",), jnp.float32, "zeros"),
        "w_out": cm.ParamSpec((w, d), ("mlp", "embed"), dt),
    }


def _gates(cfg, p, u):
    """u: (..., w) conv output → (log_a, b) of the recurrence h' = a·h + b."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(jnp.einsum("...w,wv->...v", u, p["w_a"]).astype(jnp.float32)
                       + p["b_a"])
    i = jax.nn.sigmoid(jnp.einsum("...w,wv->...v", u, p["w_x"]).astype(jnp.float32)
                       + p["b_x"])
    log_a = -cfg.recurrent.c * jax.nn.softplus(p["lam"]) * r      # ≤ 0
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * uf)
    return a, b


def _conv_train(p, x):
    """Causal depthwise conv via shifted adds. x: (B,S,w)."""
    cw = p["conv_w"].shape[0]
    y = x * p["conv_w"][cw - 1].astype(x.dtype)
    for i in range(1, cw):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, :-i]
        y = y + shifted * p["conv_w"][cw - 1 - i].astype(x.dtype)
    return y + p["conv_b"].astype(x.dtype)


def rglru_block(cfg, p: dict, x, h0=None, conv_state=None):
    """Full-sequence recurrent block. x: (B,S,d).

    Returns (out, (h_final, conv_tail)) — the state pair primes decode.
    """
    B, S, _ = x.shape
    u = constrain_hidden(jnp.einsum("bsd,dw->bsw", x, p["w_in"]))
    gate = constrain_hidden(cm.gelu(jnp.einsum("bsd,dw->bsw", x, p["w_gate_in"])))
    if conv_state is not None:  # continuation: prepend cached conv tail
        u_ext = jnp.concatenate([conv_state.astype(u.dtype), u], axis=1)
        c = _conv_train(p, u_ext)[:, conv_state.shape[1]:]
    else:
        c = _conv_train(p, u)
    a, b = _gates(cfg, p, c)

    if h0 is None:
        h0 = jnp.zeros((B, a.shape[-1]), jnp.float32)
    # prepend the carried state as step 0 with a=1 (identity), b=h0
    a_ext = jnp.concatenate([jnp.ones((B, 1, a.shape[-1]), jnp.float32), a], axis=1)
    b_ext = jnp.concatenate([h0[:, None, :], b], axis=1)

    W = a.shape[-1]
    if (cfg.use_pallas and h0 is not None and S % 64 == 0
            and W % min(128, W) == 0):
        # Pallas sequential-scan kernel; the carried state enters as b_0 of
        # a length-S+? recurrence — fold it into b instead: h_1 = a_1·h0 + b_1
        from repro.kernels.rglru.ops import linear_recurrence

        b_seeded = b.at[:, 0].add(a[:, 0] * h0)
        h = linear_recurrence(a, b_seeded, chunk=64, block_w=min(128, W),
                              interpret=jax.default_backend() != "tpu")
    else:
        def combine(lhs, rhs):
            a1, b1 = lhs
            a2, b2 = rhs
            return a1 * a2, a2 * b1 + b2

        _, h = jax.lax.associative_scan(combine, (a_ext, b_ext), axis=1)
        h = h[:, 1:]                                              # drop seed step
    out = constrain_residual(
        jnp.einsum("bsw,wd->bsd", (h.astype(x.dtype) * gate), p["w_out"]))
    cw = cfg.recurrent.conv_width
    conv_tail = u[:, -(cw - 1):].astype(jnp.float32)
    return out.astype(x.dtype), (h[:, -1], conv_tail)


def rglru_decode(cfg, p: dict, x1, h, conv_state):
    """One-token step. x1: (B,1,d); h: (B,w) fp32; conv_state: (B,cw-1,w)."""
    u = jnp.einsum("bsd,dw->bsw", x1, p["w_in"])                  # (B,1,w)
    gate = cm.gelu(jnp.einsum("bsd,dw->bsw", x1, p["w_gate_in"]))
    window = jnp.concatenate([conv_state.astype(u.dtype), u], axis=1)  # (B,cw,w)
    c = jnp.einsum("bcw,cw->bw", window, p["conv_w"]) + p["conv_b"].astype(u.dtype)
    a, b = _gates(cfg, p, c[:, None, :])
    h = (a[:, 0] * h + b[:, 0]).astype(jnp.float32)
    out = jnp.einsum("bw,wd->bd", h.astype(x1.dtype) * gate[:, 0], p["w_out"])
    return out[:, None].astype(x1.dtype), h, window[:, 1:].astype(jnp.float32)
