"""Dense feed-forward variants: SwiGLU, squared-ReLU, (gated-)GELU."""
from __future__ import annotations

import jax.numpy as jnp
import jax

from repro.distributed.ctx import constrain_hidden, constrain_residual
from repro.models import common as cm


def ffn_specs(cfg, d_ff=None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = jnp.dtype(cfg.param_dtype)
    gated = cfg.ffn_activation in ("swiglu", "gelu")  # gelu == GeGLU (gemma-style)
    s = {
        "w_up": cm.ParamSpec((d, f), ("embed", "mlp"), dt),
        "w_down": cm.ParamSpec((f, d), ("mlp", "embed"), dt),
    }
    if gated:
        s["w_gate"] = cm.ParamSpec((d, f), ("embed", "mlp"), dt)
    return s


def ffn(cfg, p: dict, x):
    from repro.distributed.sp_ffn import sp_ffn

    y = sp_ffn(cfg, p, x)    # explicit-collective Megatron/ZeRO-3 block
    if y is not None:
        return y
    up = jnp.einsum("...d,df->...f", x, p["w_up"])
    if "w_gate" in p:
        act = cm.ACTIVATIONS["silu" if cfg.ffn_activation == "swiglu" else "gelu"]
        h = act(jnp.einsum("...d,df->...f", x, p["w_gate"])) * up
    else:
        h = cm.ACTIVATIONS[cfg.ffn_activation](up)
    if h.ndim == 3:
        # Megatron-SP: hidden sharded on the tensor axis, full seq local —
        # weight grads are then computed in sharded form (no grad all-reduce)
        h = constrain_hidden(h)
    y = jnp.einsum("...f,fd->...d", h, p["w_down"]).astype(x.dtype)
    return constrain_residual(y) if y.ndim == 3 else y


def rwkv_channel_mix_specs(cfg) -> dict:
    """RWKV-6 channel mix: token-shift + squared-ReLU keyed by receptance."""
    d, f = cfg.d_model, cfg.d_ff
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "mu_k": cm.ParamSpec((d,), ("embed",), jnp.float32, "small"),
        "mu_r": cm.ParamSpec((d,), ("embed",), jnp.float32, "small"),
        "w_k": cm.ParamSpec((d, f), ("embed", "mlp"), dt),
        "w_v": cm.ParamSpec((f, d), ("mlp", "embed"), dt),
        "w_r": cm.ParamSpec((d, d), ("embed", "embed"), dt),
    }


def rwkv_channel_mix(cfg, p: dict, x, x_prev):
    """x: (B,S,d); x_prev: (B,S,d) token-shifted input (prev token)."""
    sx = x_prev - x
    kx = x + sx * p["mu_k"].astype(x.dtype)
    rx = x + sx * p["mu_r"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(jnp.einsum("...d,df->...f", kx, p["w_k"])))
    if k.ndim == 3:
        k = constrain_hidden(k)
    kv = jnp.einsum("...f,fd->...d", k, p["w_v"])
    if kv.ndim == 3:
        kv = constrain_residual(kv)
    r = jax.nn.sigmoid(jnp.einsum("...d,de->...e", rx, p["w_r"]).astype(jnp.float32))
    return (r.astype(x.dtype) * kv).astype(x.dtype)
