from repro.models import common  # noqa: F401
from repro.models.model import (  # noqa: F401
    build_decode_step,
    build_decode_step_paged,
    build_prefill_past_step,
    build_prefill_step,
    chunked_xent,
    count_params,
    decode_cache,
    decode_cache_paged,
    loss_fn,
    model_specs,
    paged_cache_flags,
    paged_support,
)
