"""Multi-head latent attention (DeepSeek-V2, arXiv:2405.04434).

Two execution paths:

- **train/prefill** — *decompressed*: up-project the latent to per-head
  K_nope/V, run standard chunked GQA-style attention over
  head_dim = qk_nope + qk_rope.
- **decode** — *absorbed*: the cache stores only the latent ``c_kv``
  (B, T, kv_lora=512) plus the shared rope key (B, T, 64); W_uk is absorbed
  into the query and W_uv into the output so no per-head K/V are ever
  materialized. This is the paper's 93% KV-cache reduction and the reason
  the decode_32k cell is memory-cheap despite 128 heads.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import common as cm
from repro.models.attention import chunked_attention, NEG_INF


def mla_specs(cfg) -> dict:
    a = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "w_dq": cm.ParamSpec((d, a.q_lora_rank), ("embed", "lora"), dt),
        "q_norm": cm.ParamSpec((a.q_lora_rank,), ("lora",), jnp.float32, "zeros"),
        "w_uq": cm.ParamSpec((a.q_lora_rank, h, a.qk_nope_head_dim + a.qk_rope_head_dim),
                             ("lora", "heads", None), dt),
        "w_dkv": cm.ParamSpec((d, a.kv_lora_rank + a.qk_rope_head_dim),
                              ("embed", None), dt),
        "kv_norm": cm.ParamSpec((a.kv_lora_rank,), (None,), jnp.float32, "zeros"),
        "w_uk": cm.ParamSpec((a.kv_lora_rank, h, a.qk_nope_head_dim),
                             ("lora", "heads", None), dt),
        "w_uv": cm.ParamSpec((a.kv_lora_rank, h, a.v_head_dim),
                             ("lora", "heads", None), dt),
        "wo": cm.ParamSpec((h, a.v_head_dim, d), ("heads", None, "embed"), dt),
    }


def _latent(cfg, p, x, positions):
    """Down-project to (c_kv, k_rope); rope applied to the shared rope key."""
    a = cfg.mla
    dkv = jnp.einsum("btd,dr->btr", x, p["w_dkv"])
    c_kv = cm.rmsnorm(dkv[..., :a.kv_lora_rank], p["kv_norm"])
    k_rope = dkv[..., a.kv_lora_rank:]                            # (B,T,rope_dim)
    k_rope = cm.rope(k_rope, positions, cfg.rope_theta)
    return c_kv, k_rope


def _queries(cfg, p, x, positions):
    a = cfg.mla
    q = jnp.einsum("bsd,dr->bsr", x, p["w_dq"])
    q = cm.rmsnorm(q, p["q_norm"])
    from repro.distributed.ctx import constrain_qkv

    q = constrain_qkv(jnp.einsum("bsr,rhk->bshk", q, p["w_uq"]))
    q_nope, q_rope = q[..., :a.qk_nope_head_dim], q[..., a.qk_nope_head_dim:]
    q_rope = cm.rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_attention(cfg, p: dict, x, positions):
    """Train-path MLA (decompressed)."""
    from repro.distributed.sp_block import sp_mla_block

    blk = sp_mla_block(cfg, p, x, positions, with_cache=False)
    if blk is not None:
        return blk[0]
    a = cfg.mla
    q_nope, q_rope = _queries(cfg, p, x, positions)
    c_kv, k_rope = _latent(cfg, p, x, positions)
    from repro.distributed.ctx import constrain_qkv

    k_nope = constrain_qkv(jnp.einsum("btr,rhk->bthk", c_kv, p["w_uk"]))
    v = constrain_qkv(jnp.einsum("btr,rhk->bthk", c_kv, p["w_uv"]))
    B, T = x.shape[0], x.shape[1]
    k_rope_h = jnp.broadcast_to(k_rope[:, :, None, :],
                                (B, T, cfg.num_heads, a.qk_rope_head_dim))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    # v_head_dim may differ from qk head_dim — pad V so chunked_attention's
    # uniform head_dim holds, slice after
    from repro.distributed.sp_attention import (maybe_sp_attention,
                                                 maybe_sp_attention_fused)

    qk_hd, v_hd = q.shape[-1], v.shape[-1]
    if v_hd < qk_hd:
        v = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, qk_hd - v_hd)))
    y = maybe_sp_attention_fused(q, k, v, p["wo"], causal=True,
                                 chunk=cfg.attn_chunk, v_head=a.v_head_dim)
    if y is not None:
        return y
    o = maybe_sp_attention(q, k, v, causal=True, chunk=cfg.attn_chunk)
    from repro.distributed.ctx import constrain_residual

    o = o[..., :a.v_head_dim]
    y = jnp.einsum("bshk,hkd->bsd", o, p["wo"]).astype(x.dtype)
    return constrain_residual(y)


def mla_prefill(cfg, p: dict, x, positions):
    from repro.distributed.sp_block import sp_mla_block

    blk = sp_mla_block(cfg, p, x, positions, with_cache=True)
    if blk is not None:
        return blk
    out = mla_attention(cfg, p, x, positions)
    c_kv, k_rope = _latent(cfg, p, x, positions)
    return out, {"c_kv": c_kv, "k_rope": k_rope}


def mla_decode(cfg, p: dict, x, cache: dict, pos):
    """Absorbed decode: scores/read run directly in the 512-d latent space.

    ``pos`` is a scalar or a (B,) vector of per-row absolute positions
    (continuous batching).
    """
    a = cfg.mla
    pos = jnp.asarray(pos, jnp.int32)
    per_row = pos.ndim == 1
    posv = pos[:, None] if per_row else jnp.full((1,), pos, jnp.int32)
    q_nope, q_rope = _queries(cfg, p, x, posv)                    # (B,1,H,·)
    c_new, kr_new = _latent(cfg, p, x, posv)
    if per_row:
        b = jnp.arange(x.shape[0])
        c_kv = cache["c_kv"].at[b, pos].set(c_new[:, 0].astype(cache["c_kv"].dtype))
        k_rope = cache["k_rope"].at[b, pos].set(kr_new[:, 0].astype(cache["k_rope"].dtype))
    else:
        c_kv = jax.lax.dynamic_update_slice_in_dim(
            cache["c_kv"], c_new.astype(cache["c_kv"].dtype), pos, axis=1)
        k_rope = jax.lax.dynamic_update_slice_in_dim(
            cache["k_rope"], kr_new.astype(cache["k_rope"].dtype), pos, axis=1)

    # absorb W_uk into q: (B,1,H,nope) x (r,H,nope) -> (B,1,H,r)
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, p["w_uk"])
    scores = jnp.einsum("bshr,btr->bsht", q_lat, c_kv).astype(jnp.float32)
    scores = scores + jnp.einsum("bshk,btk->bsht", q_rope, k_rope).astype(jnp.float32)
    scores = scores / np.sqrt(a.qk_nope_head_dim + a.qk_rope_head_dim)
    T = c_kv.shape[1]
    idx = jnp.arange(T, dtype=jnp.int32)
    valid = (idx[None, :] <= pos[:, None]) if per_row else (idx <= pos)
    valid = valid[:, None, None, :] if per_row else valid[None, None, None, :]
    scores = jnp.where(valid, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o_lat = jnp.einsum("bsht,btr->bshr", probs, c_kv)             # latent readout
    o = jnp.einsum("bshr,rhk->bshk", o_lat, p["w_uv"])            # absorb W_uv
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"]).astype(x.dtype)
    return out, {"c_kv": c_kv, "k_rope": k_rope}
