"""Multi-head latent attention (DeepSeek-V2, arXiv:2405.04434).

Two execution paths:

- **train/prefill** — *decompressed*: up-project the latent to per-head
  K_nope/V, run standard chunked GQA-style attention over
  head_dim = qk_nope + qk_rope.
- **decode** — *absorbed*: the cache stores only the latent ``c_kv``
  (B, T, kv_lora=512) plus the shared rope key (B, T, 64); W_uk is absorbed
  into the query and W_uv into the output so no per-head K/V are ever
  materialized. This is the paper's 93% KV-cache reduction and the reason
  the decode_32k cell is memory-cheap despite 128 heads.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import common as cm
from repro.models.attention import chunked_attention, NEG_INF


def mla_specs(cfg) -> dict:
    a = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "w_dq": cm.ParamSpec((d, a.q_lora_rank), ("embed", "lora"), dt),
        "q_norm": cm.ParamSpec((a.q_lora_rank,), ("lora",), jnp.float32, "zeros"),
        "w_uq": cm.ParamSpec((a.q_lora_rank, h, a.qk_nope_head_dim + a.qk_rope_head_dim),
                             ("lora", "heads", None), dt),
        "w_dkv": cm.ParamSpec((d, a.kv_lora_rank + a.qk_rope_head_dim),
                              ("embed", None), dt),
        "kv_norm": cm.ParamSpec((a.kv_lora_rank,), (None,), jnp.float32, "zeros"),
        "w_uk": cm.ParamSpec((a.kv_lora_rank, h, a.qk_nope_head_dim),
                             ("lora", "heads", None), dt),
        "w_uv": cm.ParamSpec((a.kv_lora_rank, h, a.v_head_dim),
                             ("lora", "heads", None), dt),
        "wo": cm.ParamSpec((h, a.v_head_dim, d), ("heads", None, "embed"), dt),
    }


def _latent(cfg, p, x, positions):
    """Down-project to (c_kv, k_rope); rope applied to the shared rope key."""
    a = cfg.mla
    dkv = jnp.einsum("btd,dr->btr", x, p["w_dkv"])
    c_kv = cm.rmsnorm(dkv[..., :a.kv_lora_rank], p["kv_norm"])
    k_rope = dkv[..., a.kv_lora_rank:]                            # (B,T,rope_dim)
    k_rope = cm.rope(k_rope, positions, cfg.rope_theta)
    return c_kv, k_rope


def _queries(cfg, p, x, positions):
    a = cfg.mla
    q = jnp.einsum("bsd,dr->bsr", x, p["w_dq"])
    q = cm.rmsnorm(q, p["q_norm"])
    from repro.distributed.ctx import constrain_qkv

    q = constrain_qkv(jnp.einsum("bsr,rhk->bshk", q, p["w_uq"]))
    q_nope, q_rope = q[..., :a.qk_nope_head_dim], q[..., a.qk_nope_head_dim:]
    q_rope = cm.rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_attention(cfg, p: dict, x, positions):
    """Train-path MLA (decompressed)."""
    from repro.distributed.sp_block import sp_mla_block

    blk = sp_mla_block(cfg, p, x, positions, with_cache=False)
    if blk is not None:
        return blk[0]
    a = cfg.mla
    q_nope, q_rope = _queries(cfg, p, x, positions)
    c_kv, k_rope = _latent(cfg, p, x, positions)
    from repro.distributed.ctx import constrain_qkv

    k_nope = constrain_qkv(jnp.einsum("btr,rhk->bthk", c_kv, p["w_uk"]))
    v = constrain_qkv(jnp.einsum("btr,rhk->bthk", c_kv, p["w_uv"]))
    B, T = x.shape[0], x.shape[1]
    k_rope_h = jnp.broadcast_to(k_rope[:, :, None, :],
                                (B, T, cfg.num_heads, a.qk_rope_head_dim))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    # v_head_dim may differ from qk head_dim — pad V so chunked_attention's
    # uniform head_dim holds, slice after
    from repro.distributed.sp_attention import (maybe_sp_attention,
                                                 maybe_sp_attention_fused)

    qk_hd, v_hd = q.shape[-1], v.shape[-1]
    if v_hd < qk_hd:
        v = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, qk_hd - v_hd)))
    y = maybe_sp_attention_fused(q, k, v, p["wo"], causal=True,
                                 chunk=cfg.attn_chunk, v_head=a.v_head_dim)
    if y is not None:
        return y
    o = maybe_sp_attention(q, k, v, causal=True, chunk=cfg.attn_chunk)
    from repro.distributed.ctx import constrain_residual

    o = o[..., :a.v_head_dim]
    y = jnp.einsum("bshk,hkd->bsd", o, p["wo"]).astype(x.dtype)
    return constrain_residual(y)


def mla_prefill(cfg, p: dict, x, positions, *, past: Optional[dict] = None,
                past_len: int = 0):
    """With ``past`` (latents of an already-cached prefix), only the suffix
    is computed on the decompressed path: suffix queries at absolute
    ``positions`` attend over concat(past, suffix) latents, and the
    returned cache covers the suffix only."""
    from repro.distributed.sp_block import sp_mla_block

    if past is not None:
        a = cfg.mla
        c_suf, kr_suf = _latent(cfg, p, x, positions)
        c_all = jnp.concatenate([past["c_kv"].astype(c_suf.dtype), c_suf],
                                axis=1)
        kr_all = jnp.concatenate([past["k_rope"].astype(kr_suf.dtype), kr_suf],
                                 axis=1)
        q_nope, q_rope = _queries(cfg, p, x, positions)
        k_nope = jnp.einsum("btr,rhk->bthk", c_all, p["w_uk"])
        v = jnp.einsum("btr,rhk->bthk", c_all, p["w_uv"])
        B, T = c_all.shape[0], c_all.shape[1]
        k_rope_h = jnp.broadcast_to(kr_all[:, :, None, :],
                                    (B, T, cfg.num_heads, a.qk_rope_head_dim))
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        k = jnp.concatenate([k_nope, k_rope_h], axis=-1)
        qk_hd, v_hd = q.shape[-1], v.shape[-1]
        if v_hd < qk_hd:
            v = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, qk_hd - v_hd)))
        o = chunked_attention(q, k, v, causal=True, chunk=cfg.attn_chunk,
                              q_offset=past_len)
        o = o[..., :a.v_head_dim]
        out = jnp.einsum("bshk,hkd->bsd", o, p["wo"]).astype(x.dtype)
        return out, {"c_kv": c_suf, "k_rope": kr_suf}

    blk = sp_mla_block(cfg, p, x, positions, with_cache=True)
    if blk is not None:
        return blk
    out = mla_attention(cfg, p, x, positions)
    c_kv, k_rope = _latent(cfg, p, x, positions)
    return out, {"c_kv": c_kv, "k_rope": k_rope}


def _absorbed_read(cfg, p: dict, x_dtype, q_nope, q_rope, c_kv, k_rope, valid):
    """Absorbed-path scores + latent readout shared by the contiguous and
    paged decode variants.  valid: bool mask broadcastable to (B,1,H,T)."""
    a = cfg.mla
    # absorb W_uk into q: (B,1,H,nope) x (r,H,nope) -> (B,1,H,r)
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, p["w_uk"])
    scores = jnp.einsum("bshr,btr->bsht", q_lat, c_kv).astype(jnp.float32)
    scores = scores + jnp.einsum("bshk,btk->bsht", q_rope,
                                 k_rope).astype(jnp.float32)
    scores = scores / np.sqrt(a.qk_nope_head_dim + a.qk_rope_head_dim)
    scores = jnp.where(valid, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x_dtype)
    o_lat = jnp.einsum("bsht,btr->bshr", probs, c_kv)             # latent readout
    o = jnp.einsum("bshr,rhk->bshk", o_lat, p["w_uv"])            # absorb W_uv
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"]).astype(x_dtype)


def mla_decode(cfg, p: dict, x, cache: dict, pos):
    """Absorbed decode: scores/read run directly in the 512-d latent space.

    ``pos`` is a scalar or a (B,) vector of per-row absolute positions
    (continuous batching).
    """
    pos = jnp.asarray(pos, jnp.int32)
    per_row = pos.ndim == 1
    posv = pos[:, None] if per_row else jnp.full((1,), pos, jnp.int32)
    q_nope, q_rope = _queries(cfg, p, x, posv)                    # (B,1,H,·)
    c_new, kr_new = _latent(cfg, p, x, posv)
    if per_row:
        b = jnp.arange(x.shape[0])
        c_kv = cache["c_kv"].at[b, pos].set(c_new[:, 0].astype(cache["c_kv"].dtype))
        k_rope = cache["k_rope"].at[b, pos].set(kr_new[:, 0].astype(cache["k_rope"].dtype))
    else:
        c_kv = jax.lax.dynamic_update_slice_in_dim(
            cache["c_kv"], c_new.astype(cache["c_kv"].dtype), pos, axis=1)
        k_rope = jax.lax.dynamic_update_slice_in_dim(
            cache["k_rope"], kr_new.astype(cache["k_rope"].dtype), pos, axis=1)

    T = c_kv.shape[1]
    idx = jnp.arange(T, dtype=jnp.int32)
    valid = (idx[None, :] <= pos[:, None]) if per_row else (idx <= pos)
    valid = valid[:, None, None, :] if per_row else valid[None, None, None, :]
    out = _absorbed_read(cfg, p, x.dtype, q_nope, q_rope, c_kv, k_rope, valid)
    return out, {"c_kv": c_kv, "k_rope": k_rope}


def mla_paged_decode(cfg, p: dict, x, cache: dict, pos, tables, *,
                     page_size: int):
    """Absorbed decode against a block-granular paged latent pool.

    cache c_kv: (num_pages+1, page_size, kv_lora); k_rope likewise — row 0
    is the null page.  tables: (B, max_pages) int32 page ids (0 where
    unallocated); pos: (B,) per-row absolute positions.  Same engine
    guarantees as ``paged_decode_attention``: valid positions are backed
    by real pages and the write page is private to its row.
    """
    a = cfg.mla
    pos = jnp.asarray(pos, jnp.int32)
    posv = pos[:, None]
    q_nope, q_rope = _queries(cfg, p, x, posv)                    # (B,1,H,·)
    c_new, kr_new = _latent(cfg, p, x, posv)
    B = x.shape[0]
    b = jnp.arange(B)
    pid = tables[b, pos // jnp.int32(page_size)]
    off = pos % jnp.int32(page_size)
    c_pool = cache["c_kv"].at[pid, off].set(
        c_new[:, 0].astype(cache["c_kv"].dtype))
    kr_pool = cache["k_rope"].at[pid, off].set(
        kr_new[:, 0].astype(cache["k_rope"].dtype))
    T = tables.shape[1] * page_size
    c_kv = c_pool[tables].reshape(B, T, a.kv_lora_rank)
    k_rope = kr_pool[tables].reshape(B, T, a.qk_rope_head_dim)
    idx = jnp.arange(T, dtype=jnp.int32)
    valid = (idx[None, :] <= pos[:, None])[:, None, None, :]
    out = _absorbed_read(cfg, p, x.dtype, q_nope, q_rope, c_kv, k_rope, valid)
    return out, {"c_kv": c_pool, "k_rope": kr_pool}
