"""Roofline report CLI: render the dry-run caches as tables.

    python -m repro.launch.report                    # optimized table
    python -m repro.launch.report --compare          # baseline vs optimized
    python -m repro.launch.report --cell deepseek-v2-236b__train_4k__pod256__baseline
"""
import argparse
import json
from pathlib import Path

ROOT = Path(__file__).resolve().parents[3] / "benchmarks" / "results"


def load(d):
    out = {}
    for f in sorted((ROOT / d).glob("*.json")):
        r = json.loads(f.read_text())
        if r.get("status") == "ok":
            out[r["cell"]] = r
    return out


def fmt_row(c):
    r, m = c["roofline"], c["memory"]
    fit = "Y" if m["fits"] else ("D" if m.get("fits_with_donation") else "N")
    return (f"{c['arch']:22s} {c['shape']:12s} {c['mesh']:8s} "
            f"{c['recipe']:10s} c={r['compute_s']:8.2f}s m={r['memory_s']:8.2f}s "
            f"x={r['collective_s']:8.2f}s {r['dominant']:10s} "
            f"frac={r['roofline_fraction']:.3f} live={m['peak_live_bytes']/1e9:5.1f}GB "
            f"fit={fit}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--compare", action="store_true")
    ap.add_argument("--cell", default=None)
    ap.add_argument("--shape", default=None)
    args = ap.parse_args()

    opt = load("dryrun")
    if args.cell:
        print(json.dumps(opt.get(args.cell) or
                         load("dryrun_baseline").get(args.cell), indent=2))
        return
    if args.compare:
        base = load("dryrun_baseline")
        print(f"{'cell':64s} {'frac(base)':>10s} {'frac(opt)':>10s} "
              f"{'coll(base)':>11s} {'coll(opt)':>10s}")
        for cid, o in sorted(opt.items()):
            b = base.get(cid)
            if b is None:
                continue
            print(f"{cid:64s} {b['roofline']['roofline_fraction']:10.3f} "
                  f"{o['roofline']['roofline_fraction']:10.3f} "
                  f"{b['roofline']['collective_s']:10.2f}s "
                  f"{o['roofline']['collective_s']:9.2f}s")
        return
    for cid, c in sorted(opt.items(),
                         key=lambda kv: (kv[1]["shape"], kv[1]["arch"])):
        if args.shape and c["shape"] != args.shape:
            continue
        print(fmt_row(c))


if __name__ == "__main__":
    main()
