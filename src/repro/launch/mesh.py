"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state.  The single-pod mesh is 16×16 =
256 chips (one v5e pod); the multi-pod mesh is 2×16×16 = 512 chips with the
leading "pod" axis mapping to the inter-pod DCI domain.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    ndev = 1
    for s in shape:
        ndev *= s
    devices = jax.devices()
    if len(devices) < ndev:
        raise RuntimeError(
            f"need {ndev} devices for mesh {shape}; found {len(devices)}. "
            "The dry-run launcher must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "any jax import (see launch/dryrun.py).")
    return jax.make_mesh(shape, axes, devices=devices[:ndev],
                         axis_types=(jax.sharding.AxisType.Auto,) * len(shape))


def make_smoke_mesh(shape=(1, 1), axes=("data", "model")):
    """A 1×1 mesh over the single real CPU device (tests/benches)."""
    return jax.make_mesh(shape, axes, devices=jax.devices()[:1],
                         axis_types=(jax.sharding.AxisType.Auto,) * len(shape))
