"""ShapeDtypeStruct input builders for every (arch × shape) cell.

Everything here is allocation-free: weak-type-correct ShapeDtypeStructs with
NamedShardings attached, ready for ``jax.jit(...).lower()``.  The modality
frontends are stubs per the assignment: whisper gets precomputed frame
embeddings, llama-vision gets precomputed patch embeddings.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig, ShapeSpec
from repro.distributed.sharding import (ShardingRecipe, batch_sharding,
                                        cache_shardings, param_shardings)
from repro.models import decode_cache
from repro.models.model import model_specs
from repro.training.train_step import TrainState, train_state_specs


def _sds(shape, dtype, sharding):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def batch_specs(cfg: ArchConfig, shape: ShapeSpec, mesh, recipe: ShardingRecipe,
                include_labels: bool) -> Dict:
    B, S = shape.global_batch, shape.seq_len
    tok_sh = batch_sharding(mesh, recipe, 2, seq_axis=1, shape=(B, S))
    out = {"tokens": _sds((B, S), jnp.int32, tok_sh)}
    if include_labels:
        out["labels"] = _sds((B, S), jnp.int32, tok_sh)
    if cfg.family == "encdec":
        out["frames"] = _sds((B, cfg.encoder_frames, cfg.d_model),
                             jnp.dtype(cfg.param_dtype),
                             batch_sharding(mesh, recipe, 3, shape=(B, 0, 0)))
    if cfg.family == "vision":
        out["image_embeds"] = _sds((B, cfg.num_image_tokens, cfg.d_model),
                                   jnp.dtype(cfg.param_dtype),
                                   batch_sharding(mesh, recipe, 3, shape=(B, 0, 0)))
    return out


def state_specs(cfg: ArchConfig, mesh, recipe: ShardingRecipe) -> TrainState:
    """Abstract TrainState with shardings attached."""
    from repro.models import common as cm
    from repro.distributed.sharding import spec_for_axes
    from jax.sharding import NamedSharding

    def to_sds(s):
        sh = NamedSharding(mesh, spec_for_axes(s.axes, recipe, mesh, s.shape))
        return _sds(s.shape, s.dtype, sh)

    return jax.tree.map(to_sds, train_state_specs(cfg), is_leaf=cm.is_spec)


def param_specs_only(cfg: ArchConfig, mesh, recipe: ShardingRecipe):
    from repro.models import common as cm
    from repro.distributed.sharding import spec_for_axes
    from jax.sharding import NamedSharding

    def to_sds(s):
        sh = NamedSharding(mesh, spec_for_axes(s.axes, recipe, mesh, s.shape))
        return _sds(s.shape, s.dtype, sh)

    return jax.tree.map(to_sds, model_specs(cfg), is_leaf=cm.is_spec)


def decode_cache_specs(cfg: ArchConfig, shape: ShapeSpec, mesh,
                       recipe: ShardingRecipe):
    cache = decode_cache(cfg, shape.global_batch, shape.seq_len, abstract=True)
    shardings = cache_shardings(cache, recipe, mesh)
    return jax.tree.map(lambda c, s: _sds(c.shape, c.dtype, s), cache, shardings)


def input_specs(cfg: ArchConfig, shape: ShapeSpec, mesh, recipe: ShardingRecipe):
    """Full argument tuple specs for the step function of this cell."""
    if shape.kind == "train":
        return {
            "state": state_specs(cfg, mesh, recipe),
            "batch": batch_specs(cfg, shape, mesh, recipe, include_labels=True),
        }
    if shape.kind == "prefill":
        return {
            "params": param_specs_only(cfg, mesh, recipe),
            "batch": batch_specs(cfg, shape, mesh, recipe, include_labels=False),
        }
    # decode
    return {
        "params": param_specs_only(cfg, mesh, recipe),
        "cache": decode_cache_specs(cfg, shape, mesh, recipe),
        "token": _sds((shape.global_batch, 1), jnp.int32,
                      batch_sharding(mesh, recipe, 2,
                                     shape=(shape.global_batch, 1))),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
