# NOTE: do not import repro.launch.dryrun here — it sets XLA_FLAGS at import
# time and must only be imported by the dry-run entrypoint itself.
from repro.launch.mesh import make_production_mesh, make_smoke_mesh  # noqa: F401
