"""Production training launcher.

    python -m repro.launch.train --arch qwen2.5-32b --steps 100 \
        [--multi-pod] [--recipe baseline] [--ckpt-dir /tmp/ckpt] [--smoke]

On a real TPU pod this builds the production mesh, shards the train state
per the recipe, and runs the same `build_train_step` the dry-run compiles.
With ``--smoke`` (or on a CPU host) it runs the reduced same-family config
on a 1×1 mesh — the code path is identical, only the mesh and config size
change.

Fault tolerance: checkpoints every ``--ckpt-every`` steps (async, atomic,
retained K=3); on restart with ``--resume`` the data pipeline fast-forwards
so no batch repeats. For multi-slice orchestration (straggler mitigation,
failover) use ``repro.training.runner.FleetRunner`` — see
examples/orchestrated_training.py.
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.distributed.ctx import sharding_ctx
from repro.distributed.sharding import RECIPES, param_shardings
from repro.launch.mesh import make_production_mesh, make_smoke_mesh
from repro.models import count_params
from repro.training import AdamWConfig, build_train_step, init_train_state
from repro.training.checkpoint import CheckpointManager
from repro.training.data import PrefetchIterator, SyntheticTokenDataset


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--recipe", default="baseline", choices=sorted(RECIPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config on a 1x1 mesh (CPU-friendly)")
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    smoke = args.smoke or jax.default_backend() == "cpu"
    cfg = get_config(args.arch)
    if smoke:
        cfg = reduced(cfg)
        mesh = make_smoke_mesh()
        batch_size = args.batch or 4
        seq = args.seq or 128
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        batch_size = args.batch or 256
        seq = args.seq or 4096
    recipe = RECIPES[args.recipe]
    print(f"arch={cfg.name} params={count_params(cfg)/1e9:.2f}B "
          f"mesh={dict(mesh.shape)} recipe={recipe.name} smoke={smoke}")

    data = SyntheticTokenDataset(cfg.vocab_size, seq, batch_size)
    ckpt = (CheckpointManager(args.ckpt_dir, keep=3, async_save=True)
            if args.ckpt_dir else None)

    with mesh, sharding_ctx(mesh, recipe):
        state = init_train_state(cfg)
        if not smoke:
            from repro.launch.specs import state_specs
            shardings = state_specs(cfg, mesh, recipe)
            state = jax.device_put(
                state, jax.tree.map(lambda s: s.sharding, shardings))
        step_fn = jax.jit(build_train_step(cfg, AdamWConfig(lr=args.lr)),
                          donate_argnums=0)
        start = 0
        if args.resume and ckpt is not None and ckpt.latest_step() is not None:
            state, meta = ckpt.restore(state)
            data.load_state_dict(meta["data"])
            start = meta["step"]
            print(f"resumed at step {start}")

        it = PrefetchIterator(iter(data))
        t0 = time.time()
        for i, batch in zip(range(start, args.steps), it):
            state, metrics = step_fn(
                state, {k: jnp.asarray(v) for k, v in batch.items()})
            if i % 10 == 0 or i == args.steps - 1:
                tps = (i - start + 1) * batch_size * seq / (time.time() - t0)
                print(f"step {i:5d} loss={float(metrics['loss']):.4f} "
                      f"gnorm={float(metrics['grad_norm']):.2f} "
                      f"tok/s={tps:,.0f}", flush=True)
            if ckpt is not None and i and i % args.ckpt_every == 0:
                ckpt.save(i, state, {"data": data.state_dict(), "step": i})
        if ckpt is not None:
            ckpt.save(args.steps, state,
                      {"data": data.state_dict(), "step": args.steps})
            ckpt.wait()
        it.close()


if __name__ == "__main__":
    main()
