import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT lower + compile every (arch × shape × mesh) cell.

For each cell this driver:
1. builds the production mesh (16×16 single-pod / 2×16×16 multi-pod),
2. lowers the cell's step function (train / prefill / decode) with sharded
   ShapeDtypeStruct inputs — no allocation ever happens,
3. compiles, proving the sharding/collective configuration is coherent,
4. records ``memory_analysis()`` (bytes/device — proves HBM fit),
   ``cost_analysis()`` (FLOPs/bytes for §Roofline), and the collective
   traffic parsed from the compiled HLO,
5. writes a JSON record to ``benchmarks/results/dryrun/`` (cells are cached;
   re-runs skip completed cells unless --force).

Usage:
    python -m repro.launch.dryrun --arch qwen2.5-32b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--recipe baseline]
    python -m repro.launch.dryrun --all --both-meshes
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config, list_archs, supports_shape
from repro.distributed.sharding import RECIPES
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs
from repro.models import build_decode_step, build_prefill_step, count_params
from repro.roofline.analysis import HW, model_flops, roofline_terms
from repro.roofline.hlo import analyze
from repro.training.train_step import build_train_step

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"


def cell_id(arch: str, shape: str, multi_pod: bool, recipe: str,
            overrides_tag: str = "") -> str:
    mesh_tag = "pod512" if multi_pod else "pod256"
    tag = f"__{overrides_tag}" if overrides_tag else ""
    return f"{arch}__{shape}__{mesh_tag}__{recipe}{tag}"


def _lower_cell(cfg, shape, mesh, recipe):
    from repro.distributed.ctx import sharding_ctx
    from repro.distributed.sharding import for_decode

    if shape.kind == "decode":
        recipe = for_decode(recipe)
    specs = input_specs(cfg, shape, mesh, recipe)
    if shape.kind == "train":
        step = build_train_step(cfg)
        args = (specs["state"], specs["batch"])
        donate = (0,)
    elif shape.kind == "prefill":
        step = build_prefill_step(cfg)
        args = (specs["params"], specs["batch"])
        donate = ()
    else:
        step = build_decode_step(cfg)
        args = (specs["params"], specs["cache"], specs["token"], specs["pos"])
        donate = (1,)  # cache is updated in place
    with mesh, sharding_ctx(mesh, recipe):
        lowered = jax.jit(step, donate_argnums=donate).lower(*args)
        compiled = lowered.compile()
    return lowered, compiled


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             recipe_name: str = "baseline", overrides: dict = None,
             overrides_tag: str = "", force: bool = False,
             results_dir: Path = RESULTS_DIR) -> dict:
    results_dir.mkdir(parents=True, exist_ok=True)
    cid = cell_id(arch, shape_name, multi_pod, recipe_name, overrides_tag)
    out_path = results_dir / f"{cid}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    ok, why = supports_shape(cfg, shape)
    record = {
        "cell": cid, "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": 512 if multi_pod else 256,
        "recipe": recipe_name, "overrides": overrides or {},
        "kind": shape.kind,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
    }
    if not ok:
        record.update({"status": "skipped", "reason": why})
        out_path.write_text(json.dumps(record, indent=2))
        return record

    recipe = RECIPES[recipe_name]
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        lowered, compiled = _lower_cell(cfg, shape, mesh, recipe)
    except Exception as e:  # a failing cell is a bug — record it loudly
        record.update({"status": "failed", "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-4000:]})
        out_path.write_text(json.dumps(record, indent=2))
        return record
    compile_s = time.time() - t0

    ca = compiled.cost_analysis() or {}
    ma = compiled.memory_analysis()
    # loop-aware analyzer: XLA cost_analysis counts while bodies once, so
    # scans (layers × microbatches × attention blocks) would be undercounted
    la = analyze(compiled.as_text())
    coll = la["collectives"]

    flops = la["flops"]
    hbm_bytes = la["bytes"]
    terms = roofline_terms(flops, hbm_bytes, coll["total"])

    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    n_active = count_params(cfg, active_only=True, include_embed=False)
    mf = model_flops(n_active, tokens, "train" if shape.kind == "train" else "serve")
    chips = record["chips"]
    mf_per_dev = mf / chips

    record.update({
        "status": "ok",
        "compile_seconds": round(compile_s, 2),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_live_bytes": ma.argument_size_in_bytes + ma.temp_size_in_bytes
                               + ma.output_size_in_bytes - ma.alias_size_in_bytes,
            "hbm_budget_bytes": int(HW.hbm_bytes),
            "fits": (ma.argument_size_in_bytes + ma.temp_size_in_bytes
                     + ma.output_size_in_bytes - ma.alias_size_in_bytes)
                    <= HW.hbm_bytes,
            # the CPU PjRt client ignores donate_argnums (alias bytes = 0);
            # on the TPU target the donated state aliases its output, so the
            # realistic criterion discounts the output buffer
            "fits_with_donation": (ma.argument_size_in_bytes
                                   + ma.temp_size_in_bytes
                                   - ma.alias_size_in_bytes) <= HW.hbm_bytes,
        },
        "cost": {
            "flops_per_device": flops,
            "hbm_bytes_per_device": hbm_bytes,
            "transcendentals": la["transcendentals"],
            # XLA's own numbers (while bodies counted once) for provenance
            "xla_flops_per_iter": float(ca.get("flops", 0.0)),
            "xla_bytes_per_iter": float(ca.get("bytes accessed", 0.0)),
        },
        "collectives": coll,
        "roofline": terms,
        "model_flops": {
            "n_active_params": n_active,
            "tokens": tokens,
            "model_flops_total": mf,
            "model_flops_per_device": mf_per_dev,
            "useful_ratio": (mf_per_dev / flops) if flops else 0.0,
        },
    })
    out_path.write_text(json.dumps(record, indent=2))
    return record


def _fmt(rec: dict) -> str:
    if rec["status"] == "skipped":
        return f"{rec['cell']:70s} SKIP ({rec['reason'][:60]})"
    if rec["status"] == "failed":
        return f"{rec['cell']:70s} FAIL {rec['error'][:90]}"
    r = rec["roofline"]
    m = rec["memory"]
    return (f"{rec['cell']:70s} ok c={r['compute_s']*1e3:9.2f}ms "
            f"m={r['memory_s']*1e3:9.2f}ms x={r['collective_s']*1e3:9.2f}ms "
            f"dom={r['dominant']:10s} frac={r['roofline_fraction']:.3f} "
            f"live={m['peak_live_bytes']/1e9:6.2f}GB fit={m['fits']} "
            f"compile={rec['compile_seconds']:.0f}s")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--recipe", default="baseline")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    if not (args.all or args.arch):
        ap.error("pass --all or --arch")

    n_ok = n_skip = n_fail = 0
    for mp in meshes:
        for arch in archs:
            for shape in shapes:
                rec = run_cell(arch, shape, multi_pod=mp,
                               recipe_name=args.recipe, force=args.force)
                print(_fmt(rec), flush=True)
                n_ok += rec["status"] == "ok"
                n_skip += rec["status"] == "skipped"
                n_fail += rec["status"] == "failed"
    print(f"\ndone: {n_ok} ok, {n_skip} skipped, {n_fail} failed")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
