"""Trace-time sharding context for activation constraints.

GSPMD propagation alone chooses bad activation shardings at these scales
(observed: batch replicated, d_model sharded — 114 TB/device live).  The
model code therefore pins the residual-stream sharding at layer boundaries
via :func:`constrain`, which resolves logical axes against the *ambient*
(mesh, recipe) installed by the step builder during lowering.  Outside a
context (unit tests on one device) it is a no-op.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Tuple

import jax
from jax.sharding import NamedSharding

_tls = threading.local()


def current() -> Optional[Tuple]:
    return getattr(_tls, "ctx", None)


@contextlib.contextmanager
def sharding_ctx(mesh, recipe):
    prev = current()
    _tls.ctx = (mesh, recipe)
    try:
        yield
    finally:
        _tls.ctx = prev


def constrain(x, axes):
    """Pin logical axes onto x if a sharding context is active."""
    ctx = current()
    if ctx is None:
        return x
    mesh, recipe = ctx
    from repro.distributed.sharding import spec_for_axes

    spec = spec_for_axes(axes, recipe, mesh, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def heads_shardable(n_heads: int) -> bool:
    """True if the ambient recipe can shard ``n_heads`` on a tensor axis."""
    c = current()
    if c is None:
        return False
    mesh, recipe = c
    return recipe.resolve("heads", mesh, set(), n_heads) is not None


def constrain_qkv(x):
    """Megatron-SP projection constraint for (B, S, H, hd) tensors.

    Heads-sharded when the head count divides the tensor axis (activations
    gathered over seq, weight grads computed locally sharded — no model-axis
    grad all-reduce); otherwise keep the sequence sharded and let
    sp_attention's seq variant handle the core.
    """
    if heads_shardable(x.shape[2]):
        return constrain(x, ("batch", None, "heads", None))
    return constrain(x, ("batch", "act_seq", None, None))


def constrain_hidden(x):
    """FFN hidden (B, S, F): shard F on the tensor axis, gather seq."""
    return constrain(x, ("batch", None, "mlp"))


def constrain_residual(x):
    """Layer output back to the sequence-parallel residual layout — GSPMD
    lowers the partial-sum + constraint into a reduce-scatter (Megatron ḡ)."""
    return constrain(x, ("batch", "act_seq", None))


def constrain_cache(cache: dict) -> dict:
    """Pin decode-cache leaves (kv_heads-before-seq priority resolution)."""
    ctx = current()
    if ctx is None:
        return cache
    mesh, recipe = ctx
    from repro.distributed.sharding import cache_spec

    out = {}
    for name, x in cache.items():
        spec = cache_spec(name, x.shape, recipe, mesh)
        out[name] = jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    return out
