"""Whole-block sequence-parallel attention via shard_map (H2c/H3a).

Measured (EXPERIMENTS.md §Perf): even with the attention *core* in
shard_map, the q/kv projections outside it still make GSPMD gather x to
full sequence and then all-reduce full-size dx in backward (deepseek:
~30% of collective traffic; same pattern in every heads-sharded arch).

Fix: the entire block runs inside one shard_map —

    xg   = all_gather(x, seq_ax)                 [dual: psum_scatter dx]
    w*   = all_gather(w, fsdp_ax)                [dual: ZeRO-3 grad RS]
    q/k/v, RoPE, blocked attention  — all local to the rank's heads
    y    = psum_scatter(o @ wo, seq_ax)          [dual: all_gather dy]

Exactly one activation gather and one activation scatter per layer; weight
gradients never leave their shard layout.  The GQA variant also returns the
rank-local K/V slice so prefill caches stay sequence-sharded.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed import ctx as dctx
from repro.distributed.sp_ffn import _gather_weight
from repro.models import common as cm


def _env(x_shape, h, k):
    c = dctx.current()
    if c is None:
        return None
    mesh, recipe = c
    B, S, d = x_shape
    used: set = set()
    b_axes = recipe.resolve("batch", mesh, used, B)
    s_ax = recipe.resolve("act_seq", mesh, set(used), S)
    h_axes = recipe.resolve("heads", mesh, set(used), h)
    if not isinstance(s_ax, str) or h_axes is None or S % mesh.shape[s_ax]:
        return None
    tp = mesh.shape[s_ax]
    if h % tp:
        return None
    wq_used = set(h_axes if isinstance(h_axes, tuple) else (h_axes,))
    fsdp = recipe.resolve("embed", mesh, wq_used, d)
    kv_sharded = k % tp == 0
    G = h // k
    if not kv_sharded and not ((h // tp) <= G and G % (h // tp) == 0):
        return None
    return mesh, recipe, b_axes, s_ax, h_axes, fsdp, tp, kv_sharded


def sp_gqa_block(cfg, p: dict, x, positions, *, causal: bool,
                 window: Optional[int], with_cache: bool):
    """Full GQA block under shard_map. Returns (y, cache|None) or None."""
    env = _env(x.shape, cfg.num_heads, cfg.num_kv_heads)
    if env is None or cfg.family == "encdec":
        return None
    mesh, recipe, b_axes, s_ax, h_axes, fsdp, tp, kv_sharded = env
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    G = H // K
    from repro.models.attention import chunked_attention

    has_bias = "bq" in p

    def body(xl, pos, wq, wk, wv, wo, *bias):
        xg = jax.lax.all_gather(xl, s_ax, axis=1, tiled=True)   # (B_loc,S,d)
        wq_f = _gather_weight(wq, fsdp, 0)
        wk_f = _gather_weight(wk, fsdp, 0)
        wv_f = _gather_weight(wv, fsdp, 0)
        q = jnp.einsum("bsd,dhk->bshk", xg, wq_f)               # local heads
        kk = jnp.einsum("btd,dgk->btgk", xg, wk_f)
        vv = jnp.einsum("btd,dgk->btgk", xg, wv_f)
        if has_bias:
            bq, bk, bv = bias
            q = q + bq.astype(q.dtype)
            kk = kk + bk.astype(kk.dtype)
            vv = vv + bv.astype(vv.dtype)
        q = cm.rope(q, pos, cfg.rope_theta)
        kk_r = cm.rope(kk, pos, cfg.rope_theta)
        if kv_sharded:
            kg, vg = kk_r, vv
        else:
            r = jax.lax.axis_index(h_axes)
            group = (r * (H // tp)) // G
            kg = jax.lax.dynamic_slice_in_dim(kk_r, group, 1, axis=2)
            vg = jax.lax.dynamic_slice_in_dim(vv, group, 1, axis=2)
        o = chunked_attention(q, kg, vg, causal=causal, window=window,
                              chunk=cfg.attn_chunk)
        y_part = jnp.einsum("bshk,hkd->bsd", o, wo).astype(xl.dtype)
        y = jax.lax.psum_scatter(y_part, s_ax, scatter_dimension=1,
                                 tiled=True)
        if not with_cache:
            return y
        # rank-local seq slice of the (replicated or head-sharded) K/V
        rs = jax.lax.axis_index(s_ax)
        S_loc = xl.shape[1]
        k_loc = jax.lax.dynamic_slice_in_dim(kk_r, rs * S_loc, S_loc, axis=1)
        v_loc = jax.lax.dynamic_slice_in_dim(vv, rs * S_loc, S_loc, axis=1)
        if kv_sharded:  # heads are rank-local: re-gather heads for the cache
            k_loc = jax.lax.all_gather(k_loc, h_axes, axis=2, tiled=True)
            v_loc = jax.lax.all_gather(v_loc, h_axes, axis=2, tiled=True)
        return y, k_loc, v_loc

    kv_h_spec = h_axes if kv_sharded else None
    in_specs = [P(b_axes, s_ax, None), P(None),
                P(fsdp, h_axes, None), P(fsdp, kv_h_spec, None),
                P(fsdp, kv_h_spec, None), P(h_axes, None, None)]
    args = [x, positions, p["wq"], p["wk"], p["wv"], p["wo"]]
    if has_bias:
        in_specs += [P(h_axes, None), P(kv_h_spec, None), P(kv_h_spec, None)]
        args += [p["bq"], p["bk"], p["bv"]]
    if with_cache:
        out_specs = (P(b_axes, s_ax, None),
                     P(b_axes, s_ax, None, None), P(b_axes, s_ax, None, None))
    else:
        out_specs = P(b_axes, s_ax, None)
    out = jax.shard_map(body, mesh=mesh, in_specs=tuple(in_specs),
                        out_specs=out_specs, check_vma=False)(*args)
    if with_cache:
        y, k_loc, v_loc = out
        return y, {"k": k_loc, "v": v_loc}
    return out, None


def sp_mla_block(cfg, p: dict, x, positions, *, with_cache: bool):
    """Full MLA block (DeepSeek-V2) under shard_map."""
    env = _env(x.shape, cfg.num_heads, cfg.num_heads)
    if env is None:
        return None
    mesh, recipe, b_axes, s_ax, h_axes, fsdp, tp, _ = env
    a = cfg.mla
    H = cfg.num_heads
    from repro.models.attention import chunked_attention

    def body(xl, pos, w_dq, qn, w_uq, w_dkv, kvn, w_uk, w_uv, wo):
        xg = jax.lax.all_gather(xl, s_ax, axis=1, tiled=True)
        # queries (heads local)
        ql = jnp.einsum("bsd,dr->bsr", xg, _gather_weight(w_dq, fsdp, 0))
        ql = cm.rmsnorm(ql, qn)
        q = jnp.einsum("bsr,rhk->bshk", ql, w_uq)
        q_nope = q[..., :a.qk_nope_head_dim]
        q_rope = cm.rope(q[..., a.qk_nope_head_dim:], pos, cfg.rope_theta)
        # latent (replicated across head ranks — it is tiny)
        dkv = jnp.einsum("btd,dr->btr", xg, _gather_weight(w_dkv, fsdp, 0))
        c_kv = cm.rmsnorm(dkv[..., :a.kv_lora_rank], kvn)
        k_rope = cm.rope(dkv[..., a.kv_lora_rank:], pos, cfg.rope_theta)
        # decompress local heads
        k_nope = jnp.einsum("btr,rhk->bthk", c_kv, w_uk)
        v = jnp.einsum("btr,rhk->bthk", c_kv, w_uv)
        B, T = xg.shape[0], xg.shape[1]
        h_loc = k_nope.shape[2]
        k_rope_h = jnp.broadcast_to(k_rope[:, :, None, :],
                                    (B, T, h_loc, a.qk_rope_head_dim))
        qf = jnp.concatenate([q_nope, q_rope], axis=-1)
        kf = jnp.concatenate([k_nope, k_rope_h], axis=-1)
        pad = qf.shape[-1] - v.shape[-1]
        vp = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, pad)))
        o = chunked_attention(qf, kf, vp, causal=True, chunk=cfg.attn_chunk)
        o = o[..., :a.v_head_dim]
        y_part = jnp.einsum("bshk,hkd->bsd", o, wo).astype(xl.dtype)
        y = jax.lax.psum_scatter(y_part, s_ax, scatter_dimension=1,
                                 tiled=True)
        if not with_cache:
            return y
        rs = jax.lax.axis_index(s_ax)
        S_loc = xl.shape[1]
        c_loc = jax.lax.dynamic_slice_in_dim(c_kv, rs * S_loc, S_loc, axis=1)
        kr_loc = jax.lax.dynamic_slice_in_dim(k_rope, rs * S_loc, S_loc,
                                              axis=1)
        return y, c_loc, kr_loc

    in_specs = (P(b_axes, s_ax, None), P(None),
                P(fsdp, None), P(None), P(None, h_axes, None),
                P(fsdp, None), P(None), P(None, h_axes, None),
                P(None, h_axes, None), P(h_axes, None, None))
    if with_cache:
        out_specs = (P(b_axes, s_ax, None),
                     P(b_axes, s_ax, None), P(b_axes, s_ax, None))
    else:
        out_specs = P(b_axes, s_ax, None)
    out = jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                        out_specs=out_specs, check_vma=False)(
        x, positions, p["w_dq"], p["q_norm"], p["w_uq"], p["w_dkv"],
        p["kv_norm"], p["w_uk"], p["w_uv"], p["wo"])
    if with_cache:
        y, c_loc, kr_loc = out
        return y, {"c_kv": c_loc, "k_rope": kr_loc}
    return out, None
