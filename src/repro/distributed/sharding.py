"""Logical-axis sharding rules → concrete NamedShardings.

A :class:`ShardingRecipe` maps *logical* parameter axes (DESIGN.md §5.5,
``repro.models.common`` docstring) onto mesh axes.  Recipes are first-class
objects because they double as *substrate capabilities* in the phys-MCP
control plane: each registered TPU pod-slice substrate is a
(mesh × recipe × precision) triple, and the matcher (Eq. 1) selects among
them using the roofline twin.  Hillclimbing in EXPERIMENTS.md §Perf is
expressed as recipe changes.

Baseline recipe (``"baseline"``):
- batch            → all data-like axes ("pod","data")
- heads/mlp/vocab/expert (tensor-/expert-parallel) → "model"
- embed (FSDP)     → "data"   (parameters ZeRO-3-sharded inside a pod,
                              replicated across pods; gradients all-reduce
                              over "pod")
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import common as cm


@dataclasses.dataclass(frozen=True)
class ShardingRecipe:
    name: str
    # logical axis -> tuple of mesh axis names (filtered by mesh presence)
    rules: Dict[str, Tuple[str, ...]]
    description: str = ""

    def resolve(self, logical: Optional[str], mesh: Mesh, used: set,
                dim: Optional[int] = None):
        """Mesh axes for one tensor dim.

        Greedy divisibility fallback: mesh axes whose size does not divide
        the dimension are dropped (e.g. qwen's 40 heads or GQA kv=8 over a
        16-way model axis → replicated). Input shardings must divide evenly
        under GSPMD; the redundant compute this produces is visible in the
        roofline table and is a hillclimb target.
        """
        if logical is None:
            return None
        want = self.rules.get(logical, ())
        axes = []
        prod = 1
        for a in want:
            if a not in mesh.axis_names or a in used:
                continue
            size = mesh.shape[a]
            if dim is not None and dim % (prod * size) != 0:
                continue
            axes.append(a)
            prod *= size
        if not axes:
            return None
        used.update(axes)
        return tuple(axes) if len(axes) > 1 else axes[0]


BASELINE = ShardingRecipe(
    name="baseline",
    rules={
        "batch": ("pod", "data"),
        "vocab": ("model",),
        "heads": ("model",),
        "kv_heads": ("model",),
        "mlp": ("model",),
        "expert": ("model",),
        "embed": ("data",),          # FSDP within pod
        "seq_kv": ("model",),        # KV-cache context sharding fallback
        "qkv_hd": ("model",),        # head_dim fallback for non-divisible heads
        "act_seq": ("model",),       # sequence-parallel residual stream
                                     # (Megatron-SP adapted to GSPMD): layer-
                                     # boundary activations shard their seq dim
                                     # over the model axis; attention/FFN
                                     # re-gather inside the layer
        "lora": (),
        "layers": (),
        "conv": (),
    },
    description="DP(pod,data) × TP/EP(model) × FSDP(data) — paper-faithful default",
)

# hillclimb variants ---------------------------------------------------------

FSDP_POD = ShardingRecipe(
    name="fsdp_pod",
    rules={**BASELINE.rules, "embed": ("pod", "data")},
    description="FSDP spans the pod axis too (param all-gather over DCI)",
)

TP_ONLY = ShardingRecipe(
    name="tp_only",
    rules={**BASELINE.rules, "embed": ()},
    description="pure DP×TP (params replicated across data axis)",
)

EXPERT_DATA = ShardingRecipe(
    name="expert_data",
    rules={**BASELINE.rules, "expert": ("data", "model"), "embed": ()},
    description="experts sharded over data×model (2D EP) for large-E MoE",
)

SEQ_DATA = ShardingRecipe(
    name="seq_data",
    rules={**BASELINE.rules, "seq": ("data",), "batch": ("pod", "data")},
    description="adds sequence sharding over data for long-context prefill",
)

NO_SP = ShardingRecipe(
    name="no_sp",
    rules={**BASELINE.rules, "act_seq": ()},
    description="baseline without sequence-parallel activations (ablation)",
)

RECIPES: Dict[str, ShardingRecipe] = {
    r.name: r for r in (BASELINE, FSDP_POD, TP_ONLY, EXPERT_DATA, SEQ_DATA, NO_SP)
}


def spec_for_axes(axes, recipe: ShardingRecipe, mesh: Mesh, shape=None) -> P:
    used: set = set()
    dims = shape if shape is not None else (None,) * len(axes)
    return P(*[recipe.resolve(a, mesh, used, d) for a, d in zip(axes, dims)])


def param_shardings(specs, recipe: ShardingRecipe, mesh: Mesh):
    """ParamSpec pytree → NamedSharding pytree."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, spec_for_axes(s.axes, recipe, mesh, s.shape)),
        specs, is_leaf=cm.is_spec)


def batch_sharding(mesh: Mesh, recipe: ShardingRecipe, rank: int,
                   seq_axis: Optional[int] = None, shape=None):
    """Sharding for an input whose leading dim is batch."""
    used: set = set()
    spec = [None] * rank
    bdim = shape[0] if shape else None
    spec[0] = recipe.resolve("batch", mesh, used, bdim)
    if seq_axis is not None and "seq" in recipe.rules:
        sdim = shape[seq_axis] if shape else None
        spec[seq_axis] = recipe.resolve("seq", mesh, used, sdim)
    return NamedSharding(mesh, P(*spec))


def for_decode(recipe: ShardingRecipe) -> ShardingRecipe:
    """Decode-cell variant: batch may additionally shard over the model axis
    (decode has tiny activations; owning full KV context per chip avoids
    per-layer KV all-gathers when batch divides)."""
    rules = dict(recipe.rules)
    rules["batch"] = tuple(rules.get("batch", ())) + ("model",)
    return ShardingRecipe(recipe.name + "+decode", rules, recipe.description)


# decode-cache leaf-name → logical axes (rank-matched, batch-leading)
CACHE_AXES = {
    "k": ("batch", "seq_kv", "kv_heads", None),
    "v": ("batch", "seq_kv", "kv_heads", None),
    "ck": ("batch", "seq_kv", "heads", None),
    "cv": ("batch", "seq_kv", "heads", None),
    "cross_k": ("batch", "seq_kv", "kv_heads", None),
    "cross_v": ("batch", "seq_kv", "kv_heads", None),
    "c_kv": ("batch", "seq_kv", None),
    "k_rope": ("batch", "seq_kv", None),
    "s": ("batch", "heads", None, None),
    "ts_tm": ("batch", None),
    "ts_cm": ("batch", None),
    "h": ("batch", "mlp"),
    "conv": ("batch", None, "mlp"),
}

# resolution priority: batch first, then parallel dims, context sharding last
_PRIORITY = {"batch": 0, "kv_heads": 1, "heads": 1, "mlp": 1, "expert": 1,
             "seq_kv": 2}


def cache_spec(name: str, shape, recipe: ShardingRecipe, mesh: Mesh) -> P:
    axes = CACHE_AXES[name]
    rank = len(shape)
    if rank == len(axes) + 1:                # stacked by scan reps
        axes = (None,) + axes
    assert rank == len(axes), (name, shape)
    used: set = set()
    order = sorted(range(rank), key=lambda i: _PRIORITY.get(axes[i], 3))
    resolved = [None] * rank
    for i in order:
        resolved[i] = recipe.resolve(axes[i], mesh, used, shape[i])
    return P(*resolved)


def cache_shardings(cache_tree, recipe: ShardingRecipe, mesh: Mesh):
    """Decode-cache pytree (possibly layer-stacked) → NamedSharding pytree."""

    def f(path, leaf):
        name = None
        for p in reversed(path):
            if isinstance(p, jax.tree_util.DictKey):
                name = p.key
                break
        return NamedSharding(mesh, cache_spec(name, leaf.shape, recipe, mesh))

    return jax.tree_util.tree_map_with_path(f, cache_tree)
