"""Sequence-parallel attention via shard_map (beyond-paper optimization H1).

Problem (measured in EXPERIMENTS.md §Perf): with sequence-parallel
activations, the pure-pjit query-block scan reshapes the seq axis into
(blocks, chunk) — GSPMD cannot express that resharding, replicates the
blocks over the model axis, and the *backward* pass then all-reduces
multi-GB score gradients per layer (qwen train_4k: 72 s collective term,
2.3 TB of all-reduce per device-step).

Fix: make the model-axis decomposition explicit with ``shard_map``.
Two variants, chosen per (arch × mesh):

- **heads-sharded** (preferred; H divisible by TP and each rank's head
  range lies within one GQA group): every rank computes its own heads over
  the full sequence; K/V enter replicated (one all-gather at the boundary,
  ~MBs); ZERO collectives inside the body, so backward stays local.
- **seq-sharded** (fallback; e.g. qwen's 40 heads): every rank owns a
  contiguous q-row block and all-gathers K/V inside; backward of the
  all_gather is a reduce-scatter of dK/dV — bytes ≈ KV size, not scores.

Both bodies reuse the same ``chunked_attention`` oracle that the Pallas
flash kernel validates against, so numerics are unchanged.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed import ctx as dctx


def _axis_size(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _resolve(recipe, mesh, logical, dim, used):
    return recipe.resolve(logical, mesh, used, dim)


def sp_attention(q, k, v, *, causal: bool, window: Optional[int],
                 chunk: int, wo=None, v_head: Optional[int] = None):
    """Drop-in replacement for chunked_attention under a sharding ctx.

    q: (B, S, H, hd); k, v: (B, S, K, hd). Returns (B, S, H, hd) — or, when
    ``wo`` (H, hd_o, d) is given, the *fused* residual output (B, S, d)
    psum-scattered back to the sequence-parallel layout (no post-hoc heads
    reshard / wo all-gather — EXPERIMENTS.md §Perf H2b). Returns None if no
    beneficial decomposition applies (caller falls back).
    """
    c = dctx.current()
    if c is None:
        return None
    mesh, recipe = c
    B, S, H, hd = q.shape
    K = k.shape[2]
    G = H // K

    used: set = set()
    b_axes = _resolve(recipe, mesh, "batch", B, used)
    used_h = set(used)
    h_axes = _resolve(recipe, mesh, "heads", H, used_h)
    tp_h = _axis_size(mesh, h_axes)
    used_s = set(used)
    s_axes = _resolve(recipe, mesh, "act_seq", S, used_s)
    tp_s = _axis_size(mesh, s_axes)

    from repro.models.attention import chunked_attention

    def finalize(o_loc, wo_loc, ax):
        """Fused out-projection: partial contraction over local heads, then
        psum-scatter the seq axis back to the SP layout."""
        if v_head is not None:
            o_loc = o_loc[..., :v_head]
        y_part = jnp.einsum("bshk,hkd->bsd", o_loc, wo_loc).astype(o_loc.dtype)
        return jax.lax.psum_scatter(y_part, ax, scatter_dimension=1,
                                    tiled=True)

    # -- variant 1: heads sharded, sequence gathered --------------------------
    # applies when (a) K shards with the q heads (alignment is automatic:
    # H_loc = G·K_loc), or (b) each rank's contiguous head range sits inside
    # a single GQA group (kv replicated, group-sliced per rank)
    kv_sharded = K % tp_h == 0
    if tp_h > 1 and (kv_sharded or
                     ((H // tp_h) <= G and G % (H // tp_h) == 0)):

        def body(ql, kl, vl, *wo_arg):
            # ql: (B_loc, S, H_loc, hd); kl/vl sharded iff kv_sharded
            if kv_sharded:
                kg, vg = kl, vl
            else:
                h_loc = ql.shape[2]
                r = jax.lax.axis_index(h_axes)
                group = (r * h_loc) // G      # single group per rank
                kg = jax.lax.dynamic_slice_in_dim(kl, group, 1, axis=2)
                vg = jax.lax.dynamic_slice_in_dim(vl, group, 1, axis=2)
            o = chunked_attention(ql, kg, vg, causal=causal, window=window,
                                  chunk=chunk)
            if wo_arg:
                return finalize(o, wo_arg[0], s_axes or h_axes)
            return o

        kv_spec = P(b_axes, None, h_axes if kv_sharded else None, None)
        args = [q, k, v]
        in_specs = [P(b_axes, None, h_axes, None), kv_spec, kv_spec]
        fused = wo is not None and s_axes is not None and S % tp_s == 0
        if fused:
            args.append(wo)
            in_specs.append(P(h_axes, None, None))
            out_specs = P(b_axes, s_axes, None)
        else:
            out_specs = P(b_axes, None, h_axes, None)
        out = jax.shard_map(body, mesh=mesh, in_specs=tuple(in_specs),
                            out_specs=out_specs, check_vma=False)(*args)
        return (out, True) if fused else (out, False)

    # -- variant 2: sequence sharded, K/V gathered inside ----------------------
    if tp_s > 1 and S % tp_s == 0:
        s_loc = S // tp_s

        def body(ql, kl, vl):
            # ql: (B_loc, S_loc, H, hd); kl/vl: (B_loc, S_loc, K, hd)
            kg = jax.lax.all_gather(kl, s_axes, axis=1, tiled=True)
            vg = jax.lax.all_gather(vl, s_axes, axis=1, tiled=True)
            r = jax.lax.axis_index(s_axes)
            return chunked_attention(ql, kg, vg, causal=causal, window=window,
                                     chunk=min(chunk, s_loc),
                                     q_offset=r * s_loc)

        out = jax.shard_map(
            body, mesh=mesh,
            in_specs=(P(b_axes, s_axes, None, None),
                      P(b_axes, s_axes, None, None),
                      P(b_axes, s_axes, None, None)),
            out_specs=P(b_axes, s_axes, None, None),
            check_vma=False,
        )(q, k, v)
        return (out, False)

    return None


def maybe_sp_attention(q, k, v, *, causal: bool = True,
                       window: Optional[int] = None, chunk: int = 512):
    """sp_attention if a profitable decomposition exists, else the plain
    chunked path. Returns the (B, S, H, hd) attention output (unfused)."""
    out = sp_attention(q, k, v, causal=causal, window=window, chunk=chunk)
    if out is not None:
        o, fused = out
        assert not fused
        return o
    from repro.models.attention import chunked_attention

    return chunked_attention(q, k, v, causal=causal, window=window,
                             chunk=chunk)


def maybe_sp_attention_fused(q, k, v, wo, *, causal: bool = True,
                             window: Optional[int] = None, chunk: int = 512,
                             v_head: Optional[int] = None):
    """Attention + fused output projection. Returns (B, S, d) or None."""
    out = sp_attention(q, k, v, causal=causal, window=window, chunk=chunk,
                       wo=wo, v_head=v_head)
    if out is None:
        return None
    o, fused = out
    if fused:
        return o
    # decomposition found but fusion not applicable: finish outside
    if v_head is not None:
        o = o[..., :v_head]
    from repro.distributed.ctx import constrain_residual

    return constrain_residual(
        jnp.einsum("bshk,hkd->bsd", o, wo).astype(o.dtype))
