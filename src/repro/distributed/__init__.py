from repro.distributed.sharding import (  # noqa: F401
    RECIPES,
    ShardingRecipe,
    batch_sharding,
    cache_shardings,
    param_shardings,
    spec_for_axes,
)
