"""Explicit Megatron-SP + ZeRO-3 FFN via shard_map (optimization H1c).

Measured problem (EXPERIMENTS.md §Perf): under pjit, the FFN's backward
psum+reshard patterns lower to *full-tensor all-reduces* instead of
reduce-scatters ("involuntary full rematerialization" in the SPMD
partitioner) — 4.5e11 link bytes/device-step on qwen train_4k, 60% of all
collective traffic.

Fix: hand-write the block's collectives inside shard_map, where autodiff
produces the exact duals:

    forward                              backward (automatic)
    x_full = all_gather(x, seq_ax)       dx = psum_scatter(dx_full)
    w_full = all_gather(w, fsdp_ax)      dw = psum_scatter(dw)  (ZeRO-3 grad RS)
    h      = act(x_full @ w_gate) * ..   (local; weight grads local-sharded)
    y_part = h @ w_down                  dh local
    y      = psum_scatter(y_part, seq)   dy_full = all_gather(dy)

Nothing is ever all-reduced at full size; weight gradients never leave
their shard layout.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed import ctx as dctx
from repro.models import common as cm


def _gather_weight(w, axes, axis_pos):
    """All-gather a weight's FSDP axis inside shard_map (no-op if None)."""
    if axes is None:
        return w
    names = axes if isinstance(axes, tuple) else (axes,)
    for a in names:
        w = jax.lax.all_gather(w, a, axis=axis_pos, tiled=True)
    return w


def sp_ffn(cfg, p: dict, x):
    """Explicit-collective FFN. Returns None if inapplicable (caller falls
    back to the pjit path)."""
    c = dctx.current()
    if c is None or x.ndim != 3:
        return None
    mesh, recipe = c
    B, S, d = x.shape
    f = p["w_up"].shape[-1]

    used: set = set()
    b_axes = recipe.resolve("batch", mesh, used, B)
    s_axes = recipe.resolve("act_seq", mesh, set(used), S)
    used_w: set = set()
    fsdp = recipe.resolve("embed", mesh, used_w, d)
    mlp = recipe.resolve("mlp", mesh, set(used_w), f)
    if s_axes is None or mlp is None or not isinstance(s_axes, str):
        return None
    if S % mesh.shape[s_axes] != 0:
        return None

    gated = "w_gate" in p
    act = cm.ACTIVATIONS["silu" if cfg.ffn_activation == "swiglu" else
                         "gelu" if gated else cfg.ffn_activation]

    def body(xl, wu, wd, *wg):
        # xl: (B_loc, S_loc, d); wu: (d_loc, f_loc); wd: (f_loc, d_loc)
        xg = jax.lax.all_gather(xl, s_axes, axis=1, tiled=True)
        wu_f = _gather_weight(wu, fsdp, 0)
        wd_f = _gather_weight(wd, fsdp, 1)
        up = jnp.einsum("bsd,df->bsf", xg, wu_f)
        if gated:
            wg_f = _gather_weight(wg[0], fsdp, 0)
            h = act(jnp.einsum("bsd,df->bsf", xg, wg_f)) * up
        else:
            h = act(up)
        y_part = jnp.einsum("bsf,fd->bsd", h, wd_f).astype(xl.dtype)
        return jax.lax.psum_scatter(y_part, s_axes, scatter_dimension=1,
                                    tiled=True)

    w_spec_up = P(fsdp, mlp)
    w_spec_down = P(mlp, fsdp)
    args = [x, p["w_up"], p["w_down"]]
    in_specs = [P(b_axes, s_axes, None), w_spec_up, w_spec_down]
    if gated:
        args.append(p["w_gate"])
        in_specs.append(w_spec_up)
    return jax.shard_map(
        body, mesh=mesh, in_specs=tuple(in_specs),
        out_specs=P(b_axes, s_axes, None), check_vma=False,
    )(*args)
