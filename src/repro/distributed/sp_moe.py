"""Explicit expert-parallel MoE dispatch via shard_map (optimization H2).

Measured problem (EXPERIMENTS.md §Perf): the pjit MoE path sorts the
*global* (token, choice) stream and scatters into a globally-indexed
(E, C, d) buffer. GSPMD cannot partition either step — tokens replicate,
the deepseek-v2 train cell reports 1860 s of collective traffic and a
107 GB live footprint.

Fix — the GShard pattern made explicit (group = one (data, seq) shard):

    per shard:  route → local sort → scatter into (E, C_loc, d)
    all_to_all  over the expert/model axis: (E, C_loc, d) → (E_loc, g·C_loc, d)
    local expert GEMMs (weights FSDP-gathered over data inside)
    all_to_all back, local combine

Every collective is one of: 2 × all_to_all (payload = dispatched tokens),
weight all-gather over the FSDP axis, and a pmean for the aux loss.
Group-wise capacity (tokens dropped per shard, not globally) is exactly
GShard's semantics.
"""
from __future__ import annotations

import jax
import jax.ad_checkpoint
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed import ctx as dctx


def _local_dispatch(x2d, top_p, top_i, E: int, k: int, cap: int):
    """Sort-based dispatch of local tokens into an (E, cap, d) buffer."""
    T = x2d.shape[0]
    flat_e = top_i.reshape(T * k)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(E, dtype=sorted_e.dtype))
    slot = jnp.arange(T * k, dtype=jnp.int32) - starts[sorted_e].astype(jnp.int32)
    keep = slot < cap
    slot_c = jnp.minimum(slot, cap - 1)
    tok = (order // k).astype(jnp.int32)
    buf = jnp.zeros((E, cap, x2d.shape[1]), x2d.dtype)
    upd = jnp.where(keep[:, None], x2d[tok], 0)
    buf = buf.at[sorted_e, slot_c].add(upd, mode="drop")
    return buf, (order, sorted_e, slot_c, keep, tok)


def _local_combine(out_buf, meta, top_p, T: int, k: int, dtype):
    order, sorted_e, slot_c, keep, tok = meta
    gathered = out_buf[sorted_e, slot_c]
    gathered = jnp.where(keep[:, None], gathered, 0)
    pair_w = top_p.reshape(T * k)[order].astype(dtype)
    contrib = gathered * pair_w[:, None]
    return jnp.zeros((T, out_buf.shape[-1]), dtype).at[tok].add(contrib)


def sp_moe(cfg, p: dict, x):
    """Explicit-collective routed-experts block. Returns (y, aux) or None."""
    c = dctx.current()
    if c is None or x.ndim != 3:
        return None
    mesh, recipe = c
    m = cfg.moe
    B, S, d = x.shape
    E, k = m.num_experts, m.top_k

    used: set = set()
    b_axes = recipe.resolve("batch", mesh, used, B)
    s_ax = recipe.resolve("act_seq", mesh, set(used), S)
    e_ax = recipe.resolve("expert", mesh, set(), E)
    wf_used = {e_ax} if isinstance(e_ax, str) else set(e_ax or ())
    fsdp = recipe.resolve("embed", mesh, set(wf_used), d)
    if not isinstance(e_ax, str) or s_ax != e_ax:
        return None                      # experts must ride the seq/model axis
    ep = mesh.shape[e_ax]
    if S % ep or E % ep:
        return None
    from repro.distributed.sp_ffn import _gather_weight

    b_size = 1
    for a in (b_axes if isinstance(b_axes, tuple) else
              (b_axes,) if b_axes else ()):
        b_size *= mesh.shape[a]
    T_loc = (B // b_size) * (S // ep)
    cap = int(max(8, round(T_loc * k / E * m.capacity_factor)))
    cap = -(-cap // 8) * 8               # sublane-align the expert GEMM

    def body(xl, router, wg, wu, wd):
        # xl: (B_loc, S_loc, d); router replicated; w*: (E_loc?, d_loc?, f)
        Bl, Sl, _ = xl.shape
        x2d = xl.reshape(Bl * Sl, d)
        logits = jnp.einsum("td,de->te", x2d.astype(jnp.float32),
                            router.astype(jnp.float32))
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_i = jax.lax.top_k(probs, k)
        top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
        # aux must match the global formula exactly: average density and
        # mean-prob across shards BEFORE the nonlinear product (equal-size
        # shards => pmean of token-means == global token-mean)
        density = jax.lax.pmean(
            jnp.mean(jax.nn.one_hot(top_i[:, 0], E), axis=0), mesh.axis_names)
        mean_prob = jax.lax.pmean(jnp.mean(probs, axis=0), mesh.axis_names)
        aux = E * jnp.sum(density * mean_prob)

        buf, meta = _local_dispatch(x2d, top_p, top_i, E, k, cap)
        # EP exchange: (E, cap, d) -> (E_loc, ep*cap, d)
        bufe = jax.lax.all_to_all(buf, e_ax, split_axis=0, concat_axis=1,
                                  tiled=True)
        bufe = jax.ad_checkpoint.checkpoint_name(bufe, "moe_bufe")
        wg_f = _gather_weight(wg, fsdp, 1)
        wu_f = _gather_weight(wu, fsdp, 1)
        wd_f = _gather_weight(wd, fsdp, 2)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", bufe, wg_f)) * \
            jnp.einsum("ecd,edf->ecf", bufe, wu_f)
        h = jax.ad_checkpoint.checkpoint_name(h, "moe_h")
        out = jnp.einsum("ecf,efd->ecd", h, wd_f).astype(xl.dtype)
        # return trip: (E_loc, ep*cap, d) -> (E, cap, d)
        out = jax.lax.all_to_all(out, e_ax, split_axis=1, concat_axis=0,
                                 tiled=True)
        y2d = _local_combine(out, meta, top_p, Bl * Sl, k, xl.dtype)
        return y2d.reshape(Bl, Sl, d), aux

    mlp_used = set(wf_used) | (set(fsdp) if isinstance(fsdp, tuple)
                               else {fsdp} if fsdp else set())
    f = p["w_gate"].shape[-1]
    mlp_ax = recipe.resolve("mlp", mesh, set(mlp_used), f)
    w_spec = P(e_ax, fsdp, mlp_ax)
    w_spec_down = P(e_ax, mlp_ax, fsdp)
    y, aux = jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(b_axes, s_ax, None), P(None, None),
                  w_spec, w_spec, w_spec_down),
        out_specs=(P(b_axes, s_ax, None), P()),
        check_vma=False,
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    return y, aux
