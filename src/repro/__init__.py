"""repro: phys-MCP control plane + multi-pod JAX training/inference framework."""

__version__ = "1.0.0"
