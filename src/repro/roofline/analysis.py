"""Three-term roofline model over the AOT-compiled artifact.

Hardware constants (TPU v5e target — the assignment's numbers):
    peak    197e12 FLOP/s bf16 per chip
    hbm_bw  819e9  B/s per chip
    link_bw 50e9   B/s per link (1 effective link per chip, conservative)

Terms (per §Roofline of the assignment):
    compute    = HLO_FLOPs(per-device) / peak
    memory     = HLO_bytes(per-device) / hbm_bw
    collective = collective_link_bytes(per-device) / link_bw

``cost_analysis()`` on an SPMD executable reports per-device numbers, so no
division by chip count is needed.  MODEL_FLOPS uses 6·N·D (dense) or
6·N_active·D (MoE) with N excluding embeddings, D = tokens processed.
"""
from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12
    hbm_bw: float = 819e9
    link_bw: float = 50e9
    hbm_bytes: float = 16e9


HW = Hardware()


def roofline_terms(flops_per_device: float, bytes_per_device: float,
                   coll_bytes_per_device: float, hw: Hardware = HW) -> Dict:
    compute = flops_per_device / hw.peak_flops
    memory = bytes_per_device / hw.hbm_bw
    collective = coll_bytes_per_device / hw.link_bw
    terms = {"compute_s": compute, "memory_s": memory, "collective_s": collective}
    dom = max(terms, key=terms.get)
    bound = max(compute, memory, collective)
    terms.update({
        "dominant": dom.replace("_s", ""),
        "step_time_lb_s": bound,
        # fraction of the bound spent doing useful math = how close the cell
        # sits to its compute roofline
        "roofline_fraction": (compute / bound) if bound > 0 else 0.0,
    })
    return terms


def model_flops(n_params_active: int, tokens: int, kind: str = "train") -> float:
    """6·N·D for train (fwd+bwd), 2·N·D for inference forward."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_params_active * tokens
