"""Loop-aware cost extraction from post-SPMD compiled HLO text.

Why not ``compiled.cost_analysis()``?  XLA's HloCostAnalysis visits a
``while`` body **once** — every ``jax.lax.scan`` (layers, microbatches,
attention query blocks, xent chunks) is therefore undercounted by its trip
count, which at 96 layers × 8 microbatches is a ~3 orders-of-magnitude error.
The compiled HLO carries ``known_trip_count`` on each while op, so this
module implements a small loop-aware analyzer:

- parses the module into computations with per-op result shapes,
- resolves operand shapes through a per-computation symbol table,
- walks the call graph from ENTRY, multiplying by loop trip counts,
- accumulates:
    * ``flops``            — 2·M·N·K for every dot (the MXU work),
    * ``bytes``            — Σ (operands + result) over non-trivial ops
                             (fusion nodes counted at their boundary — a good
                             HBM-traffic proxy under XLA's aggressive fusion),
    * ``transcendentals``  — element counts of exp/log/tanh/... ops,
    * ``collectives``      — per-kind link bytes using ring cost models:
        all-gather          out·(g−1)/g
        all-reduce          2·out·(g−1)/g
        reduce-scatter      out·(g−1)
        all-to-all          out·(g−1)/g
        collective-permute  out

Everything is per-device (the HLO is a single-device SPMD program).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(?[^=]*?)\s*"
                    r"([a-z][\w\-]*)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*?)\)\s*->")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_CALL_ATTR_RE = re.compile(r"(?:calls|to_apply|body|condition)=%([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

_SKIP_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "iota", "after-all", "opt-barrier", "partition-id", "replica-id"}

# Ops whose operand/result traffic is counted toward HBM bytes.  Standalone
# elementwise/layout ops (convert, multiply, transpose, broadcast, ...) are
# EXCLUDED: on the TPU target XLA fuses such chains into their producers/
# consumers, so their traffic is already represented by the dot / fusion /
# reduce boundaries. The CPU backend fuses less, which is why we don't simply
# trust its op mix.
_BYTES_OPS = {"dot", "convolution", "gather", "scatter", "dynamic-slice",
              "dynamic-update-slice", "reduce", "reduce-window", "sort",
              "concatenate", "pad", "select-and-scatter", "cholesky",
              "triangular-solve", "fft", "rng", "copy"}
_TRANSCENDENTAL = {"exponential", "log", "tanh", "rsqrt", "sqrt", "power",
                   "logistic", "expm1", "log1p", "sine", "cosine"}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _type_elems_bytes(type_str: str) -> Tuple[int, int]:
    """Total (elements, bytes) over all array shapes in a type string."""
    elems = tot = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        tot += n * _DTYPE_BYTES[dt]
    return elems, tot


def _shape_dims(type_str: str) -> Optional[Tuple[str, List[int]]]:
    """First array shape in a type string → (dtype, dims)."""
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dt, dims = m.groups()
    if dt not in _DTYPE_BYTES:
        return None
    return dt, [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    result_type: str
    operands: List[str]
    attrs: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    types: Dict[str, str]            # symbol -> type string


def parse_module(hlo_text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    comment_re = re.compile(r"/\*.*?\*/")
    for raw in hlo_text.splitlines():
        line = comment_re.sub("", raw.rstrip())
        s = line.strip()
        if cur is None:
            if ("{" in line and "->" in line and not s.startswith("//")):
                m = _COMP_HDR_RE.match(s)
                if not m:
                    continue
                name, params = m.group(1), m.group(2)
                cur = Computation(name, [], {})
                if s.startswith("ENTRY"):
                    entry = name
                # params: "p0: f32[2,3], p1: bf16[4]"
                for pm in re.finditer(r"([\w.\-]+)\s*:\s*([^,]+)", params):
                    cur.types[pm.group(1)] = pm.group(2)
            continue
        if s == "}" or s.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, rtype, kind, rest = m.groups()
        # operands: %refs up to the closing paren of the op call
        depth = 1
        i = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        operand_str, attrs = rest[:i], rest[i + 1:]
        operands = re.findall(r"%([\w.\-]+)", operand_str)
        cur.types[name] = rtype
        cur.ops.append(Op(name, kind, rtype, operands, attrs))
    if cur is not None:
        comps[cur.name] = cur
    return comps, entry


def _group_size(attrs: str) -> int:
    m = _GROUPS_IOTA_RE.search(attrs)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(attrs)
    if m:
        return max(len([x for x in m.group(1).split(",") if x.strip()]), 1)
    return 2


def _dot_flops(comp: Computation, op: Op) -> float:
    out = _shape_dims(op.result_type)
    if out is None:
        return 0.0
    _, out_dims = out
    n_out = 1
    for d in out_dims:
        n_out *= d
    k = 1
    m = _CONTRACT_RE.search(op.attrs)
    if m and op.operands:
        lhs_t = comp.types.get(op.operands[0], "")
        lhs = _shape_dims(lhs_t)
        if lhs:
            _, lhs_dims = lhs
            for idx in m.group(1).split(","):
                if idx != "" and int(idx) < len(lhs_dims):
                    k *= lhs_dims[int(idx)]
    return 2.0 * n_out * k


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    transcendentals: float = 0.0
    coll: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    coll_counts: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))


def _collective_kind(kind: str) -> Optional[str]:
    k = kind.removesuffix("-start").removesuffix("-done")
    return k if k in _COLLECTIVES else None


def _visit(comps: Dict[str, Computation], cname: str, mult: float, acc: Costs,
           seen_stack: Tuple[str, ...] = ()):
    comp = comps.get(cname)
    if comp is None or cname in seen_stack:
        return
    for op in comp.ops:
        kind = op.kind
        if kind in _SKIP_OPS:
            continue
        ckind = _collective_kind(kind)
        if kind == "while":
            tm = _TRIP_RE.search(op.attrs)
            trips = int(tm.group(1)) if tm else 1
            called = _CALL_ATTR_RE.findall(op.attrs)
            for sub in called:
                _visit(comps, sub, mult * trips, acc, seen_stack + (cname,))
            continue
        if kind in ("fusion", "call", "conditional", "async-start"):
            # fusion boundary: one write + one read of the result. Operands
            # are NOT re-counted — they were counted when produced (chains of
            # small CPU-backend fusions would otherwise multiply-count the
            # same tensor; the TPU target forms fewer, larger fusions).
            _, b = _type_elems_bytes(op.result_type)
            acc.bytes += 2.0 * b * mult
            for sub in _CALL_ATTR_RE.findall(op.attrs):
                sc = comps.get(sub)
                if sc is None:
                    continue
                for iop in sc.ops:
                    if iop.kind == "dot":
                        acc.flops += _dot_flops(sc, iop) * mult
                    elif iop.kind in _TRANSCENDENTAL:
                        e, _ = _type_elems_bytes(iop.result_type)
                        acc.transcendentals += e * mult
            continue
        if ckind is not None:
            if kind.endswith("-done"):
                continue
            _, out_bytes = _type_elems_bytes(op.result_type)
            g = _group_size(op.attrs)
            if ckind == "all-gather":
                moved = out_bytes * (g - 1) / g
            elif ckind == "all-reduce":
                moved = 2.0 * out_bytes * (g - 1) / g
            elif ckind == "reduce-scatter":
                moved = out_bytes * (g - 1)
            elif ckind == "all-to-all":
                moved = out_bytes * (g - 1) / g
            else:
                moved = float(out_bytes)
            acc.coll[ckind] += moved * mult
            acc.coll_counts[ckind] += mult
            # collective buffers also traverse HBM
            acc.bytes += 2.0 * out_bytes * mult
            continue
        # generic op
        if kind in _BYTES_OPS:
            _, rb = _type_elems_bytes(op.result_type)
            ob = sum(_type_elems_bytes(comp.types.get(o, ""))[1]
                     for o in op.operands)
            acc.bytes += (rb + ob) * mult
        if kind == "dot":
            acc.flops += _dot_flops(comp, op) * mult
        elif kind in _TRANSCENDENTAL:
            e, _ = _type_elems_bytes(op.result_type)
            acc.transcendentals += e * mult


def analyze(hlo_text: str) -> Dict:
    """Loop-aware per-device costs for a compiled SPMD module."""
    comps, entry = parse_module(hlo_text)
    acc = Costs()
    if entry is not None:
        _visit(comps, entry, 1.0, acc)
    coll = dict(acc.coll)
    coll["total"] = float(sum(acc.coll.values()))
    coll["counts"] = {k: int(v) for k, v in acc.coll_counts.items()}
    return {
        "flops": acc.flops,
        "bytes": acc.bytes,
        "transcendentals": acc.transcendentals,
        "collectives": coll,
    }


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Back-compat wrapper: loop-aware collective traffic only."""
    return analyze(hlo_text)["collectives"]
