"""Predictive serving cost model: the roofline as an admission oracle.

The TPU roofline twin (PR 3) predicts *training* step time after the fact;
serving needs the prediction *before* the work runs: a request that cannot
finish inside its deadline must be refused at admission (structured
``DEADLINE``) instead of timing out mid-decode after burning batch slots.

The model prices one decode step of the whole batch from first principles —
2·N FLOPs per token (``model_flops`` inference form) against parameter +
KV-cache HBM traffic (``roofline_terms``) — which gives a hardware lower
bound, then tightens it with measured step/prefill medians exactly like
``RooflineSurrogate`` does for training (the lower bound stays a floor: a
noisy fast sample can never make the model optimistic beyond physics).

Predicted completion for a new arrival =

    prefill(prompt) + queue_drain(backlog / batch_size) + steps · step_ms

scaled by a safety factor, with queue drain counted because continuous
batching admits at slot grain: a full batch retires at most ``batch_size``
tokens per step.
"""
from __future__ import annotations

import collections
import statistics
import threading
from typing import Deque, Dict, Optional

import numpy as np

from repro.models import decode_cache, model_specs
from repro.models.common import param_count
from repro.roofline.analysis import HW, Hardware, model_flops, roofline_terms

_DTYPE_BYTES = {"bfloat16": 2, "float16": 2, "float32": 4, "float64": 8}


def _dtype_bytes(name: str) -> int:
    try:
        return _DTYPE_BYTES.get(str(name)) or np.dtype(name).itemsize
    except TypeError:
        return 4


def _cache_bytes_per_row(cfg, max_seq: int) -> int:
    """HBM footprint of one batch row's decode cache (abstract shapes —
    never allocates)."""
    import jax

    tree = decode_cache(cfg, 1, max_seq, abstract=True)
    return int(sum(np.prod(leaf.shape) * np.dtype(leaf.dtype).itemsize
                   for leaf in jax.tree.leaves(tree)))


class ServingCostModel:
    """Roofline-prior, measurement-tightened cost model for one engine."""

    #: headroom multiplier on every prediction (scheduling jitter, GC, the
    #: prose reason a refusal carries shows the *scaled* number)
    SAFETY = 1.25
    #: observation windows (medians are robust to jit-compile outliers)
    WINDOW = 64

    def __init__(self, cfg, *, batch_size: int, max_seq: int,
                 hw: Hardware = HW, safety: float = SAFETY):
        self.batch_size = batch_size
        self.max_seq = max_seq
        self.safety = safety
        n_params = param_count(model_specs(cfg))
        pbytes = n_params * _dtype_bytes(cfg.param_dtype)
        kv_bytes = _cache_bytes_per_row(cfg, max_seq) * batch_size
        # one decode step of the full batch: 2·N FLOPs per live token, one
        # full parameter read, one KV-cache sweep
        flops = model_flops(n_params, batch_size, kind="inference")
        self._terms = roofline_terms(flops, pbytes + kv_bytes, 0.0, hw)
        self.step_lb_ms = self._terms["step_time_lb_s"] * 1e3
        # per-token prefill lower bound: same arithmetic at batch 1, token 1
        pf = roofline_terms(model_flops(n_params, 1, kind="inference"),
                            pbytes, 0.0, hw)
        self.prefill_lb_ms_per_token = pf["step_time_lb_s"] * 1e3
        self._lock = threading.Lock()
        self._step_ms: Deque[float] = collections.deque(maxlen=self.WINDOW)
        self._prefill_ms_tok: Deque[float] = collections.deque(maxlen=self.WINDOW)

    # -- measurement feed (engine on_step_ms / on_prefill_ms hooks) -----------
    def observe_step(self, ms: float) -> None:
        with self._lock:
            self._step_ms.append(ms)

    def observe_prefill(self, prompt_len: int, ms: float) -> None:
        if prompt_len > 0:
            with self._lock:
                self._prefill_ms_tok.append(ms / prompt_len)

    # -- predictions ----------------------------------------------------------
    def step_ms(self) -> float:
        with self._lock:
            obs = statistics.median(self._step_ms) if self._step_ms else 0.0
        return max(obs, self.step_lb_ms)

    def prefill_ms(self, prompt_len: int) -> float:
        with self._lock:
            obs = (statistics.median(self._prefill_ms_tok)
                   if self._prefill_ms_tok else 0.0)
        return prompt_len * max(obs, self.prefill_lb_ms_per_token)

    def predict_request_ms(self, prompt_len: int, max_new_tokens: int,
                           backlog_tokens: int = 0) -> float:
        """Predicted arrival→completion time for a new request given the
        engine's current backlog (tokens owed to queued + live requests)."""
        step = self.step_ms()
        decode_steps = max(max_new_tokens - 1, 0)   # first token: prefill
        drain_steps = backlog_tokens / max(1, self.batch_size)
        total = (self.prefill_ms(prompt_len)
                 + (drain_steps + decode_steps) * step)
        return self.safety * total

    def snapshot(self) -> Dict:
        with self._lock:
            n_step, n_pf = len(self._step_ms), len(self._prefill_ms_tok)
        return {
            "step_lb_ms": round(self.step_lb_ms, 6),
            "step_ms": round(self.step_ms(), 4),
            "prefill_lb_ms_per_token": round(self.prefill_lb_ms_per_token, 6),
            "dominant": self._terms["dominant"],
            "observed_steps": n_step,
            "observed_prefills": n_pf,
        }
