"""Predictive serving cost model: the roofline as an admission oracle.

The TPU roofline twin (PR 3) predicts *training* step time after the fact;
serving needs the prediction *before* the work runs: a request that cannot
finish inside its deadline must be refused at admission (structured
``DEADLINE``) instead of timing out mid-decode after burning batch slots.

The model prices one decode step of the whole batch from first principles —
2·N FLOPs per token (``model_flops`` inference form) against parameter +
KV-cache HBM traffic (``roofline_terms``) — which gives a hardware lower
bound, then tightens it with measured step/prefill medians exactly like
``RooflineSurrogate`` does for training (the lower bound stays a floor: a
noisy fast sample can never make the model optimistic beyond physics).

Predicted completion for a new arrival =

    prefill(prompt) + queue_drain(backlog / batch_size) + steps · step_ms

scaled by a safety factor, with queue drain counted because continuous
batching admits at slot grain: a full batch retires at most ``batch_size``
tokens per step.
"""
from __future__ import annotations

import collections
import statistics
import threading
from typing import Deque, Dict, Optional

import numpy as np

from repro.models import (decode_cache, decode_cache_paged, model_specs,
                          paged_cache_flags)
from repro.models.common import param_count
from repro.roofline.analysis import HW, Hardware, model_flops, roofline_terms

_DTYPE_BYTES = {"bfloat16": 2, "float16": 2, "float32": 4, "float64": 8}


def _dtype_bytes(name: str) -> int:
    try:
        return _DTYPE_BYTES.get(str(name)) or np.dtype(name).itemsize
    except TypeError:
        return 4


def _cache_bytes_per_row(cfg, max_seq: int) -> int:
    """HBM footprint of one batch row's decode cache (abstract shapes —
    never allocates)."""
    import jax

    tree = decode_cache(cfg, 1, max_seq, abstract=True)
    return int(sum(np.prod(leaf.shape) * np.dtype(leaf.dtype).itemsize
                   for leaf in jax.tree.leaves(tree)))


def _paged_cache_bytes(cfg, batch: int, max_seq: int, pool_pages: int,
                       page_size: int):
    """-> (pool_bytes, resident_bytes) of the paged decode cache (abstract
    shapes).  ``pool_bytes`` spans all ``pool_pages + 1`` rows (incl. the
    null page); resident leaves keep the slot-granular batch layout."""
    import jax

    tree = decode_cache_paged(cfg, batch, max_seq, pool_pages, page_size,
                              abstract=True)
    flags = paged_cache_flags(cfg)
    pool_b = resident_b = 0
    for flag, leaf in zip(jax.tree.leaves(flags), jax.tree.leaves(tree)):
        b = int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
        if flag:
            pool_b += b
        else:
            resident_b += b
    return pool_b, resident_b


class ServingCostModel:
    """Roofline-prior, measurement-tightened cost model for one engine."""

    #: headroom multiplier on every prediction (scheduling jitter, GC, the
    #: prose reason a refusal carries shows the *scaled* number)
    SAFETY = 1.25
    #: observation windows (medians are robust to jit-compile outliers)
    WINDOW = 64

    def __init__(self, cfg, *, batch_size: int, max_seq: int,
                 hw: Hardware = HW, safety: float = SAFETY,
                 page_size: Optional[int] = None,
                 pool_pages: Optional[int] = None):
        self.batch_size = batch_size
        self.max_seq = max_seq
        self.safety = safety
        self.page_size = page_size
        self.pool_pages = pool_pages
        n_params = param_count(model_specs(cfg))
        pbytes = n_params * _dtype_bytes(cfg.param_dtype)
        if page_size is not None and pool_pages:
            # paged engine: KV HBM is priced in pages — a full pool for the
            # static step bound (conservative), live + predicted-growth
            # pages for dynamic capacity questions (page_hbm_bytes)
            pool_b, resident_b = _paged_cache_bytes(
                cfg, batch_size, max_seq, pool_pages, page_size)
            self.bytes_per_page = pool_b // (pool_pages + 1)
            self.resident_cache_bytes = resident_b
            kv_bytes = resident_b + pool_pages * self.bytes_per_page
        else:
            self.bytes_per_page = 0
            self.resident_cache_bytes = 0
            kv_bytes = _cache_bytes_per_row(cfg, max_seq) * batch_size
        self.kv_hbm_bytes = kv_bytes
        # one decode step of the full batch: 2·N FLOPs per live token, one
        # full parameter read, one KV-cache sweep
        flops = model_flops(n_params, batch_size, kind="inference")
        self._terms = roofline_terms(flops, pbytes + kv_bytes, 0.0, hw)
        self.step_lb_ms = self._terms["step_time_lb_s"] * 1e3
        # per-token prefill lower bound: same arithmetic at batch 1, token 1
        pf = roofline_terms(model_flops(n_params, 1, kind="inference"),
                            pbytes, 0.0, hw)
        self.prefill_lb_ms_per_token = pf["step_time_lb_s"] * 1e3
        self._lock = threading.Lock()
        self._step_ms: Deque[float] = collections.deque(maxlen=self.WINDOW)
        self._prefill_ms_tok: Deque[float] = collections.deque(maxlen=self.WINDOW)

    # -- measurement feed (engine on_step_ms / on_prefill_ms hooks) -----------
    def observe_step(self, ms: float) -> None:
        with self._lock:
            self._step_ms.append(ms)

    def observe_prefill(self, prompt_len: int, ms: float) -> None:
        if prompt_len > 0:
            with self._lock:
                self._prefill_ms_tok.append(ms / prompt_len)

    # -- predictions ----------------------------------------------------------
    def step_ms(self) -> float:
        with self._lock:
            obs = statistics.median(self._step_ms) if self._step_ms else 0.0
        return max(obs, self.step_lb_ms)

    def prefill_ms(self, prompt_len: int) -> float:
        with self._lock:
            obs = (statistics.median(self._prefill_ms_tok)
                   if self._prefill_ms_tok else 0.0)
        return prompt_len * max(obs, self.prefill_lb_ms_per_token)

    def page_hbm_bytes(self, live_pages: int, growth_pages: int = 0) -> int:
        """KV HBM footprint at ``live_pages`` pool pages in use plus a
        predicted-growth allowance — what a paged engine actually touches,
        as opposed to the ``batch × max_seq`` worst case."""
        return int(self.resident_cache_bytes
                   + (live_pages + growth_pages) * self.bytes_per_page)

    def predict_request_ms(self, prompt_len: int, max_new_tokens: int,
                           backlog_tokens: int = 0, *,
                           backlog_prefill_tokens: int = 0,
                           cached_prefix_tokens: int = 0) -> float:
        """Predicted arrival→completion time for a new request given the
        engine's current backlog.  ``backlog_tokens`` is decode work owed
        to queued + live requests; ``backlog_prefill_tokens`` is un-prefilled
        prompt work of waiting requests (priced at prefill rate, not decode
        rate).  ``cached_prefix_tokens`` are prompt tokens the prefix cache
        already holds — only the suffix is prefilled."""
        step = self.step_ms()
        decode_steps = max(max_new_tokens - 1, 0)   # first token: prefill
        drain_steps = backlog_tokens / max(1, self.batch_size)
        suffix = max(prompt_len - cached_prefix_tokens, 1)
        total = (self.prefill_ms(suffix)
                 + self.prefill_ms(backlog_prefill_tokens)
                 + (drain_steps + decode_steps) * step)
        return self.safety * total

    def snapshot(self) -> Dict:
        with self._lock:
            n_step, n_pf = len(self._step_ms), len(self._prefill_ms_tok)
        snap = {
            "step_lb_ms": round(self.step_lb_ms, 6),
            "step_ms": round(self.step_ms(), 4),
            "prefill_lb_ms_per_token": round(self.prefill_lb_ms_per_token, 6),
            "dominant": self._terms["dominant"],
            "observed_steps": n_step,
            "observed_prefills": n_pf,
        }
        if self.bytes_per_page:
            snap["bytes_per_page"] = self.bytes_per_page
        return snap
