"""Structured control-plane error taxonomy (wire protocol v1).

Before the protocol-first redesign every rejection was an ad-hoc prose
string ("concurrency limit", "circuit open (quarantined): ...").  Prose is
fine for humans but useless for clients programming against the plane: a
remote caller needs to distinguish "this task can never match" from "the
fleet is saturated, retry later" from "the breaker is open, back off".

:class:`ErrorCode` is the closed set of machine-readable outcomes every
control-plane rejection maps onto; the in-process path (``Orchestrator``),
the wire path (``repro.gateway``) and the federated path
(``RemotePlaneAdapter``) all speak it, so a rejection classified on an edge
plane survives two hops to a cloud client unchanged.

Prose reasons are NOT replaced — every :class:`ControlPlaneError` and every
rejected ``InvocationResult`` still carries the human-readable reason
(including e.g. a twin's recorded ``invalidation_reason``); the code rides
alongside it.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, Optional


class ErrorCode(str, enum.Enum):
    """Closed taxonomy of structured control-plane failure outcomes."""

    #: no admissible backend for this task shape (modality/function mismatch)
    NO_MATCH = "NO_MATCH"
    #: policy manager refused: supervision, tenancy, safety bounds
    POLICY_DENIED = "POLICY_DENIED"
    #: circuit breaker open / probation refused (resource quarantined)
    BREAKER_OPEN = "BREAKER_OPEN"
    #: concurrency slots exhausted / queue backpressure
    QUEUE_SATURATED = "QUEUE_SATURATED"
    #: deadline lapsed (while queued, or admission blocked past the budget)
    DEADLINE = "DEADLINE"
    #: twin validity constraint failed (invalidated / stale / low confidence)
    TWIN_INVALID = "TWIN_INVALID"
    #: every fallback attempt failed (prepare/invoke/postcondition errors)
    FALLBACK_EXHAUSTED = "FALLBACK_EXHAUSTED"
    #: named resource does not exist on this plane
    NOT_FOUND = "NOT_FOUND"
    #: malformed request / unsupported protocol version
    BAD_REQUEST = "BAD_REQUEST"
    #: remote plane unreachable (federation transport failure)
    PLANE_UNAVAILABLE = "PLANE_UNAVAILABLE"
    #: federating this plane would make it transitively reach itself
    FEDERATION_CYCLE = "FEDERATION_CYCLE"
    #: missing/unknown wire credentials (gateway requires per-plane keys)
    UNAUTHORIZED = "UNAUTHORIZED"
    #: unexpected server-side failure
    INTERNAL = "INTERNAL"


#: substring → code classification table for legacy prose reasons, most
#: specific first (an aggregated multi-candidate reason may contain several
#: patterns; the first hit wins, so e.g. a fleet whose only blocker is an
#: open breaker classifies BREAKER_OPEN, not NO_MATCH)
_CLASSIFIERS = (
    (ErrorCode.TWIN_INVALID, ("twin invalidated", "twin stale",
                              "twin confidence", "twin fallback unavailable",
                              "no twin bound")),
    (ErrorCode.BREAKER_OPEN, ("circuit open", "quarantined", "probation")),
    (ErrorCode.DEADLINE, ("deadline exceeded", "deadline lapsed",
                          "hop budget", "deadline budget")),
    (ErrorCode.FEDERATION_CYCLE, ("federation cycle", "would create a cycle")),
    (ErrorCode.QUEUE_SATURATED, ("concurrency limit", "queue saturated")),
    (ErrorCode.POLICY_DENIED, ("supervision", "not authorized",
                               "exceeds safety bound")),
    (ErrorCode.FALLBACK_EXHAUSTED, ("fallback attempts exhausted",
                                    "prepare failure", "invoke failure",
                                    "postcondition")),
    (ErrorCode.NOT_FOUND, ("resource unregistered", "no such resource")),
    (ErrorCode.BAD_REQUEST, ("bad request", "exceeds max_seq", "empty prompt",
                             "kv cache overflow")),
)


def classify_rejection(reason: Optional[str]) -> ErrorCode:
    """Map a prose rejection reason onto the structured taxonomy.

    New code passes codes explicitly; this classifier keeps every legacy
    reason string (matcher admissibility prose, aggregated multi-candidate
    rejections) wire-classifiable without rewriting each producer.
    """
    if not reason:
        return ErrorCode.INTERNAL
    low = reason.lower()
    for code, needles in _CLASSIFIERS:
        if any(n in low for n in needles):
            return code
    return ErrorCode.NO_MATCH


@dataclasses.dataclass
class WireError:
    """Structured error as it crosses the wire: code + prose + detail."""

    code: ErrorCode
    message: str
    detail: Dict = dataclasses.field(default_factory=dict)

    def to_wire(self) -> Dict:
        return {"code": self.code.value, "message": self.message,
                "detail": dict(self.detail)}

    @classmethod
    def from_wire(cls, d: Dict) -> "WireError":
        try:
            code = ErrorCode(d.get("code", "INTERNAL"))
        except ValueError:
            code = ErrorCode.INTERNAL
        return cls(code, d.get("message", ""), dict(d.get("detail") or {}))


class ControlPlaneError(RuntimeError):
    """Raised by protocol-aware surfaces (gateway client, federation
    adapter) when the plane rejects a request; carries the structured code
    and any detail (e.g. a twin's ``invalidation_reason``)."""

    def __init__(self, code: ErrorCode, message: str,
                 detail: Optional[Dict] = None):
        super().__init__(message)
        self.code = code
        self.message = message
        self.detail = dict(detail or {})

    @classmethod
    def from_wire_error(cls, err: WireError) -> "ControlPlaneError":
        return cls(err.code, err.message, err.detail)


class AdmissionRefused(ControlPlaneError):
    """Raised by an adapter that REFUSES work it predicts it cannot serve
    within the task's budget (predictive admission control, e.g. the LM
    serving substrate's roofline admission model).

    Unlike an invocation *failure*, a refusal is not evidence of substrate
    ill-health: the invocation manager completes the lifecycle session
    normally (no NEEDS_RESET, no FAILED), and the health manager records
    the attempt as ok so refusals never trip a circuit breaker.  The
    refusal message should contain a classifier needle (e.g. "deadline
    budget", "exceeds max_seq") so prose classification recovers the code
    after fallback aggregation.
    """
