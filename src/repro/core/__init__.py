"""phys-MCP control plane: the paper's primary contribution.

Three-plane separation (paper §IV):
- control plane: registry, matcher, policy, lifecycle, invocation, orchestrator
- twin plane:    twin.TwinState / TwinSyncManager
- data plane:    repro.substrates.* adapters
"""
from repro.core.contracts import (SessionContracts, TelemetryContract,  # noqa: F401
                                  TimingContract, LifecycleContract,
                                  contracts_from_descriptor)
from repro.core.descriptors import (CapabilityDescriptor, Observability,  # noqa: F401
                                    PolicyConstraints, ResourceDescriptor,
                                    SignalSpec, TimingSemantics,
                                    LifecycleSemantics, shared_key_ratio)
from repro.core.errors import (ControlPlaneError, ErrorCode,  # noqa: F401
                               WireError, classify_rejection)
from repro.core.health import (BreakerState, BreakerTransition,  # noqa: F401
                               HealthManager, HealthThresholds,
                               LEGAL_BREAKER)
from repro.core.invocation import (InvocationManager, InvocationResult,  # noqa: F401
                                   RESULT_KEYS, Session)
from repro.core.lifecycle import LifecycleManager, LifecycleState  # noqa: F401
from repro.core.matcher import (Candidate, LatencyOnlySelector, Matcher,  # noqa: F401
                                MatchWeights, ModalityOnlySelector,
                                RandomAdmissibleSelector)
from repro.core.orchestrator import Orchestrator, OrchestrationTrace  # noqa: F401
from repro.core.policy import PolicyManager  # noqa: F401
from repro.core.scheduler import ControlPlaneScheduler, SchedulerClosed  # noqa: F401
from repro.core.registry import CapabilityRegistry  # noqa: F401
from repro.core.simclock import (Clock, SystemClock, SYSTEM_CLOCK,  # noqa: F401
                                 VirtualClock, RealSleepForbidden,
                                 forbid_real_sleep)
from repro.core.simulator import (FleetSimulator, SimScenario,  # noqa: F401
                                  scenario_matrix, run_audits,
                                  event_trace_hash)
from repro.core.tasks import (TaskRequest, new_task_id,  # noqa: F401
                              set_plane_namespace)
from repro.core.telemetry import RuntimeSnapshot, TelemetryBus, TelemetryEvent  # noqa: F401
from repro.core.topology import (DEFAULT_HOP_BUDGET, HOP_WIRE_MARGIN_MS,  # noqa: F401
                                 PlaneTopology, budget_admissible,
                                 forward_task, remaining_budget_ms)
from repro.core.twin import (RecordReplaySurrogate, TwinNotReady,  # noqa: F401
                             TwinState, TwinSurrogate, TwinSyncManager,
                             output_divergence)
from repro.core.twin_executor import TwinExecutor, TwinUnavailable  # noqa: F401
