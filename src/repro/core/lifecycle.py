"""Lifecycle plane: explicit state machines per substrate (requirement R4).

Physical substrates are not always-ready resources — warm-up, priming,
calibration, reset, cooldown and recovery are part of the effective
execution cost.  The manager enforces legal transitions and records their
wall-clock cost (surfaced in RQ3 as control-path overhead).
"""
from __future__ import annotations

import dataclasses
import enum
import time
from typing import Dict, List, Optional, Tuple


class LifecycleState(enum.Enum):
    UNINITIALIZED = "uninitialized"
    PREPARING = "preparing"        # warm-up / priming / calibration
    READY = "ready"
    RUNNING = "running"
    NEEDS_RESET = "needs_reset"    # must flush/recharge/rest before reuse
    RECOVERING = "recovering"
    COOLDOWN = "cooldown"
    FAILED = "failed"
    RETIRED = "retired"


_LEGAL: Dict[LifecycleState, Tuple[LifecycleState, ...]] = {
    LifecycleState.UNINITIALIZED: (LifecycleState.PREPARING,),
    LifecycleState.PREPARING: (LifecycleState.READY, LifecycleState.FAILED),
    LifecycleState.READY: (LifecycleState.RUNNING, LifecycleState.PREPARING,
                           LifecycleState.RETIRED, LifecycleState.FAILED),
    LifecycleState.RUNNING: (LifecycleState.READY, LifecycleState.NEEDS_RESET,
                             LifecycleState.COOLDOWN, LifecycleState.FAILED),
    LifecycleState.NEEDS_RESET: (LifecycleState.RECOVERING,
                                 LifecycleState.FAILED),
    LifecycleState.RECOVERING: (LifecycleState.READY, LifecycleState.FAILED),
    LifecycleState.COOLDOWN: (LifecycleState.READY,),
    LifecycleState.FAILED: (LifecycleState.RECOVERING, LifecycleState.RETIRED),
    LifecycleState.RETIRED: (),
}


@dataclasses.dataclass
class Transition:
    src: str
    dst: str
    action: str
    at: float
    duration_ms: float = 0.0


class LifecycleManager:
    def __init__(self):
        self._states: Dict[str, LifecycleState] = {}
        self._log: Dict[str, List[Transition]] = {}

    def state(self, rid: str) -> LifecycleState:
        return self._states.get(rid, LifecycleState.UNINITIALIZED)

    def history(self, rid: str) -> List[Transition]:
        return self._log.get(rid, [])

    def transition(self, rid: str, dst: LifecycleState, action: str = "",
                   duration_ms: float = 0.0) -> None:
        src = self.state(rid)
        if dst not in _LEGAL[src]:
            raise LifecycleError(
                f"illegal lifecycle transition {src.value} -> {dst.value} "
                f"for {rid} (action={action!r})")
        self._states[rid] = dst
        self._log.setdefault(rid, []).append(
            Transition(src.value, dst.value, action, time.time(), duration_ms))

    # convenience wrappers mirroring the paper's verbs -----------------------
    def prepare(self, rid: str) -> None:
        if self.state(rid) == LifecycleState.READY:
            self.transition(rid, LifecycleState.PREPARING, "re-prepare")
        else:
            self.transition(rid, LifecycleState.PREPARING, "prepare")

    def ready(self, rid: str) -> None:
        self.transition(rid, LifecycleState.READY, "ready")

    def run(self, rid: str) -> None:
        self.transition(rid, LifecycleState.RUNNING, "invoke")

    def complete(self, rid: str, needs_reset: bool = False) -> None:
        dst = LifecycleState.NEEDS_RESET if needs_reset else LifecycleState.READY
        self.transition(rid, dst, "complete")

    def fail(self, rid: str, why: str = "") -> None:
        self.transition(rid, LifecycleState.FAILED, f"fail:{why}")

    def recover(self, rid: str, mode: str = "reset") -> None:
        self.transition(rid, LifecycleState.RECOVERING, mode)
        self.transition(rid, LifecycleState.READY, f"{mode}-done")


class LifecycleError(RuntimeError):
    pass
