"""Lifecycle plane: explicit state machines per substrate (requirement R4).

Physical substrates are not always-ready resources — warm-up, priming,
calibration, reset, cooldown and recovery are part of the effective
execution cost.  The manager enforces legal transitions and records their
wall-clock cost (surfaced in RQ3 as control-path overhead).

Concurrency model: every resource has its own reentrant lock (``lock``),
so concurrent prepare/recover transitions are serialized *per substrate*
rather than globally.  Substrates whose policy allows ``max_concurrent > 1``
can have overlapping invocations: ``run``/``complete`` keep a per-resource
active-session count, and only the last session out performs the
RUNNING → READY/NEEDS_RESET transition (a reset requested by any
overlapping session is remembered until then).
"""
from __future__ import annotations

import dataclasses
import enum
import threading
from typing import Dict, List, Optional, Tuple

from repro.core.simclock import Clock, SYSTEM_CLOCK


class LifecycleState(enum.Enum):
    UNINITIALIZED = "uninitialized"
    PREPARING = "preparing"        # warm-up / priming / calibration
    READY = "ready"
    RUNNING = "running"
    NEEDS_RESET = "needs_reset"    # must flush/recharge/rest before reuse
    RECOVERING = "recovering"
    COOLDOWN = "cooldown"
    FAILED = "failed"
    RETIRED = "retired"


_LEGAL: Dict[LifecycleState, Tuple[LifecycleState, ...]] = {
    LifecycleState.UNINITIALIZED: (LifecycleState.PREPARING,),
    LifecycleState.PREPARING: (LifecycleState.READY, LifecycleState.FAILED),
    LifecycleState.READY: (LifecycleState.RUNNING, LifecycleState.PREPARING,
                           LifecycleState.RETIRED, LifecycleState.FAILED),
    LifecycleState.RUNNING: (LifecycleState.READY, LifecycleState.NEEDS_RESET,
                             LifecycleState.COOLDOWN, LifecycleState.FAILED),
    LifecycleState.NEEDS_RESET: (LifecycleState.RECOVERING,
                                 LifecycleState.FAILED),
    LifecycleState.RECOVERING: (LifecycleState.READY, LifecycleState.FAILED),
    LifecycleState.COOLDOWN: (LifecycleState.READY,),
    LifecycleState.FAILED: (LifecycleState.RECOVERING, LifecycleState.RETIRED),
    LifecycleState.RETIRED: (),
}


@dataclasses.dataclass
class Transition:
    src: str
    dst: str
    action: str
    at: float
    duration_ms: float = 0.0


class LifecycleManager:
    def __init__(self, clock: Optional[Clock] = None):
        # injectable timebase: transition log stamps are virtual under the
        # scenario simulator, wall on a live plane
        self.clock: Clock = clock or SYSTEM_CLOCK
        self._states: Dict[str, LifecycleState] = {}
        self._log: Dict[str, List[Transition]] = {}
        self._active: Dict[str, int] = {}
        self._pending_reset: Dict[str, bool] = {}
        self._rid_locks: Dict[str, threading.RLock] = {}
        self._global = threading.Lock()

    def lock(self, rid: str) -> threading.RLock:
        """Per-resource reentrant lock; hold it to make a multi-step
        lifecycle sequence (recover → prepare → ready) atomic for ``rid``
        without serializing unrelated substrates."""
        with self._global:
            lk = self._rid_locks.get(rid)
            if lk is None:
                lk = self._rid_locks[rid] = threading.RLock()
            return lk

    def state(self, rid: str) -> LifecycleState:
        with self._global:
            return self._states.get(rid, LifecycleState.UNINITIALIZED)

    def active_sessions(self, rid: str) -> int:
        with self._global:
            return self._active.get(rid, 0)

    def history(self, rid: str) -> List[Transition]:
        with self._global:
            return list(self._log.get(rid, []))

    def _append(self, rid: str, tr: Transition) -> None:
        with self._global:
            self._log.setdefault(rid, []).append(tr)

    def transition(self, rid: str, dst: LifecycleState, action: str = "",
                   duration_ms: float = 0.0) -> None:
        with self.lock(rid):
            src = self.state(rid)
            if dst not in _LEGAL[src]:
                raise LifecycleError(
                    f"illegal lifecycle transition {src.value} -> {dst.value} "
                    f"for {rid} (action={action!r})")
            with self._global:
                self._states[rid] = dst
                self._log.setdefault(rid, []).append(
                    Transition(src.value, dst.value, action, self.clock.now(),
                               duration_ms))

    # convenience wrappers mirroring the paper's verbs -----------------------
    def prepare(self, rid: str) -> None:
        with self.lock(rid):
            if self.state(rid) == LifecycleState.READY:
                self.transition(rid, LifecycleState.PREPARING, "re-prepare")
            else:
                self.transition(rid, LifecycleState.PREPARING, "prepare")

    def ready(self, rid: str) -> None:
        self.transition(rid, LifecycleState.READY, "ready")

    def run(self, rid: str) -> None:
        """Enter RUNNING; overlapping entry is legal for substrates whose
        policy admits several concurrent sessions (tracked by count)."""
        with self.lock(rid):
            if (self.state(rid) == LifecycleState.RUNNING
                    and self.active_sessions(rid) > 0):
                with self._global:
                    self._active[rid] += 1
                self._append(rid, Transition("running", "running",
                                             "invoke-overlap", self.clock.now()))
                return
            self.transition(rid, LifecycleState.RUNNING, "invoke")
            with self._global:
                self._active[rid] = 1

    def complete(self, rid: str, needs_reset: bool = False) -> None:
        """Leave RUNNING; only the last overlapping session transitions the
        substrate state, honoring any reset requested while overlapped."""
        with self.lock(rid):
            with self._global:
                remaining = max(0, self._active.get(rid, 1) - 1)
                self._active[rid] = remaining
            if self.state(rid) == LifecycleState.FAILED:
                # a concurrent session already failed the substrate; this
                # session's completion is bookkeeping only — do NOT record a
                # pending reset (recovery from FAILED resets anyway, and a
                # stale flag would force a spurious NEEDS_RESET later)
                self._append(rid, Transition("failed", "failed",
                                             "complete-after-fail",
                                             self.clock.now()))
                return
            if needs_reset:
                with self._global:
                    self._pending_reset[rid] = True
            if remaining > 0:
                self._append(rid, Transition("running", "running",
                                             "complete-overlap", self.clock.now()))
                return
            with self._global:
                pending = self._pending_reset.pop(rid, False)
            dst = (LifecycleState.NEEDS_RESET if pending
                   else LifecycleState.READY)
            self.transition(rid, dst, "complete")

    def fail(self, rid: str, why: str = "", held_slot: bool = False) -> None:
        """Mark the substrate FAILED.  ``held_slot=True`` releases the
        failing session's own RUNNING slot; slots of other sessions still
        in flight are preserved so their complete() stays balanced."""
        with self.lock(rid):
            if self.state(rid) == LifecycleState.FAILED:
                self._append(rid, Transition("failed", "failed",
                                             f"fail:{why}", self.clock.now()))
            else:
                self.transition(rid, LifecycleState.FAILED, f"fail:{why}")
            with self._global:
                if held_slot:
                    self._active[rid] = max(0, self._active.get(rid, 0) - 1)
                self._pending_reset.pop(rid, None)

    def recover(self, rid: str, mode: str = "reset") -> None:
        with self.lock(rid):
            self.transition(rid, LifecycleState.RECOVERING, mode)
            self.transition(rid, LifecycleState.READY, f"{mode}-done")

    def reopen(self, rid: str, mode: str = "reset") -> bool:
        """Recover-on-reopen for the health manager: when a circuit breaker
        half-opens, a substrate parked in NEEDS_RESET or FAILED is recovered
        before the first probation probe — but never while sessions are
        still on the hardware.  Returns True iff a recovery ran."""
        with self.lock(rid):
            if self.active_sessions(rid) > 0:
                return False
            if self.state(rid) in (LifecycleState.NEEDS_RESET,
                                   LifecycleState.FAILED):
                self.recover(rid, mode)
                return True
            return False


class LifecycleError(RuntimeError):
    pass
