"""End-to-end control plane: match → admit → prepare → invoke → validate →
(fallback | complete)  (paper §IV-D, §VII-A).

The orchestrator validates postconditions after invocation — required
telemetry present, health/validity bounds respected, stabilization-time
honored — and reroutes to a fallback backend after preparation failures,
invocation failures, or postcondition violations (RQ2, Table IV).

Executable-twin tier: tasks may opt in (``TaskRequest.twin_mode``) to
shadow execution (the twin runs concurrently with the real invocation and
the measured divergence feeds twin confidence/fidelity and the
HealthManager) or twin-served fallback (a VALID twin answers instead of a
rejection when hardware is quarantined or saturated, with ``served_by:
twin`` provenance on the trace and result telemetry).  Speculative serving
lives on the scheduler (``submit_speculative``).

Concurrency: :meth:`execute` is safe to call from many threads at once —
per-substrate admission uses deadline-aware blocking acquisition, lifecycle
transitions are serialized per resource, and live queue-depth telemetry is
maintained so the matcher steers new tasks away from saturated substrates.
``submit`` stays the one-shot synchronous entry point; sustained workloads
go through :class:`repro.core.scheduler.ControlPlaneScheduler`, which feeds
``execute`` from a worker pool.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

from repro.core.descriptors import ResourceDescriptor
from repro.core.errors import AdmissionRefused, ErrorCode, classify_rejection
from repro.core.health import HealthManager
from repro.core.invocation import (InvocationError, InvocationManager,
                                   InvocationResult)
from repro.core.lifecycle import LifecycleManager
from repro.core.matcher import Candidate, Matcher
from repro.core.policy import PolicyManager
from repro.core.registry import CapabilityRegistry
from repro.core.simclock import Clock, SYSTEM_CLOCK
from repro.core.tasks import TaskRequest
from repro.core.telemetry import TelemetryBus, TelemetryEvent
from repro.core.topology import PlaneTopology
from repro.core.twin import TwinSyncManager
from repro.core.twin_executor import TwinExecutor


@dataclasses.dataclass
class OrchestrationTrace:
    """Explainable record of one task's path through the control plane.

    ``control_overhead_ms`` counts control-plane *work* (matching, policy,
    lifecycle bookkeeping); time spent blocked waiting for a substrate
    concurrency slot is backpressure, not overhead, and is reported
    separately as ``queue_wait_ms``.  A trace is owned by the single
    worker executing its task (it needs no locking and stays a plain
    serializable dataclass — ``dataclasses.asdict`` works).
    """

    task_id: str
    attempts: List[Dict] = dataclasses.field(default_factory=list)
    selected: Optional[str] = None
    fallback_used: bool = False
    rejected_reason: Optional[str] = None
    #: structured taxonomy code matching ``rejected_reason`` (wire protocol
    #: v1); None while the task has not been rejected
    error_code: Optional[str] = None
    control_overhead_ms: float = 0.0
    queue_wait_ms: float = 0.0
    #: provenance: "substrate" (real hardware) or "twin" (served by an
    #: executable digital twin — degraded-confidence accounting applies)
    served_by: str = "substrate"
    #: twin confidence captured atomically at serve time (twin serves only)
    twin_confidence: Optional[float] = None
    #: measured twin-vs-real divergence for shadow-mode tasks (None when the
    #: twin could not answer or the task did not opt in)
    shadow_divergence: Optional[float] = None

    def add_control_ms(self, ms: float) -> None:
        self.control_overhead_ms += ms

    def add_queue_wait_ms(self, ms: float) -> None:
        self.queue_wait_ms += ms

    def record_attempt(self, entry: Dict) -> Dict:
        self.attempts.append(entry)
        return entry

    # -- wire forms -----------------------------------------------------------
    def to_wire(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_wire(cls, d: Dict) -> "OrchestrationTrace":
        from repro.core.descriptors import known_fields

        return cls(**known_fields(cls, d))


class Orchestrator:
    MAX_ATTEMPTS = 3
    #: how long ``execute`` may block waiting for a substrate concurrency
    #: slot when the task carries no latency budget (seconds)
    DEFAULT_ACQUIRE_TIMEOUT_S = 30.0

    #: queue-saturation threshold for twin-served fallback: an opted-in task
    #: whose best candidate has more than this many queued sessions per
    #: concurrency slot is served by a valid twin instead of waiting
    #: (None disables the proactive path; the reject path stays active)
    TWIN_FALLBACK_QUEUE_FACTOR = 3.0

    def __init__(self, registry: Optional[CapabilityRegistry] = None,
                 matcher_cls=Matcher,
                 acquire_timeout_s: float = DEFAULT_ACQUIRE_TIMEOUT_S,
                 health=True,
                 twin_fallback_queue_factor: Optional[float]
                 = TWIN_FALLBACK_QUEUE_FACTOR,
                 plane: str = "plane",
                 clock: Optional[Clock] = None):
        # one injectable timebase for the whole plane: telemetry stamps,
        # twin staleness, health cooldowns, admission deadlines.  Virtual
        # under the scenario simulator; SYSTEM_CLOCK in production.
        self.clock: Clock = clock or SYSTEM_CLOCK
        self.registry = registry or CapabilityRegistry()
        self.bus = TelemetryBus(clock=self.clock)
        # plane identity + federation graph (multi-hop cycle detection);
        # the gateway serves it at /v1/topology and renames it to its plane
        self.topology = PlaneTopology(plane)
        # descriptor change feed: every register/unregister surfaces as a
        # first-class "registry" telemetry event (epoch + wire descriptor),
        # so parent planes following this plane's stream track fleet
        # membership live instead of re-fetching on breaker reopen
        self.registry.subscribe(self._on_fleet_change)
        self.twins = TwinSyncManager(self.bus, clock=self.clock)
        self.twin_exec = TwinExecutor(self.twins, self.bus)
        self.twin_fallback_queue_factor = twin_fallback_queue_factor
        self.policy = PolicyManager()
        self.lifecycle = LifecycleManager(clock=self.clock)
        self.acquire_timeout_s = acquire_timeout_s
        # telemetry-driven recovery loop: ``health=True`` (default) builds a
        # HealthManager with default thresholds, a dict forwards constructor
        # overrides (cooldown_s, probes_to_close, ...), False disables it
        self.health: Optional[HealthManager] = None
        if health is not False and health is not None:
            kw = dict(health) if isinstance(health, dict) else {}
            kw.setdefault("clock", self.clock.monotonic)
            self.health = HealthManager(self.bus, self.policy, self.registry,
                                        recoverer=self._reopen_resource, **kw)
        self.matcher: Matcher = matcher_cls(self.registry, self.bus,
                                            self.twins, self.policy,
                                            health=self.health)
        self.invocations = InvocationManager(self.registry, self.lifecycle,
                                             self.bus)

    def _on_fleet_change(self, action: str, desc, epoch: int) -> None:
        self.bus.emit(TelemetryEvent(desc.resource_id, "registry", {
            "action": action,
            "epoch": epoch,
            "plane_id": self.topology.plane_id,
            "descriptor": desc.to_dict(),
        }))

    def _reopen_resource(self, rid: str) -> bool:
        """Recover-on-reopen hook for the health manager: re-arm a substrate
        whose breaker just half-opened.  A physical reset runs whenever the
        substrate is idle — a breaker trips on *misbehavior* (error rate,
        drift, postconditions), which lifecycle state alone may not reflect
        (a drifted crossbar sits READY) — plus the lifecycle recovery when
        it is parked in NEEDS_RESET/FAILED.  Never resets under live
        sessions.  A fresh runtime snapshot is published so the matcher
        sees post-reset drift/health before the first probation probe."""
        desc = self.registry.get(rid)
        adapter = self.registry.adapter(rid)
        if desc is None or adapter is None:
            return False
        modes = desc.capability.lifecycle.recovery_modes
        mode = modes[0] if modes else "soft"
        with self.lifecycle.lock(rid):
            if self.lifecycle.active_sessions(rid) > 0:
                return False
            adapter.reset(mode)
            self.lifecycle.reopen(rid, mode)
        snap = adapter.snapshot()
        if snap is not None:
            self.bus.update_snapshot(snap)
        return True

    # -- postconditions -------------------------------------------------------
    def _postconditions(self, result: InvocationResult, session) -> Optional[str]:
        ok, missing = session.contracts.telemetry.validate(result.telemetry)
        if not ok:
            return f"missing required telemetry: {missing}"
        health = result.telemetry.get("health_status", "healthy")
        if health == "failed":
            return "backend reported failed health after invocation"
        obs = result.timing_ms.get("observation_ms", 0.0)
        if not session.contracts.timing.result_authoritative(obs):
            return (f"observation {obs:.1f}ms below stabilization bound "
                    f"{session.contracts.timing.min_stabilization_ms}ms")
        return None

    # -- main entry -----------------------------------------------------------
    def submit(self, task: TaskRequest
               ) -> Tuple[InvocationResult, OrchestrationTrace]:
        """One-shot synchronous submission (compatibility wrapper around
        :meth:`execute`)."""
        return self.execute(task)

    def execute(self, task: TaskRequest, deadline: Optional[float] = None
                ) -> Tuple[InvocationResult, OrchestrationTrace]:
        """Run one task through match → admit → invoke → validate, with
        fallback.  ``deadline`` (``time.monotonic`` timestamp) bounds how
        long admission may block on a busy substrate; without one, the
        task's latency budget (or the orchestrator default) applies.
        """
        trace = OrchestrationTrace(task.task_id)
        # multi-hop budget floor: a task whose end-to-end deadline budget
        # was fully consumed in transit (or that arrived with a negative
        # hop budget — a buggy or hostile forwarder) terminates here with
        # the structured DEADLINE outcome instead of burning substrate time
        if task.hop_budget is not None and task.hop_budget < 0:
            return self._reject_or_twin(
                task, trace, f"hop budget exhausted in transit "
                f"(route {list(task.route)})", code=ErrorCode.DEADLINE)
        if task.deadline_budget_ms is not None and task.deadline_budget_ms <= 0:
            return self._reject_or_twin(
                task, trace, f"deadline budget exhausted in transit "
                f"({task.deadline_budget_ms:.1f}ms remaining after "
                f"{len(task.route)} hops)", code=ErrorCode.DEADLINE)
        if deadline is None and task.deadline_budget_ms is not None:
            # a forwarded task's remaining end-to-end budget bounds local
            # admission exactly like a client latency budget would
            deadline = self.clock.monotonic() + task.deadline_budget_ms / 1e3
        if deadline is None and task.latency_budget_ms is not None:
            # pin the budget to a fixed deadline once, so repeated fallback
            # attempts share it instead of each getting a fresh full budget
            deadline = self.clock.monotonic() + task.latency_budget_ms / 1e3
        t_ctl = time.perf_counter()
        tried: set = set()
        cand = self.matcher.select(task)
        # initial match cost is control overhead on EVERY path (success,
        # fallback, rejection), not just rejection
        trace.add_control_ms((time.perf_counter() - t_ctl) * 1e3)

        served = self._twin_if_saturated(task, trace, cand)
        if served is not None:
            return served, trace

        for attempt in range(self.MAX_ATTEMPTS):
            if cand is None:
                t_rej = time.perf_counter()
                reasons = {c.resource_id: c.reason
                           for c in self.matcher.rank(task) if not c.admissible}
                reason = ("no acceptable backend candidate: "
                          + "; ".join(f"{r}={why}"
                                      for r, why in reasons.items()))
                # keep the cause of the LAST attempt in the rejection: a
                # candidate that was tried and failed is admissible, so its
                # failure (e.g. a downstream plane's structured DEADLINE)
                # would otherwise vanish from the reason — and from the
                # wire classification
                last_failure = next(
                    (a.get("failure") for a in reversed(trace.attempts)
                     if a.get("failure")), None)
                if last_failure:
                    reason += f"; last attempt: {last_failure}"
                trace.add_control_ms((time.perf_counter() - t_rej) * 1e3)
                return self._reject_or_twin(task, trace, reason)
            rid = cand.resource_id
            tried.add(rid)
            desc = self.registry.get(rid)
            trace.record_attempt({"resource": rid, "score": cand.score,
                                  "terms": cand.terms})
            if desc is None:
                # fleet changed between ranking and attempt (concurrent
                # unregister): treat like any other attempt failure
                result, failure, spill = None, "resource unregistered", None
            else:
                # shadow mode: the twin answers the same task concurrently
                # with the real invocation (executor pool vs this worker);
                # the measured divergence feeds confidence/fidelity/health
                shadow_fut = None
                if task.twin_mode == "shadow":
                    shadow_fut = self.twin_exec.shadow_start(task, rid)
                result, failure, spill = self._attempt(task, desc, trace,
                                                       deadline, tried)
                if failure is None and result is not None:
                    self.twin_exec.observe(task, rid, result)
                    if shadow_fut is not None:
                        trace.shadow_divergence = self.twin_exec.shadow_finish(
                            task, rid, result, shadow_fut)
                        if trace.shadow_divergence is not None:
                            result.telemetry.setdefault(
                                "shadow_divergence",
                                round(trace.shadow_divergence, 6))
                elif shadow_fut is not None:
                    self.twin_exec.shadow_abandon(shadow_fut)

            if failure is None:
                trace.selected = rid
                trace.fallback_used = attempt > 0
                return result, trace

            trace.attempts[-1]["failure"] = failure
            if not task.allow_fallback:
                return self._reject_or_twin(task, trace, failure)
            t_fb = time.perf_counter()
            cand = spill if spill is not None else \
                self._next_candidate(task, tried)
            trace.add_control_ms((time.perf_counter() - t_fb) * 1e3)

        return self._reject_or_twin(task, trace,
                                    "fallback attempts exhausted")

    # -- twin-served fallback -------------------------------------------------
    @staticmethod
    def _mark_twin_served(trace: OrchestrationTrace, served) -> None:
        trace.selected = served.resource_id
        trace.served_by = "twin"
        trace.twin_confidence = served.telemetry.get("twin_confidence")
        trace.fallback_used = True
        trace.rejected_reason = None

    def _twin_if_saturated(self, task: TaskRequest, trace: OrchestrationTrace,
                           cand: Optional[Candidate]):
        """Proactive twin serving: an opted-in task whose best candidate is
        queue-saturated past the policy threshold gets a valid-twin answer
        instead of joining the waiting line."""
        if (cand is None or task.twin_mode != "fallback"
                or self.twin_fallback_queue_factor is None):
            return None
        desc = self.registry.get(cand.resource_id)
        if desc is None:
            return None
        depth = self.bus.queue_depth(cand.resource_id)
        limit = (self.twin_fallback_queue_factor
                 * max(1, desc.capability.policy.max_concurrent))
        if depth < limit:
            return None
        served, _ = self.twin_exec.serve_fallback(
            task, self.matcher,
            f"queue saturated (depth {depth} >= {limit:.0f})")
        if served is not None:
            self._mark_twin_served(trace, served)
        return served

    def _reject_or_twin(self, task: TaskRequest, trace: OrchestrationTrace,
                        reason: str, code: Optional[ErrorCode] = None
                        ) -> Tuple[InvocationResult, OrchestrationTrace]:
        """Terminal rejection funnel: tasks that opted in (twin_mode
        "fallback" — an explicit opt-in, honored even when substrate
        fallback is disallowed) are served by a VALID twin instead of
        rejected; twin refusal reasons (staleness, invalidation, missing
        telemetry) are appended to the rejection message.

        ``code`` is the structured taxonomy outcome; classified from the
        prose reason when the caller doesn't pass one.  The code reflects
        the ORIGINAL rejection cause even when twin refusals are appended
        (a breaker-open task whose twin also refused is still
        BREAKER_OPEN on the wire)."""
        if code is None:
            code = classify_rejection(reason)
        if task.twin_mode == "fallback":
            served, refusals = self.twin_exec.serve_fallback(
                task, self.matcher, reason)
            if served is not None:
                self._mark_twin_served(trace, served)
                return served, trace
            reason = (reason + "; twin fallback unavailable: "
                      + "; ".join(refusals))
        trace.rejected_reason = reason
        trace.error_code = code.value
        return self.invocations.rejected(task, reason, code=code), trace

    def _acquire_timeout(self, task: TaskRequest,
                         deadline: Optional[float]) -> float:
        """Deadline-aware admission budget: remaining time to the caller's
        deadline (``execute`` pins the task latency budget to one), else
        the orchestrator default.  Returns seconds (<= 0: non-blocking)."""
        if deadline is not None:
            return deadline - self.clock.monotonic()
        return self.acquire_timeout_s

    #: floor for how long admission waits on a busy substrate before
    #: considering a spill to an alternative backend (seconds)
    MIN_ACQUIRE_PATIENCE_S = 0.02

    def _acquire_with_patience(self, task: TaskRequest,
                               desc: ResourceDescriptor,
                               deadline: Optional[float],
                               tried: set
                               ) -> Tuple[bool, Optional[Candidate], float]:
        """Deadline-aware blocking admission with bounded patience.

        Block roughly two service times for a slot; if the substrate is
        still saturated and another admissible backend exists, give up so
        the caller spills there (keeping workers productive instead of
        camped on one semaphore).  With no alternative, camp for the full
        remaining deadline — contention must become queueing, not a
        spurious "concurrency limit" rejection.

        Returns ``(acquired, spill_candidate, rank_ms)``; the spill
        candidate is the ranked alternative found while probing, handed
        back so the caller does not repeat the rank, and ``rank_ms`` is the
        matching work spent probing (control overhead, not queue wait).
        """
        remaining = self._acquire_timeout(task, deadline)
        patience = remaining
        if task.allow_fallback:
            exp_s = desc.capability.timing.expected_latency_ms / 1e3
            patience = min(remaining,
                           max(self.MIN_ACQUIRE_PATIENCE_S, 2.0 * exp_s))
        t0 = self.clock.monotonic()
        if self.policy.acquire(desc, patience):
            return True, None, 0.0
        if patience >= remaining:
            return False, None, 0.0
        t_rank = time.perf_counter()
        alt = self._next_candidate(task, tried)
        rank_ms = (time.perf_counter() - t_rank) * 1e3
        if alt is not None:
            return False, alt, rank_ms   # spill: an alternative can take it
        rest = remaining - (self.clock.monotonic() - t0)
        return self.policy.acquire(desc, rest), None, rank_ms

    def _attempt(self, task: TaskRequest, desc: ResourceDescriptor,
                 trace: OrchestrationTrace, deadline: Optional[float],
                 tried: set) -> Tuple[Optional[InvocationResult], Optional[str],
                                      Optional[Candidate]]:
        """One prepare→invoke→validate attempt against a chosen substrate.
        Returns (result, failure_reason, spill_candidate): failure_reason is
        None on success; spill_candidate carries the pre-ranked fallback
        when admission spilled, so the caller skips a redundant rank."""
        rid = desc.resource_id
        result = None
        self.bus.adjust_queue_depth(rid, +1)
        t_wait = time.perf_counter()
        try:
            acquired, spill, rank_ms = self._acquire_with_patience(
                task, desc, deadline, tried)
            # the spill-probe rank is matching work, not backpressure
            trace.add_control_ms(rank_ms)
            wait_ms = max(0.0, (time.perf_counter() - t_wait) * 1e3 - rank_ms)
            if not acquired:
                trace.add_queue_wait_ms(wait_ms)
                return None, "concurrency limit", spill
            trace.add_queue_wait_ms(wait_ms)
            # breaker gate: a quarantined resource refuses outright (the
            # matcher raced a trip), probation reserves a probe slot so the
            # re-admission trickle stays bounded
            health_token = None
            if self.health is not None:
                allowed, health_token, why = self.health.begin_attempt(rid)
                if not allowed:
                    self.policy.release(desc)
                    return None, why, None
            t0 = time.perf_counter()
            failure = None
            attempt_ok = False
            try:
                session = self.invocations.open_session(task, desc)
                self.invocations.prepare(session)
                result = self.invocations.invoke(session)
                post = self._postconditions(result, session)
                if post is not None:
                    failure = f"postcondition: {post}"
                    result.status = "invalidated"
                    self.twins.invalidate(rid, post)
                attempt_ok = failure is None
            except AdmissionRefused as e:
                # predictive refusal (e.g. roofline admission: the substrate
                # cannot finish inside the deadline budget).  Not a substrate
                # fault: the attempt counts as ok for the breaker, and the
                # prose keeps the refusal's classifier needles so the final
                # rejection classifies to the refusal's code (e.g. DEADLINE)
                failure = f"admission refused: {e}"
                attempt_ok = True
            except InvocationError as e:
                failure = f"{e.phase} failure: {e}"
            finally:
                self.policy.release(desc)
                if self.health is not None:
                    self.health.finish_attempt(
                        health_token, ok=attempt_ok,
                        kind=failure or "exception",
                        latency_ms=(time.perf_counter() - t0) * 1e3)
            elapsed_ms = (time.perf_counter() - t0) * 1e3
            if result is not None:
                # control overhead excludes the backend execution itself
                elapsed_ms -= result.timing_ms.get("backend_ms", 0.0)
            trace.add_control_ms(max(0.0, elapsed_ms))
            return result, failure, None
        finally:
            self.bus.adjust_queue_depth(rid, -1)

    def _next_candidate(self, task: TaskRequest, tried: set) -> Optional[Candidate]:
        # fallback ignores the directed preference: capability-based rerank
        # (clone() un-aliases metadata so the caller's dict stays untouched)
        if hasattr(task, "clone"):
            free_task = task.clone(backend_preference=None)
        else:
            free_task = task
            free_task.backend_preference = None
        ranked = [c for c in self.matcher.rank(free_task)
                  if c.admissible and c.resource_id not in tried]
        return ranked[0] if ranked else None

    # -- convenience ----------------------------------------------------------
    def discover(self, **query) -> List[ResourceDescriptor]:
        return self.registry.discover(**query)

    def register(self, adapter) -> ResourceDescriptor:
        desc = adapter.descriptor()
        self.registry.register(desc, adapter)
        twin = adapter.make_twin()
        if twin is not None:
            self.twins.register(twin)
        snap = adapter.snapshot()
        if snap is not None:
            self.bus.update_snapshot(snap)
        return desc

    def unregister(self, resource_id: str) -> None:
        """Remove a resource from the fleet (the registry listener pushes
        the change onto the bus as a ``registry`` event — parent planes
        following the stream see the membership change live)."""
        adapter = self.registry.adapter(resource_id)
        self.registry.unregister(resource_id)
        if adapter is not None and hasattr(adapter, "close"):
            try:
                adapter.close()
            except Exception:                              # noqa: BLE001
                pass
