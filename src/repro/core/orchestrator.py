"""End-to-end control plane: match → admit → prepare → invoke → validate →
(fallback | complete)  (paper §IV-D, §VII-A).

The orchestrator validates postconditions after invocation — required
telemetry present, health/validity bounds respected, stabilization-time
honored — and reroutes to a fallback backend after preparation failures,
invocation failures, or postcondition violations (RQ2, Table IV).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

from repro.core.descriptors import ResourceDescriptor
from repro.core.invocation import (InvocationError, InvocationManager,
                                   InvocationResult)
from repro.core.lifecycle import LifecycleManager
from repro.core.matcher import Candidate, Matcher
from repro.core.policy import PolicyManager
from repro.core.registry import CapabilityRegistry
from repro.core.tasks import TaskRequest
from repro.core.telemetry import TelemetryBus
from repro.core.twin import TwinSyncManager


@dataclasses.dataclass
class OrchestrationTrace:
    """Explainable record of one task's path through the control plane."""

    task_id: str
    attempts: List[Dict] = dataclasses.field(default_factory=list)
    selected: Optional[str] = None
    fallback_used: bool = False
    rejected_reason: Optional[str] = None
    control_overhead_ms: float = 0.0


class Orchestrator:
    MAX_ATTEMPTS = 3

    def __init__(self, registry: Optional[CapabilityRegistry] = None,
                 matcher_cls=Matcher):
        self.registry = registry or CapabilityRegistry()
        self.bus = TelemetryBus()
        self.twins = TwinSyncManager(self.bus)
        self.policy = PolicyManager()
        self.lifecycle = LifecycleManager()
        self.matcher: Matcher = matcher_cls(self.registry, self.bus,
                                            self.twins, self.policy)
        self.invocations = InvocationManager(self.registry, self.lifecycle,
                                             self.bus)

    # -- postconditions -------------------------------------------------------
    def _postconditions(self, result: InvocationResult, session) -> Optional[str]:
        ok, missing = session.contracts.telemetry.validate(result.telemetry)
        if not ok:
            return f"missing required telemetry: {missing}"
        health = result.telemetry.get("health_status", "healthy")
        if health == "failed":
            return "backend reported failed health after invocation"
        obs = result.timing_ms.get("observation_ms", 0.0)
        if not session.contracts.timing.result_authoritative(obs):
            return (f"observation {obs:.1f}ms below stabilization bound "
                    f"{session.contracts.timing.min_stabilization_ms}ms")
        return None

    # -- main entry -----------------------------------------------------------
    def submit(self, task: TaskRequest) -> (InvocationResult, OrchestrationTrace):
        trace = OrchestrationTrace(task.task_id)
        t_ctl = time.perf_counter()
        tried: set = set()
        cand = self.matcher.select(task)
        control_ms = (time.perf_counter() - t_ctl) * 1e3

        for attempt in range(self.MAX_ATTEMPTS):
            if cand is None:
                reasons = {c.resource_id: c.reason
                           for c in self.matcher.rank(task) if not c.admissible}
                trace.rejected_reason = (
                    "no acceptable backend candidate: "
                    + "; ".join(f"{r}={why}" for r, why in reasons.items()))
                trace.control_overhead_ms += control_ms
                return (self.invocations.rejected(task, trace.rejected_reason),
                        trace)
            rid = cand.resource_id
            tried.add(rid)
            desc = self.registry.get(rid)
            trace.attempts.append({"resource": rid, "score": cand.score,
                                   "terms": cand.terms})
            t0 = time.perf_counter()
            if not self.policy.acquire(desc):
                failure = "concurrency limit"
            else:
                failure = None
                try:
                    session = self.invocations.open_session(task, desc)
                    self.invocations.prepare(session)
                    result = self.invocations.invoke(session)
                    post = self._postconditions(result, session)
                    if post is not None:
                        failure = f"postcondition: {post}"
                        result.status = "invalidated"
                        self.twins.invalidate(rid, post)
                except InvocationError as e:
                    failure = f"{e.phase} failure: {e}"
                finally:
                    self.policy.release(desc)
            trace.control_overhead_ms += (time.perf_counter() - t0) * 1e3

            if failure is None:
                trace.selected = rid
                trace.fallback_used = attempt > 0
                # control overhead excludes the backend execution itself
                trace.control_overhead_ms -= result.timing_ms.get("backend_ms", 0.0)
                return result, trace

            trace.attempts[-1]["failure"] = failure
            if not task.allow_fallback:
                trace.rejected_reason = failure
                return self.invocations.rejected(task, failure), trace
            cand = self._next_candidate(task, tried)

        trace.rejected_reason = "fallback attempts exhausted"
        return self.invocations.rejected(task, trace.rejected_reason), trace

    def _next_candidate(self, task: TaskRequest, tried: set) -> Optional[Candidate]:
        # fallback ignores the directed preference: capability-based rerank
        free_task = dataclasses.replace(task) if dataclasses.is_dataclass(task) else task
        free_task.backend_preference = None
        ranked = [c for c in self.matcher.rank(free_task)
                  if c.admissible and c.resource_id not in tried]
        return ranked[0] if ranked else None

    # -- convenience ----------------------------------------------------------
    def discover(self, **query) -> List[ResourceDescriptor]:
        return self.registry.discover(**query)

    def register(self, adapter) -> ResourceDescriptor:
        desc = adapter.descriptor()
        self.registry.register(desc, adapter)
        twin = adapter.make_twin()
        if twin is not None:
            self.twins.register(twin)
        snap = adapter.snapshot()
        if snap is not None:
            self.bus.update_snapshot(snap)
        return desc
