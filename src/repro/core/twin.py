"""Twin plane: synchronized, validity-aware digital state (paper §IV-A).

The twin is *not* the substrate: its value depends on how current it is and
how well it matches observed behavior.  :class:`TwinState` tracks sync
metadata, confidence and drift; :class:`TwinSyncManager` consumes telemetry
events and flags stale/diverged twins so the matcher can condition placement
on twin validity (requirement R5).

For the TPU pod substrate the twin is the roofline model over the compiled
artifact — the high-fidelity end of the paper's twin spectrum (DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, Optional

from repro.core.telemetry import TelemetryBus, TelemetryEvent


@dataclasses.dataclass
class TwinState:
    twin_id: str
    resource_id: str
    kind: str = "behavioral"               # ode | behavioral | roofline | record
    confidence: float = 1.0                # decays with drift & staleness
    drift_estimate: float = 0.0
    last_sync: float = dataclasses.field(default_factory=time.time)
    calibration_ts: float = dataclasses.field(default_factory=time.time)
    observations: int = 0
    model: Dict = dataclasses.field(default_factory=dict)   # twin parameters

    def age_ms(self) -> float:
        return (time.time() - self.last_sync) * 1e3

    def valid(self, max_age_ms: Optional[float], min_confidence: float = 0.3):
        if max_age_ms is not None and self.age_ms() > max_age_ms:
            return False, f"twin stale ({self.age_ms():.0f}ms > {max_age_ms}ms)"
        if self.confidence < min_confidence:
            return False, f"twin confidence {self.confidence:.2f} < {min_confidence}"
        return True, "ok"

    def to_dict(self) -> Dict:
        return {
            "twin_id": self.twin_id, "resource_id": self.resource_id,
            "kind": self.kind, "confidence": round(self.confidence, 4),
            "drift_estimate": round(self.drift_estimate, 4),
            "age_ms": round(self.age_ms(), 2),
            "observations": self.observations,
        }


class TwinSyncManager:
    """Associates telemetry with twin state and updates sync metadata.

    All state updates are serialized under one lock: with the concurrent
    control plane, telemetry-driven confidence updates (``_on_event``) race
    against postcondition invalidation (``invalidate``); unlocked
    read-modify-writes could silently restore confidence to a twin that was
    just invalidated.
    """

    DRIFT_DECAY = 0.85       # confidence multiplier per unit drift observed

    def __init__(self, bus: TelemetryBus):
        self._twins: Dict[str, TwinState] = {}
        self._bus = bus
        self._lock = threading.Lock()
        bus.subscribe(self._on_event)

    def register(self, twin: TwinState) -> TwinState:
        with self._lock:
            self._twins[twin.resource_id] = twin
        return twin

    def get(self, resource_id: str) -> Optional[TwinState]:
        with self._lock:
            return self._twins.get(resource_id)

    def mark_synced(self, resource_id: str, drift: float = 0.0) -> None:
        with self._lock:
            tw = self._twins.get(resource_id)
            if tw is None:
                return
            tw.last_sync = time.time()
            tw.observations += 1
            tw.drift_estimate = drift
            tw.confidence = max(0.0, min(1.0, 1.0 - drift))

    def invalidate(self, resource_id: str, reason: str = "") -> None:
        with self._lock:
            tw = self._twins.get(resource_id)
            if tw is not None:
                tw.confidence = 0.0

    def recalibrate(self, resource_id: str) -> None:
        with self._lock:
            tw = self._twins.get(resource_id)
            if tw is not None:
                tw.calibration_ts = time.time()
                tw.last_sync = time.time()
                tw.drift_estimate = 0.0
                tw.confidence = 1.0

    # -- telemetry coupling ---------------------------------------------------
    def _on_event(self, ev: TelemetryEvent) -> None:
        with self._lock:
            tw = self._twins.get(ev.resource_id)
            if tw is None:
                return
            if ev.kind == "result":
                drift = float(ev.fields.get("drift_score", 0.0))
                tw.last_sync = ev.timestamp
                tw.observations += 1
                tw.drift_estimate = drift
                tw.confidence = max(0.0, min(1.0, tw.confidence *
                                             (self.DRIFT_DECAY ** drift) + 0.05
                                             * (1.0 - drift)))
            elif ev.kind == "drift":
                tw.drift_estimate = float(ev.fields.get("drift_score", 0.0))
                tw.confidence = max(0.0, 1.0 - tw.drift_estimate)
