"""Twin plane: executable, synchronized, validity-aware digital state
(paper §IV-A).

The twin is *not* the substrate: its value depends on how current it is and
how well it matches observed behavior.  :class:`TwinState` tracks sync
metadata, confidence, drift and *measured* fidelity; :class:`TwinSyncManager`
consumes telemetry events and flags stale/diverged/invalidated twins so the
matcher can condition placement on twin validity (requirement R5).

Executable-twin contract
------------------------

Since PR 3 the twin plane is an executable tier, not passive metadata.
Every adapter's ``make_twin()`` may attach a :class:`TwinSurrogate` — an
executable model keyed by ``TwinState.kind``:

- ``ode``        — integrates the same dynamics the physical system realizes
                   (chemical mass-action network);
- ``behavioral`` — mirror of the programmed device/population (ideal
                   crossbar conductances, LIF population with nominal noise);
- ``roofline``   — the compiled cost model plus last-observed training
                   metrics (TPU pod);
- ``record``     — record/replay twin learned from recent invocation
                   results (:class:`RecordReplaySurrogate`).

The surrogate contract:

- ``simulate(task)`` returns the same RAW dict shape as
  ``SubstrateAdapter.invoke`` (``output`` / ``telemetry`` / ``artifacts`` /
  ``backend_ms``), or raises :class:`TwinNotReady` when the twin has not
  learned enough to answer;
- ``observe(task, raw)`` is the learning hook — the orchestrator feeds every
  successful real invocation back so record/roofline twins stay current;
- ``divergence(real_output, twin_output)`` is NORMALIZED (0 = exact
  agreement, ~1 = unusable) and ``tolerance`` declares the acceptable
  divergence for this substrate.

:class:`~repro.core.twin_executor.TwinExecutor` drives surrogates in three
modes (shadow / fallback / speculate); the *measured* divergence it reports
through :meth:`TwinSyncManager.observe_divergence` — not adapter-self-
reported drift — feeds one shared confidence law plus ``fidelity_score``,
which the matcher's D term and the HealthManager's fidelity trips consume.

Confidence law (one law for every sync path): each observation blends
``confidence * DRIFT_DECAY**drift + SYNC_CREDIT * (1 - drift)``, clamped to
[0, 1].  An explicit :meth:`TwinSyncManager.invalidate` records its reason
on the state and pins validity False until an explicit re-sync
(``mark_synced`` / ``recalibrate``) or a measured within-tolerance shadow
comparison — passive telemetry may rebuild confidence but cannot clear an
invalidation by itself.

For the TPU pod substrate the twin is the roofline model over the compiled
artifact — the high-fidelity end of the paper's twin spectrum (DESIGN.md §2).
"""
from __future__ import annotations

import copy
import dataclasses
import threading
from collections import OrderedDict
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from repro.core.simclock import Clock, SYSTEM_CLOCK
from repro.core.telemetry import TelemetryBus, TelemetryEvent


class TwinNotReady(RuntimeError):
    """The surrogate has not learned/observed enough to answer yet."""


# ---------------------------------------------------------------------------
# divergence metric


def output_divergence(real, twin) -> float:
    """Normalized divergence between two adapter ``output`` payloads.

    0.0 = exact agreement, 1.0 = unusable.  Handles the shapes adapters
    produce: dicts (mean over the union of keys, missing key = 1), numeric
    scalars (relative error), sequences (relative L2), bools/strings
    (exact match).  NaNs compare equal to NaNs (a twin predicting "no loss
    yet" for a backend reporting the same is agreement, not divergence).
    """
    if real is None and twin is None:
        return 0.0
    if real is None or twin is None:
        return 1.0
    if isinstance(real, bool) or isinstance(twin, bool):
        return 0.0 if bool(real) == bool(twin) else 1.0
    if isinstance(real, dict) and isinstance(twin, dict):
        keys = set(real) | set(twin)
        if not keys:
            return 0.0
        return float(np.mean([
            output_divergence(real.get(k), twin.get(k)) if k in real
            and k in twin else 1.0 for k in sorted(keys)]))
    if isinstance(real, str) or isinstance(twin, str):
        return 0.0 if real == twin else 1.0
    try:
        a = np.asarray(real, dtype=np.float64).ravel()
        b = np.asarray(twin, dtype=np.float64).ravel()
    except (TypeError, ValueError):
        return 0.0 if real == twin else 1.0
    if a.shape != b.shape:
        return 1.0
    if a.size == 0:
        return 0.0
    both_nan = np.isnan(a) & np.isnan(b)
    a = np.where(both_nan, 0.0, a)
    b = np.where(both_nan, 0.0, b)
    if np.isnan(a).any() or np.isnan(b).any():
        return 1.0
    denom = max(float(np.linalg.norm(a)), float(np.linalg.norm(b)), 1e-9)
    return float(min(1.0, np.linalg.norm(a - b) / denom))


# ---------------------------------------------------------------------------
# surrogate contract


class TwinSurrogate:
    """Executable surrogate model behind a :class:`TwinState`.

    Subclasses override :meth:`simulate` (required), :meth:`observe` and
    :meth:`divergence` (optional), and declare ``kind`` / ``tolerance``.
    Surrogates may be called from shadow-pool threads concurrently with
    adapter invocations — keep internal state small and lock it if mutated.
    """

    kind: str = "behavioral"
    #: declared acceptable normalized divergence vs the real output
    tolerance: float = 0.2

    def simulate(self, task) -> Dict:
        """Answer ``task`` digitally; same raw dict shape as
        ``SubstrateAdapter.invoke``.  Raise :class:`TwinNotReady` when the
        twin cannot answer yet."""
        raise NotImplementedError

    def observe(self, task, raw: Dict) -> None:
        """Learning hook: called with every successful real invocation's
        ``{"output": ..., "telemetry": ...}``."""

    def divergence(self, real_output, twin_output) -> float:
        return output_divergence(real_output, twin_output)


class RecordReplaySurrogate(TwinSurrogate):
    """Record/replay twin learned from recent invocation results.

    Replays the last observed result for the task's payload key (exact
    match preferred, else the most recent record as a degraded behavioral
    approximation); :class:`TwinNotReady` until the first observation.
    """

    kind = "record"
    tolerance = 0.5

    def __init__(self, capacity: int = 32,
                 key_fn: Optional[Callable] = None):
        self.capacity = capacity
        self._key = key_fn or (lambda task: repr(task.payload))
        self._records: "OrderedDict[str, Dict]" = OrderedDict()  # guarded_by: _lock
        self._lock = threading.Lock()

    def observe(self, task, raw: Dict) -> None:
        rec = {"output": copy.deepcopy(raw.get("output")),
               "telemetry": copy.deepcopy(raw.get("telemetry", {}))}
        with self._lock:
            self._records[self._key(task)] = rec
            self._records.move_to_end(self._key(task))
            while len(self._records) > self.capacity:
                self._records.popitem(last=False)

    def simulate(self, task) -> Dict:
        with self._lock:
            if not self._records:
                raise TwinNotReady("record twin has no observations yet")
            rec = self._records.get(self._key(task))
            exact = rec is not None
            if rec is None:
                rec = next(reversed(self._records.values()))
            rec = copy.deepcopy(rec)
        telemetry = dict(rec.get("telemetry", {}))
        telemetry["replayed"] = True
        telemetry["replay_exact_key"] = exact
        return {"output": rec.get("output"), "telemetry": telemetry,
                "artifacts": {}, "backend_ms": 0.0}


# ---------------------------------------------------------------------------
# twin state + sync manager


@dataclasses.dataclass
class TwinState:
    twin_id: str
    resource_id: str
    kind: str = "behavioral"               # ode | behavioral | roofline | record
    confidence: float = 1.0                # decays with drift & staleness
    drift_estimate: float = 0.0
    # stamped by the owning TwinSyncManager's clock at register(); a raw
    # default_factory=time.time here would stamp wall epochs into
    # virtual-time runs (wall is past the VirtualClock epoch, so such
    # twins would look fresher-than-now and never go stale)
    last_sync: Optional[float] = None
    calibration_ts: Optional[float] = None
    observations: int = 0
    model: Dict = dataclasses.field(default_factory=dict)   # twin parameters
    #: why the twin was last invalidated ("" = not invalidated); pins
    #: ``valid()`` False until an explicit re-sync or a measured
    #: within-tolerance shadow comparison
    invalidation_reason: str = ""
    #: EMA of MEASURED shadow/speculation divergence (None = never measured)
    divergence_ema: Optional[float] = None
    #: 1.0 = twin demonstrably matches reality, 0.0 = demonstrably wrong;
    #: stays 1.0 until a divergence is actually measured
    fidelity_score: float = 1.0
    #: executable surrogate (None = metadata-only twin); excluded from
    #: serialization — it is code, not state
    surrogate: Optional[TwinSurrogate] = dataclasses.field(
        default=None, repr=False, compare=False)
    #: wall-time source for staleness (set by the owning TwinSyncManager
    #: from its injected clock; None = real time).  Code, not state —
    #: excluded from comparison and repr like the surrogate.
    time_fn: Optional[Callable[[], float]] = dataclasses.field(
        default=None, repr=False, compare=False)

    #: default ``valid()`` confidence floor; tasks override it via
    #: ``TaskRequest.twin_min_confidence``
    DEFAULT_MIN_CONFIDENCE = 0.3

    def age_ms(self) -> float:
        if self.last_sync is None:
            return 0.0
        now = self.time_fn() if self.time_fn is not None \
            else SYSTEM_CLOCK.now()
        return (now - self.last_sync) * 1e3

    @property
    def executable(self) -> bool:
        return self.surrogate is not None

    def valid(self, max_age_ms: Optional[float],
              min_confidence: Optional[float] = None) -> Tuple[bool, str]:
        """Is this twin trustworthy right now?  ``min_confidence=None``
        applies :data:`DEFAULT_MIN_CONFIDENCE`; tasks may tighten or relax
        it per request."""
        if min_confidence is None:
            min_confidence = self.DEFAULT_MIN_CONFIDENCE
        if self.invalidation_reason:
            return False, f"twin invalidated: {self.invalidation_reason}"
        if max_age_ms is not None and self.age_ms() > max_age_ms:
            return False, f"twin stale ({self.age_ms():.0f}ms > {max_age_ms}ms)"
        if self.confidence < min_confidence:
            return False, f"twin confidence {self.confidence:.2f} < {min_confidence}"
        return True, "ok"

    def to_dict(self) -> Dict:
        return {
            "twin_id": self.twin_id, "resource_id": self.resource_id,
            "kind": self.kind, "confidence": round(self.confidence, 4),
            "drift_estimate": round(self.drift_estimate, 4),
            "age_ms": round(self.age_ms(), 2),
            "observations": self.observations,
            "invalidation_reason": self.invalidation_reason or None,
            "divergence_ema": (round(self.divergence_ema, 4)
                               if self.divergence_ema is not None else None),
            "fidelity_score": round(self.fidelity_score, 4),
            "executable": self.executable,
        }


class TwinSyncManager:
    """Associates telemetry with twin state and updates sync metadata.

    All state updates are serialized under one lock: with the concurrent
    control plane, telemetry-driven confidence updates (``_on_event``) race
    against postcondition invalidation (``invalidate``) and shadow-measured
    divergence (``observe_divergence``); unlocked read-modify-writes could
    silently restore confidence to a twin that was just invalidated.

    One confidence law serves every sync path (``mark_synced``, result
    telemetry, drift telemetry, measured divergence): see :meth:`_observe`.
    """

    DRIFT_DECAY = 0.85       # confidence multiplier per unit drift observed
    SYNC_CREDIT = 0.05       # confidence restored per clean observation
    DIVERGENCE_EMA = 0.3     # weight of the newest measured divergence

    def __init__(self, bus: TelemetryBus, clock: Optional[Clock] = None):
        self._twins: Dict[str, TwinState] = {}   # guarded_by: _lock
        self._bus = bus
        # injectable timebase (defaults to the bus's, so twin staleness and
        # telemetry timestamps agree); virtual under the scenario simulator
        self.clock: Clock = clock or getattr(bus, "clock", SYSTEM_CLOCK)
        self._lock = threading.Lock()
        bus.subscribe(self._on_event)

    def now(self) -> float:
        """This manager's wall-time reading — fault injectors and tests age
        twins relative to THIS timebase, never raw ``time.time()``."""
        return self.clock.now()

    def register(self, twin: TwinState) -> TwinState:
        with self._lock:
            twin.time_fn = self.clock.now
            # stamp unset sync metadata from this manager's timebase so a
            # freshly built TwinState is "synced now" on ITS clock
            if twin.last_sync is None:
                twin.last_sync = self.clock.now()
            if twin.calibration_ts is None:
                twin.calibration_ts = self.clock.now()
            self._twins[twin.resource_id] = twin
        return twin

    def get(self, resource_id: str) -> Optional[TwinState]:
        with self._lock:
            return self._twins.get(resource_id)

    # -- the one shared confidence update -------------------------------------
    def _observe(self, tw: TwinState, drift: float,  # planelint: holds(_lock)
                 ts: Optional[float] = None) -> None:
        """The single confidence law (caller holds the lock): blend the
        current confidence toward agreement, never outside [0, 1]."""
        drift = max(0.0, min(1.0, drift))
        tw.last_sync = ts if ts is not None else self.clock.now()
        tw.observations += 1
        tw.drift_estimate = drift
        tw.confidence = max(0.0, min(1.0, tw.confidence *
                                     (self.DRIFT_DECAY ** drift)
                                     + self.SYNC_CREDIT * (1.0 - drift)))

    def mark_synced(self, resource_id: str, drift: float = 0.0) -> None:
        """Explicit synchronization against the resource: applies the shared
        confidence law AND clears any standing invalidation."""
        with self._lock:
            tw = self._twins.get(resource_id)
            if tw is None:
                return
            tw.invalidation_reason = ""
            self._observe(tw, drift)

    def invalidate(self, resource_id: str, reason: str = "") -> None:
        """Hard invalidation (postcondition violation, speculation
        mismatch): confidence drops to zero and ``reason`` is recorded on
        the state so admissibility rejections can surface it."""
        with self._lock:
            tw = self._twins.get(resource_id)
            if tw is not None:
                tw.confidence = 0.0
                tw.invalidation_reason = reason or "invalidated"

    def recalibrate(self, resource_id: str) -> None:
        with self._lock:
            tw = self._twins.get(resource_id)
            if tw is not None:
                tw.calibration_ts = self.clock.now()
                tw.last_sync = self.clock.now()
                tw.drift_estimate = 0.0
                tw.confidence = 1.0
                tw.invalidation_reason = ""
                tw.divergence_ema = None
                tw.fidelity_score = 1.0

    # -- measured fidelity (shadow / speculation comparisons) ------------------
    def observe_divergence(self, resource_id: str, divergence: float,
                           tolerance: float) -> None:
        """Feed one MEASURED twin-vs-real divergence into the twin state.

        Unlike adapter-self-reported drift, this is direct evidence: it
        drives ``fidelity_score`` (an EMA normalized by the surrogate's
        declared tolerance, consumed by the matcher's D term), runs the
        shared confidence law with a divergence-equivalent drift, and — when
        the twin demonstrably agrees with reality (divergence within
        tolerance) — clears a standing invalidation.
        """
        tol = max(float(tolerance), 1e-9)
        divergence = max(0.0, float(divergence))
        with self._lock:
            tw = self._twins.get(resource_id)
            if tw is None:
                return
            if tw.divergence_ema is None:
                tw.divergence_ema = divergence
            else:
                tw.divergence_ema = ((1.0 - self.DIVERGENCE_EMA)
                                     * tw.divergence_ema
                                     + self.DIVERGENCE_EMA * divergence)
            tw.fidelity_score = max(
                0.0, min(1.0, 1.0 - tw.divergence_ema / (2.0 * tol)))
            if divergence <= tol:
                tw.invalidation_reason = ""
            self._observe(tw, min(1.0, divergence / (2.0 * tol)))

    def check_serve(self, resource_id: str,
                    max_age_ms: Optional[float] = None,
                    min_confidence: Optional[float] = None
                    ) -> Tuple[Optional[TwinState], bool, str, float]:
        """Atomic validity check for twin-served execution: returns
        ``(twin, ok, reason, confidence_at_check)`` evaluated under the
        manager lock, so a serve decision and the confidence it cites can
        never straddle a concurrent invalidation."""
        with self._lock:
            tw = self._twins.get(resource_id)
            if tw is None:
                return None, False, "no twin bound to resource", 0.0
            ok, why = tw.valid(max_age_ms, min_confidence)
            return tw, ok, why, tw.confidence

    # -- telemetry coupling ---------------------------------------------------
    def _on_event(self, ev: TelemetryEvent) -> None:
        with self._lock:
            tw = self._twins.get(ev.resource_id)
            if tw is None:
                return
            if ev.kind == "result":
                drift = float(ev.fields.get("drift_score", 0.0))
                self._observe(tw, drift, ts=ev.timestamp)
            elif ev.kind == "drift":
                self._observe(tw, float(ev.fields.get("drift_score", 0.0)),
                              ts=ev.timestamp)
