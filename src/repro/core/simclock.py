"""Injectable time source: one clock abstraction for production and
simulation.

Every time-dependent control-plane component (scheduler deadlines and
backpressure waits, health-manager cooldowns, telemetry staleness, twin
freshness, chaos-harness drive loops) reads time through a :class:`Clock`
instead of the ``time`` module directly.  Production uses
:data:`SYSTEM_CLOCK` (a thin delegate to ``time``); the planet-scale
scenario harness (:mod:`repro.core.simulator`) injects a
:class:`VirtualClock`, so a simulated hour of fleet behavior — diurnal
waves, breaker cooldowns, twin staleness — elapses in the wall-time it
takes to *process the events*, with zero real sleeps on the simulated
path.

Design rules:

- ``now()`` is wall-clock epoch seconds (feeds telemetry timestamps and
  twin ``last_sync``); ``monotonic()`` is the scheduling timebase (feeds
  deadlines and cooldowns).  A :class:`VirtualClock` advances both in
  lockstep from a fixed epoch, so same-seed runs produce bit-identical
  timestamps.
- waiting is *notification-first*: :meth:`Clock.wait_for` parks on a real
  ``threading.Condition`` so production waits cost nothing and wake
  immediately on notify.  Under a :class:`VirtualClock` a bounded wait
  instead advances virtual time (single-threaded discrete-event
  semantics) — this is what lets the scheduler's former
  ``time.sleep(0.01)`` polls virtualize away.
- :func:`forbid_real_sleep` is the audit hook: it patches ``time.sleep``
  for the duration of a simulated run and records (or refuses) any real
  sleep attempted on the simulated path.
"""
from __future__ import annotations

import contextlib
import threading
import time
from typing import Callable, Iterator, Optional

__all__ = ["Clock", "SystemClock", "VirtualClock", "SYSTEM_CLOCK",
           "RealSleepForbidden", "forbid_real_sleep"]


class Clock:
    """Abstract time source.  Subclasses supply wall/monotonic time plus
    the waiting primitives the control plane uses instead of raw
    ``time.sleep`` / bare condition timeouts."""

    def now(self) -> float:
        """Wall-clock epoch seconds (telemetry timestamps, twin sync)."""
        raise NotImplementedError

    def monotonic(self) -> float:
        """Scheduling timebase (deadlines, cooldowns, latency stats)."""
        raise NotImplementedError

    def sleep(self, seconds: float) -> None:
        raise NotImplementedError

    def condition(self, lock: Optional[threading.Lock] = None
                  ) -> threading.Condition:
        """A condition variable whose timed waits this clock mediates."""
        return threading.Condition(lock)

    def wait_for(self, cond: threading.Condition,
                 predicate: Callable[[], bool],
                 timeout: Optional[float] = None) -> bool:
        """Wait on ``cond`` (caller holds it) until ``predicate`` or
        ``timeout``.  Returns the final predicate value."""
        raise NotImplementedError

    def wait_event(self, event: threading.Event,
                   timeout: Optional[float] = None) -> bool:
        """Wait until ``event`` is set or ``timeout`` elapsed; returns
        ``event.is_set()`` (the ``threading.Event.wait`` contract)."""
        raise NotImplementedError


class SystemClock(Clock):
    """Production clock: a thin delegate to the ``time`` module."""

    def now(self) -> float:
        return time.time()

    def monotonic(self) -> float:
        return time.monotonic()

    def sleep(self, seconds: float) -> None:
        if seconds > 0:
            time.sleep(seconds)

    def wait_for(self, cond: threading.Condition,
                 predicate: Callable[[], bool],
                 timeout: Optional[float] = None) -> bool:
        return cond.wait_for(predicate, timeout=timeout)

    def wait_event(self, event: threading.Event,
                   timeout: Optional[float] = None) -> bool:
        return event.wait(timeout=timeout)


#: process-wide default — every component's ``clock=None`` resolves here
SYSTEM_CLOCK = SystemClock()


class VirtualClock(Clock):
    """Deterministic virtual time for the scenario simulator and tests.

    Time only moves when someone *advances* it — ``sleep`` and bounded
    waits advance instead of blocking, so a simulated hour costs exactly
    the wall-time of the event processing in between.  Starting from a
    fixed ``epoch`` makes every timestamp a pure function of the event
    sequence: same seed → identical timestamps → identical trace hash.

    Thread discipline: the clock is safe to *read* from any thread, but
    advancing is meant to happen from one logical driver at a time (the
    simulator's event loop, or a test and its strictly-alternating worker).
    An unbounded :meth:`wait_for` degenerates to a real notification wait —
    it consumes no time, virtual or real, and is how scheduler workers park
    for queue space under a virtual clock.
    """

    #: fixed wall epoch (2023-11-14T22:13:20Z) — arbitrary but stable, so
    #: virtual timestamps are reproducible across runs and machines
    EPOCH = 1_700_000_000.0

    def __init__(self, epoch: float = EPOCH):
        self.epoch = epoch
        self._elapsed = 0.0
        self._sleeps = 0                     # virtual sleeps serviced
        self._lock = threading.Lock()

    # -- reading ---------------------------------------------------------------
    def now(self) -> float:
        with self._lock:
            return self.epoch + self._elapsed

    def monotonic(self) -> float:
        with self._lock:
            return self._elapsed

    @property
    def virtual_sleeps(self) -> int:
        """How many sleeps/timed-waits were absorbed into virtual time."""
        with self._lock:
            return self._sleeps

    # -- advancing -------------------------------------------------------------
    def advance(self, seconds: float) -> float:
        """Move virtual time forward; returns the new monotonic reading."""
        if seconds < 0:
            raise ValueError("virtual time cannot run backwards")
        with self._lock:
            self._elapsed += seconds
            return self._elapsed

    def advance_to(self, monotonic_target: float) -> float:
        """Jump to an absolute monotonic instant (never backwards)."""
        with self._lock:
            if monotonic_target < self._elapsed:
                raise ValueError(
                    f"virtual time cannot run backwards "
                    f"({monotonic_target} < {self._elapsed})")
            self._elapsed = monotonic_target
            return self._elapsed

    def sleep(self, seconds: float) -> None:
        if seconds > 0:
            with self._lock:
                self._elapsed += seconds
                self._sleeps += 1

    # -- waiting ---------------------------------------------------------------
    def wait_for(self, cond: threading.Condition,
                 predicate: Callable[[], bool],
                 timeout: Optional[float] = None) -> bool:
        if predicate():
            return True
        if timeout is None:
            # notification-driven: no time passes, virtual or real — the
            # waker is another thread (e.g. a scheduler worker freeing a
            # queue slot), not the passage of time
            return cond.wait_for(predicate)
        # bounded wait = discrete-event step: absorb the timeout into
        # virtual time and re-check.  The caller's wait loop re-evaluates
        # its deadline against this clock, so polling loops converge in
        # O(iterations), not O(wall time).
        self.sleep(timeout)
        return predicate()

    def wait_event(self, event: threading.Event,
                   timeout: Optional[float] = None) -> bool:
        if event.is_set():
            return True
        if timeout is None:
            return event.wait()
        self.sleep(timeout)
        return event.is_set()


class RealSleepForbidden(AssertionError):
    """A real ``time.sleep`` was attempted inside a no-real-sleep region."""


@contextlib.contextmanager
def forbid_real_sleep(strict: bool = True) -> Iterator[dict]:
    """Audit guard for the simulated path: while active, ``time.sleep``
    raises (``strict=True``) or is counted (``strict=False``).

    Yields a mutable ``{"calls": int}`` the caller can assert on.  The
    patch is process-global — use around single-threaded simulator runs,
    not around code legitimately sharing the process with sleeping
    threads.
    """
    counter = {"calls": 0}
    original = time.sleep

    def guarded(seconds: float) -> None:
        counter["calls"] += 1
        if strict:
            raise RealSleepForbidden(
                f"time.sleep({seconds!r}) on the simulated path — all "
                "waiting must go through the injected Clock")
        original(seconds)

    time.sleep = guarded
    try:
        yield counter
    finally:
        time.sleep = original
