"""Policy & safety manager (requirement R7).

Enforces admissible operating regions, human-supervision requirements,
tenant authorization, exclusivity and concurrency limits.  A shared PNN
cannot be exposed as an unconstrained stateless service — admission happens
*before* lifecycle preparation, so rejected tasks never touch the substrate.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, Optional, Tuple

from repro.core.descriptors import ResourceDescriptor
from repro.core.tasks import TaskRequest


@dataclasses.dataclass
class PolicyDecision:
    allowed: bool
    reason: str = "ok"

    def __bool__(self):
        return self.allowed


class PolicyManager:
    def __init__(self):
        self._locks: Dict[str, threading.Semaphore] = {}
        self._lock = threading.Lock()

    def _sem(self, desc: ResourceDescriptor) -> threading.Semaphore:
        with self._lock:
            if desc.resource_id not in self._locks:
                self._locks[desc.resource_id] = threading.Semaphore(
                    max(desc.capability.policy.max_concurrent, 1))
            return self._locks[desc.resource_id]

    def admit(self, desc: ResourceDescriptor, task: TaskRequest) -> PolicyDecision:
        pol = desc.capability.policy
        if pol.requires_supervision and not task.supervision_available:
            return PolicyDecision(False,
                                  "substrate requires human supervision; task "
                                  "declares none available")
        if pol.authorized_tenants != ("*",) and task.tenant not in pol.authorized_tenants:
            return PolicyDecision(False, f"tenant {task.tenant!r} not authorized")
        stim = None
        if isinstance(task.metadata, dict):
            stim = task.metadata.get("stimulation_amplitude")
        if (pol.max_stimulation is not None and stim is not None
                and stim > pol.max_stimulation):
            return PolicyDecision(False,
                                  f"stimulation {stim} exceeds safety bound "
                                  f"{pol.max_stimulation}")
        return PolicyDecision(True)

    def acquire(self, desc: ResourceDescriptor) -> bool:
        return self._sem(desc).acquire(blocking=False)

    def release(self, desc: ResourceDescriptor) -> None:
        self._sem(desc).release()
