"""Policy & safety manager (requirement R7).

Enforces admissible operating regions, human-supervision requirements,
tenant authorization, exclusivity and concurrency limits.  A shared PNN
cannot be exposed as an unconstrained stateless service — admission happens
*before* lifecycle preparation, so rejected tasks never touch the substrate.

Concurrency admission is deadline-aware: ``acquire`` blocks up to the
caller's remaining deadline for a per-substrate slot instead of turning
transient contention into spurious "concurrency limit" fallbacks.  Held
slots are accounted per resource so a drained control plane can be audited
for semaphore leaks (``outstanding`` / ``fully_released``).
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, Optional, Tuple

from repro.core.descriptors import ResourceDescriptor
from repro.core.tasks import TaskRequest


@dataclasses.dataclass
class PolicyDecision:
    allowed: bool
    reason: str = "ok"

    def __bool__(self):
        return self.allowed


class PolicyManager:
    def __init__(self):
        self._locks: Dict[str, threading.Semaphore] = {}  # guarded_by: _lock
        self._held: Dict[str, int] = {}                   # guarded_by: _lock
        self._probes: Dict[str, int] = {}                 # guarded_by: _lock
        self._lock = threading.Lock()

    def _sem(self, desc: ResourceDescriptor) -> threading.Semaphore:
        with self._lock:
            if desc.resource_id not in self._locks:
                self._locks[desc.resource_id] = threading.Semaphore(
                    max(desc.capability.policy.max_concurrent, 1))
            return self._locks[desc.resource_id]

    def admit(self, desc: ResourceDescriptor, task: TaskRequest) -> PolicyDecision:
        pol = desc.capability.policy
        if pol.requires_supervision and not task.supervision_available:
            return PolicyDecision(False,
                                  "substrate requires human supervision; task "
                                  "declares none available")
        if pol.authorized_tenants != ("*",) and task.tenant not in pol.authorized_tenants:
            return PolicyDecision(False, f"tenant {task.tenant!r} not authorized")
        stim = None
        if isinstance(task.metadata, dict):
            stim = task.metadata.get("stimulation_amplitude")
        if (pol.max_stimulation is not None and stim is not None
                and stim > pol.max_stimulation):
            return PolicyDecision(False,
                                  f"stimulation {stim} exceeds safety bound "
                                  f"{pol.max_stimulation}")
        return PolicyDecision(True)

    def acquire(self, desc: ResourceDescriptor,
                timeout_s: Optional[float] = 0.0) -> bool:
        """Take one concurrency slot on the substrate.

        ``timeout_s=0.0`` (default) is the seed's non-blocking behaviour;
        a positive value blocks up to that deadline; ``None`` blocks
        indefinitely.  Returns False iff no slot became available in time.
        """
        sem = self._sem(desc)
        if timeout_s is None:
            ok = sem.acquire()
        elif timeout_s <= 0.0:
            ok = sem.acquire(blocking=False)
        else:
            ok = sem.acquire(timeout=timeout_s)
        if ok:
            with self._lock:
                self._held[desc.resource_id] = \
                    self._held.get(desc.resource_id, 0) + 1
        return ok

    def release(self, desc: ResourceDescriptor) -> None:
        with self._lock:
            self._held[desc.resource_id] = max(
                0, self._held.get(desc.resource_id, 0) - 1)
        self._sem(desc).release()

    # -- probation slot budget (health manager trickle) -----------------------
    def acquire_probe(self, resource_id: str, budget: int) -> bool:
        """Reserve one probation probe slot; the health manager routes a
        bounded trickle of real tasks through a half-open resource, capped
        at ``budget`` concurrent probes per resource."""
        with self._lock:
            held = self._probes.get(resource_id, 0)
            if held >= max(1, budget):
                return False
            self._probes[resource_id] = held + 1
            return True

    def release_probe(self, resource_id: str) -> None:
        with self._lock:
            self._probes[resource_id] = max(
                0, self._probes.get(resource_id, 0) - 1)

    def probes_held(self, resource_id: str) -> int:
        with self._lock:
            return self._probes.get(resource_id, 0)

    def probe_outstanding(self) -> Dict[str, int]:
        """Currently-held probe slot count per resource (non-zero only)."""
        with self._lock:
            return {rid: n for rid, n in self._probes.items() if n > 0}

    # -- leak auditing --------------------------------------------------------
    def outstanding(self) -> Dict[str, int]:
        """Currently-held slot count per resource (non-zero entries only)."""
        with self._lock:
            return {rid: n for rid, n in self._held.items() if n > 0}

    def fully_released(self) -> bool:
        """True iff every acquired slot — concurrency AND probation probe —
        has been released (no leaks)."""
        return not self.outstanding() and not self.probe_outstanding()
