"""Task model: what a client asks the control plane to do.

A task is expressed in substrate-aware terms (paper §VII-B): modality,
latency target, required telemetry fields, acceptable twin age, supervision
availability, an optional direct backend preference, and a fallback policy.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Dict, Optional, Tuple

_ids = itertools.count(1)


@dataclasses.dataclass
class TaskRequest:
    function: str                              # e.g. "inference", "screening"
    input_modality: str
    output_modality: str
    payload: Any = None
    latency_budget_ms: Optional[float] = None
    required_telemetry: Tuple[str, ...] = ()
    max_twin_age_ms: Optional[float] = None
    supervision_available: bool = True
    backend_preference: Optional[str] = None   # directed workflow target
    allow_fallback: bool = True
    tenant: str = "default"
    repeated: bool = False                     # needs repeated low-latency calls
    #: executable-twin opt-in: None (off) | "shadow" (twin runs alongside the
    #: real invocation, divergence measured) | "fallback" (a valid twin may
    #: serve instead of a rejection) | "speculate" (twin answers first, real
    #: hardware confirms asynchronously — see submit_speculative)
    twin_mode: Optional[str] = None
    #: per-task override of the twin validity confidence floor
    #: (None = TwinState.DEFAULT_MIN_CONFIDENCE)
    twin_min_confidence: Optional[float] = None
    metadata: Dict[str, Any] = dataclasses.field(default_factory=dict)
    task_id: str = dataclasses.field(
        default_factory=lambda: f"task-{next(_ids):05d}")

    def clone(self, **overrides) -> "TaskRequest":
        """Copy with field overrides and an UN-ALIASED metadata dict.

        ``dataclasses.replace`` shares mutable fields with the original, so
        every control-plane path that derives a task variant (fallback
        re-rank, twin-candidate policy check, speculation confirm) must go
        through here or risk mutating the caller's metadata.  ``task_id``
        is preserved: a clone is the same task, re-expressed."""
        if "metadata" not in overrides and isinstance(self.metadata, dict):
            overrides["metadata"] = dict(self.metadata)
        return dataclasses.replace(self, **overrides)

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["payload"] = None if self.payload is None else "<payload>"
        return d
