"""Task model: what a client asks the control plane to do.

A task is expressed in substrate-aware terms (paper §VII-B): modality,
latency target, required telemetry fields, acceptable twin age, supervision
availability, an optional direct backend preference, and a fallback policy.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Dict, Optional, Tuple

_ids = itertools.count(1)


@dataclasses.dataclass
class TaskRequest:
    function: str                              # e.g. "inference", "screening"
    input_modality: str
    output_modality: str
    payload: Any = None
    latency_budget_ms: Optional[float] = None
    required_telemetry: Tuple[str, ...] = ()
    max_twin_age_ms: Optional[float] = None
    supervision_available: bool = True
    backend_preference: Optional[str] = None   # directed workflow target
    allow_fallback: bool = True
    tenant: str = "default"
    repeated: bool = False                     # needs repeated low-latency calls
    metadata: Dict[str, Any] = dataclasses.field(default_factory=dict)
    task_id: str = dataclasses.field(
        default_factory=lambda: f"task-{next(_ids):05d}")

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["payload"] = None if self.payload is None else "<payload>"
        return d
