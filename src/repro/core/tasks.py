"""Task model: what a client asks the control plane to do.

A task is expressed in substrate-aware terms (paper §VII-B): modality,
latency target, required telemetry fields, acceptable twin age, supervision
availability, an optional direct backend preference, and a fallback policy.

Wire fidelity: ``to_wire()`` is the FAITHFUL serialization the gateway
transports (payload included — a remote plane cannot execute a redacted
task); ``summary()`` is the redacting form for logs and traces (payload
replaced by a placeholder).  ``to_dict()`` stays an alias of ``summary()``
so existing log/trace consumers keep their redaction.

Task-id namespacing: ids are minted per *plane*.  With a single module
counter, a client plane and a gateway plane running in different processes
would both mint ``task-00001`` and collide the moment one's tasks reach the
other over the wire.  Every id therefore embeds a plane namespace (default:
a process-derived token; override with :func:`set_plane_namespace` for
readable logs), and ``from_wire`` preserves the originating plane's id so a
task keeps one identity across a federation hop.
"""
from __future__ import annotations

import dataclasses
import itertools
import os
from typing import Any, Dict, Optional, Tuple

_ids = itertools.count(1)
#: plane namespace embedded in minted task ids: pid (debuggable) + a random
#: token (collision-resistant where pids recycle or collide mod the pid
#: space).  Minted LAZILY and re-minted after fork — a pre-fork import must
#: not hand every worker the same namespace.
_plane_ns: Optional[str] = None
_ns_pid: Optional[int] = None


def _namespace() -> str:
    global _plane_ns, _ns_pid
    if _plane_ns is None or _ns_pid != os.getpid():
        _plane_ns = f"{os.getpid() % 0xFFFF:04x}{os.urandom(2).hex()}"
        _ns_pid = os.getpid()
    return _plane_ns


def set_plane_namespace(namespace: Optional[str]) -> Optional[str]:
    """Set this process/plane's task-id namespace (returns the previous
    one for restore; ``None`` reverts to the auto-minted default).  Purely
    cosmetic beyond uniqueness — ids become ``task-<namespace>-NNNNN``."""
    global _plane_ns, _ns_pid
    prev, _plane_ns = _plane_ns, namespace
    _ns_pid = os.getpid()
    return prev


def new_task_id() -> str:
    return f"task-{_namespace()}-{next(_ids):05d}"


@dataclasses.dataclass
class TaskRequest:
    function: str                              # e.g. "inference", "screening"
    input_modality: str
    output_modality: str
    payload: Any = None
    latency_budget_ms: Optional[float] = None
    required_telemetry: Tuple[str, ...] = ()
    max_twin_age_ms: Optional[float] = None
    supervision_available: bool = True
    backend_preference: Optional[str] = None   # directed workflow target
    allow_fallback: bool = True
    tenant: str = "default"
    repeated: bool = False                     # needs repeated low-latency calls
    #: executable-twin opt-in: None (off) | "shadow" (twin runs alongside the
    #: real invocation, divergence measured) | "fallback" (a valid twin may
    #: serve instead of a rejection) | "speculate" (twin answers first, real
    #: hardware confirms asynchronously — see submit_speculative)
    twin_mode: Optional[str] = None
    #: per-task override of the twin validity confidence floor
    #: (None = TwinState.DEFAULT_MIN_CONFIDENCE)
    twin_min_confidence: Optional[float] = None
    #: multi-hop federation budgets (repro.core.topology): how many more
    #: plane-to-plane forwards this task may take (None = never forwarded;
    #: the first forward stamps the default), and the remaining end-to-end
    #: deadline budget in ms, decremented by a wire margin per hop (None =
    #: seeded from latency_budget_ms at the first forward, or unbounded)
    hop_budget: Optional[int] = None
    deadline_budget_ms: Optional[float] = None
    #: plane ids this task was forwarded through, origin first
    route: Tuple[str, ...] = ()
    metadata: Dict[str, Any] = dataclasses.field(default_factory=dict)
    task_id: str = dataclasses.field(default_factory=new_task_id)

    def clone(self, **overrides) -> "TaskRequest":
        """Copy with field overrides and an UN-ALIASED metadata dict.

        ``dataclasses.replace`` shares mutable fields with the original, so
        every control-plane path that derives a task variant (fallback
        re-rank, twin-candidate policy check, speculation confirm) must go
        through here or risk mutating the caller's metadata.  ``task_id``
        is preserved: a clone is the same task, re-expressed."""
        if "metadata" not in overrides and isinstance(self.metadata, dict):
            overrides["metadata"] = dict(self.metadata)
        return dataclasses.replace(self, **overrides)

    # -- wire forms -----------------------------------------------------------
    def to_wire(self) -> Dict:
        """FAITHFUL serialization (payload included) for transport to a
        remote plane; ``from_wire`` round-trips it exactly."""
        d = dataclasses.asdict(self)
        d["required_telemetry"] = list(self.required_telemetry)
        d["route"] = list(self.route)
        return d

    @classmethod
    def from_wire(cls, d: Dict) -> "TaskRequest":
        """Reconstruct a task from its wire form, PRESERVING the
        originating plane's ``task_id`` (a task keeps one identity across a
        federation hop; no id is re-minted)."""
        from repro.core.descriptors import known_fields

        d = known_fields(cls, d)
        d["required_telemetry"] = tuple(d.get("required_telemetry") or ())
        d["route"] = tuple(d.get("route") or ())
        d["metadata"] = dict(d.get("metadata") or {})
        return cls(**d)

    def summary(self) -> Dict:
        """Redacting form for logs/traces: payload is replaced by a
        placeholder (payloads may be large or sensitive)."""
        d = self.to_wire()
        d["payload"] = None if self.payload is None else "<payload>"
        return d

    def to_dict(self) -> Dict:
        """Alias of :meth:`summary` — the historical (redacting) shape."""
        return self.summary()
