"""Substrate-aware capability model (paper §V, Table I).

Two descriptor kinds:

- :class:`ResourceDescriptor` — identifies a concrete substrate instance and
  its operating context (substrate class, location, adapter type, tenancy,
  twin binding).
- :class:`CapabilityDescriptor` — what the resource can do and under which
  conditions: signal modality, admissible I/O, timing regime, lifecycle
  affordances, programmability, observability, telemetry availability.

These are machine-readable inputs to matching, admission control, invocation
setup and supervision — not passive documentation.  ``to_dict()`` produces
the wire form whose *shared-key ratio* across heterogeneous backends is the
paper's RQ1 portability metric (1.0 in the paper; reproduced in
``benchmarks/bench_portability.py``).

Descriptor portability is round-trip-faithful: every spec has a
``from_dict`` constructor and ``to_dict → from_dict → to_dict`` is an
identity (property-tested in ``tests/test_protocol.py``), so a descriptor
discovered over the wire is indistinguishable from one built in-process —
the matcher, policy manager and contracts all consume it unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple


def _tup(v) -> Tuple:
    """Wire lists come back as tuples (descriptor dataclasses are frozen
    and hashable; ``dataclasses.asdict`` serializes tuples as lists)."""
    return tuple(v) if v is not None else ()


def known_fields(cls, d: Dict) -> Dict:
    """Drop unknown keys before dataclass construction: additive fields
    from a newer MINOR protocol version must be ignored, not crash a
    ``from_dict``/``from_wire`` (the wire compatibility policy in
    ``repro.gateway.protocol``).  Shared by every wire constructor."""
    names = {f.name for f in dataclasses.fields(cls)}
    return {k: v for k, v in d.items() if k in names}


# signal modalities used by the reference backends (paper §VI)
MODALITIES = (
    "concentration",      # DNA/chemical: molecular concentrations
    "spikes",             # biological/wetware: stimulation patterns / spike trains
    "vector",             # memristive/photonic: digital vectors/tensors
    "tensor",
    "tensor_shards",      # TPU pod substrate: sharded device arrays
    "tokens",             # LM serving substrate: token-id sequences
)

LATENCY_REGIMES = ("slow_seconds", "fast_ms", "sub_ms")

PROGRAMMABILITY = ("fixed", "configurable", "tunable", "in_situ_adaptive")


@dataclasses.dataclass(frozen=True)
class SignalSpec:
    """Typed multi-physics I/O description (requirement R2)."""

    modality: str
    encoding: str = "float32"
    admissible_range: Tuple[float, float] = (0.0, 1.0)
    sampling_hz: Optional[float] = None
    transduction: Optional[str] = None    # required conversion step, if any

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict) -> "SignalSpec":
        d = known_fields(cls, d)
        d["admissible_range"] = tuple(d.get("admissible_range", (0.0, 1.0)))
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class TimingSemantics:
    """R3: when outputs become meaningful."""

    latency_regime: str                   # slow_seconds | fast_ms | sub_ms
    expected_latency_ms: float
    observation_window_ms: float
    min_stabilization_ms: float = 0.0
    trigger_mode: str = "request"         # request | stream | event
    freshness_ms: float = 60_000.0        # results older than this are stale

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict) -> "TimingSemantics":
        return cls(**known_fields(cls, d))


@dataclasses.dataclass(frozen=True)
class LifecycleSemantics:
    """R4: warm-up / reset / calibration affordances."""

    warmup_ms: float = 0.0
    resetable: bool = True
    reset_modes: Tuple[str, ...] = ("soft",)
    reset_cost_ms: float = 0.0
    calibration_interval_s: Optional[float] = None
    recovery_modes: Tuple[str, ...] = ()
    cooldown_ms: float = 0.0

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict) -> "LifecycleSemantics":
        d = known_fields(cls, d)
        d["reset_modes"] = _tup(d.get("reset_modes", ("soft",)))
        d["recovery_modes"] = _tup(d.get("recovery_modes"))
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class Observability:
    """R5: which runtime signals exist and which feed the twin."""

    output_channels: Tuple[str, ...]
    telemetry_fields: Tuple[str, ...]
    drift_indicators: Tuple[str, ...] = ()
    twin_linked_fields: Tuple[str, ...] = ()

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict) -> "Observability":
        return cls(output_channels=_tup(d.get("output_channels")),
                   telemetry_fields=_tup(d.get("telemetry_fields")),
                   drift_indicators=_tup(d.get("drift_indicators")),
                   twin_linked_fields=_tup(d.get("twin_linked_fields")))


@dataclasses.dataclass(frozen=True)
class PolicyConstraints:
    """R7: safety, isolation, tenancy."""

    exclusive: bool = True
    requires_supervision: bool = False
    max_stimulation: Optional[float] = None
    max_concurrent: int = 1
    authorized_tenants: Tuple[str, ...] = ("*",)
    biosafety_level: int = 0

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict) -> "PolicyConstraints":
        d = known_fields(cls, d)
        d["authorized_tenants"] = _tup(d.get("authorized_tenants", ("*",)))
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class CapabilityDescriptor:
    functions: Tuple[str, ...]            # e.g. ("inference", "screening")
    input_signal: SignalSpec
    output_signal: SignalSpec
    timing: TimingSemantics
    lifecycle: LifecycleSemantics
    programmability: str
    observability: Observability
    policy: PolicyConstraints
    supports_repeated_invocation: bool = True
    energy_proxy_mj: Optional[float] = None

    def to_dict(self) -> Dict:
        return {
            "functions": list(self.functions),
            "input_signal": self.input_signal.to_dict(),
            "output_signal": self.output_signal.to_dict(),
            "timing": self.timing.to_dict(),
            "lifecycle": self.lifecycle.to_dict(),
            "programmability": self.programmability,
            "observability": self.observability.to_dict(),
            "policy": self.policy.to_dict(),
            "supports_repeated_invocation": self.supports_repeated_invocation,
            "energy_proxy_mj": self.energy_proxy_mj,
        }

    @classmethod
    def from_dict(cls, d: Dict) -> "CapabilityDescriptor":
        return cls(
            functions=_tup(d.get("functions")),
            input_signal=SignalSpec.from_dict(d["input_signal"]),
            output_signal=SignalSpec.from_dict(d["output_signal"]),
            timing=TimingSemantics.from_dict(d["timing"]),
            lifecycle=LifecycleSemantics.from_dict(d["lifecycle"]),
            programmability=d["programmability"],
            observability=Observability.from_dict(d["observability"]),
            policy=PolicyConstraints.from_dict(d["policy"]),
            supports_repeated_invocation=d.get("supports_repeated_invocation",
                                               True),
            energy_proxy_mj=d.get("energy_proxy_mj"),
        )


@dataclasses.dataclass(frozen=True)
class ResourceDescriptor:
    resource_id: str
    substrate_class: str                  # chemical | wetware | memristive | ...
    adapter_type: str                     # in_process | http | external_api
    location: str                         # extreme_edge | edge | fog | cloud | lab
    twin_binding: Optional[str]           # twin model id, None = no twin
    capability: CapabilityDescriptor
    description: str = ""

    def to_dict(self) -> Dict:
        return {
            "resource_id": self.resource_id,
            "substrate_class": self.substrate_class,
            "adapter_type": self.adapter_type,
            "location": self.location,
            "twin_binding": self.twin_binding,
            "capability": self.capability.to_dict(),
            "description": self.description,
        }

    @classmethod
    def from_dict(cls, d: Dict) -> "ResourceDescriptor":
        return cls(
            resource_id=d["resource_id"],
            substrate_class=d["substrate_class"],
            adapter_type=d["adapter_type"],
            location=d["location"],
            twin_binding=d.get("twin_binding"),
            capability=CapabilityDescriptor.from_dict(d["capability"]),
            description=d.get("description", ""),
        )


def shared_key_ratio(dicts: List[Dict]) -> float:
    """Paper RQ1 metric: |∩ keys| / |∪ keys| over top-level descriptor keys."""
    if not dicts:
        return 0.0
    key_sets = [set(d.keys()) for d in dicts]
    inter = set.intersection(*key_sets)
    union = set.union(*key_sets)
    return len(inter) / len(union) if union else 1.0
