"""Task-to-substrate matcher (paper §IV-C, Eq. 1) + simplified baselines.

    S(t,s) = α·C(t,s) + β·T(t,s) + γ·L(t,s) + δ·D(t,s) − ε·O(s)

- C — capability compatibility (modality, function, repeated invocation)
- T — timing suitability (latency budget vs expected latency regime)
- L — lifecycle cost (warm-up/reset/cooldown amortization)
- D — twin confidence & deployment locality
- O — orchestration overhead (adapter boundary cost)

Admissibility is checked first (hard constraints: modality, policy, twin
freshness, readiness); Eq. 1 only ranks admissible candidates.  Every score
is returned with its per-term breakdown — the matcher is *explainable*,
which the fault-campaign benchmarks rely on.

Baselines (paper RQ2): random-admissible, modality-only, latency-only.
The decisive suite cases are exactly those needing runtime semantics:
drifted local backend, stale twin, missing supervision.

Sustained-throughput path: the static half of admission + scoring (function,
modality, repeated-invocation checks and the C/T/L/O terms — everything
derivable from descriptors and the task shape alone) is cached per task
signature and invalidated whenever the registry epoch moves
(register/unregister).  Runtime semantics — policy, snapshots, twin
validity, live queue depth — are always evaluated fresh, so snapshot
changes take effect immediately without cache invalidation and caching
never changes a decision, only removes repeated descriptor walks when many
similar tasks stream through the scheduler.
"""
from __future__ import annotations

import dataclasses
import random
import threading
from typing import Dict, List, Optional, Tuple

from repro.core.descriptors import ResourceDescriptor
from repro.core.registry import CapabilityRegistry
from repro.core.policy import PolicyManager
from repro.core.tasks import TaskRequest
from repro.core.telemetry import TelemetryBus
from repro.core.topology import budget_admissible
from repro.core.twin import TwinSyncManager

_LOCALITY_SCORE = {"extreme_edge": 1.0, "edge": 0.9, "device/edge": 0.9,
                   "fog": 0.6, "cloud": 0.4, "lab": 0.5, "sim./lab": 0.5}

DRIFT_LIMIT = 0.5
QUEUE_PENALTY = 0.15      # added to O per session queued BEYOND max_concurrent
_STATIC_CACHE_MAX = 256   # distinct (epoch, task-shape) entries retained


@dataclasses.dataclass(frozen=True)
class MatchWeights:
    alpha: float = 1.0      # capability compatibility
    beta: float = 1.0       # timing suitability
    gamma: float = 0.5      # lifecycle cost
    delta: float = 0.8      # twin confidence + locality
    epsilon: float = 0.3    # orchestration overhead


@dataclasses.dataclass
class Candidate:
    resource_id: str
    score: float
    terms: Dict[str, float]
    admissible: bool
    reason: str = "ok"


class Matcher:
    """The full phys-MCP matcher: static descriptors + runtime snapshots."""

    name = "phys-mcp"

    def __init__(self, registry: CapabilityRegistry, bus: TelemetryBus,
                 twins: TwinSyncManager, policy: PolicyManager,
                 weights: MatchWeights = MatchWeights(), health=None):
        self.registry = registry
        self.bus = bus
        self.twins = twins
        self.policy = policy
        self.w = weights
        #: optional HealthManager: quarantined (open-breaker) resources are
        #: inadmissible, probation ones only while probe budget remains
        self.health = health
        self._cache_lock = threading.Lock()
        self._static_cache: Dict[Tuple, Dict[str, Tuple]] = {}

    # -- static-work cache ----------------------------------------------------
    @staticmethod
    def _task_shape(task: TaskRequest) -> Tuple:
        """The task fields the static checks/terms depend on — tasks sharing
        a shape share cached static admissibility and C/T/L/O terms."""
        return (task.function, task.input_modality, task.output_modality,
                task.repeated, task.latency_budget_ms)

    def _static_eval(self, desc: ResourceDescriptor, task: TaskRequest
                     ) -> Tuple[bool, str, Optional[Dict[str, float]]]:
        """Cached static admissibility + static score terms for one
        descriptor, invalidated by registry epoch moves.  Snapshot changes
        need no invalidation: nothing telemetry-dependent is ever cached
        (runtime terms are recomputed fresh in _finish_terms /
        _runtime_admissible), so keying on bus.epoch would only kill the
        hit rate for workloads that publish health snapshots.

        Hits validate the cached entry against the caller's descriptor
        OBJECT (descriptors are frozen, so re-registration produces a new
        object): a racing re-register can therefore never pin stale
        capabilities onto a fresh epoch."""
        key = (self.registry.epoch, self._task_shape(task))
        with self._cache_lock:
            per_shape = self._static_cache.get(key)
            if per_shape is not None:
                hit = per_shape.get(desc.resource_id)
                if hit is not None and hit[0] is desc:
                    return hit[1:]
        entry = (desc,) + self._static_one(desc, task)
        with self._cache_lock:
            if key not in self._static_cache:
                # evict oldest epochs/shapes first; never drop the whole
                # cache at once (insertion order ≈ staleness)
                while len(self._static_cache) >= _STATIC_CACHE_MAX:
                    self._static_cache.pop(next(iter(self._static_cache)))
                self._static_cache[key] = {}
            self._static_cache[key][desc.resource_id] = entry
        return entry[1:]

    def _static_one(self, desc: ResourceDescriptor, task: TaskRequest
                    ) -> Tuple[bool, str, Optional[Dict[str, float]]]:
        cap = desc.capability
        if task.function not in cap.functions:
            return False, f"function {task.function!r} unsupported", None
        if cap.input_signal.modality != task.input_modality:
            return False, "input modality mismatch", None
        if cap.output_signal.modality != task.output_modality:
            return False, "output modality mismatch", None
        if task.repeated and not cap.supports_repeated_invocation:
            return False, "repeated invocation unsupported", None
        return True, "ok", self._static_terms(desc, task)

    # -- hard admission checks ------------------------------------------------
    def admissible(self, desc: ResourceDescriptor, task: TaskRequest
                   ) -> Tuple[bool, str]:
        ok, why, _ = self._static_eval(desc, task)
        if not ok:
            return False, why
        return self._runtime_admissible(desc, task)

    def _runtime_admissible(self, desc: ResourceDescriptor, task: TaskRequest
                            ) -> Tuple[bool, str]:
        if desc.substrate_class == "federated_plane":
            # multi-hop budget gate: a task whose hop budget is spent or
            # whose remaining deadline budget cannot absorb another wire
            # hop must stay on local hardware; refusing placement here is
            # what surfaces as a structured DEADLINE when no local
            # candidate exists.  Not cached: budgets vary per task instance
            # (decremented each hop), not per task shape.
            ok, why = budget_admissible(task)
            if not ok:
                return False, why
        pol = self.policy.admit(desc, task)
        if not pol:
            return False, pol.reason
        if self.health is not None:
            ok, why = self.health.admissible(desc.resource_id)
            if not ok:
                return False, why
        snap = self.bus.snapshot(desc.resource_id)
        if snap is not None:
            if snap.health_status == "failed" or snap.readiness == "down":
                return False, f"runtime state {snap.health_status}/{snap.readiness}"
            if snap.drift_score > DRIFT_LIMIT:
                return False, f"drift {snap.drift_score:.2f} > {DRIFT_LIMIT}"
        twin = self.twins.get(desc.resource_id)
        if twin is not None and (task.max_twin_age_ms is not None
                                 or task.twin_min_confidence is not None):
            # twin validity is an opt-in hard constraint: a freshness bound
            # and/or a per-task confidence floor; the reason (including any
            # recorded invalidation cause) is surfaced in the rejection
            ok, why = twin.valid(task.max_twin_age_ms,
                                 task.twin_min_confidence)
            if not ok:
                return False, why
        return True, "ok"

    def twin_candidates(self, task: TaskRequest
                        ) -> List[Tuple[ResourceDescriptor, object, bool, str]]:
        """The twin-serve set for fallback/speculation: every statically
        admissible resource carrying an EXECUTABLE twin, with its serve-time
        validity verdict, ordered best-confidence first.

        Policy still applies — except the human-supervision requirement: a
        twin serve never touches hardware, so simulation needs no
        supervisor.  Invalid twins are returned too (``ok=False`` + reason)
        so refusals can be surfaced in rejection messages.
        """
        policy_task = task.clone(supervision_available=True) \
            if hasattr(task, "clone") else task
        out: List[Tuple[ResourceDescriptor, object, bool, str]] = []
        for desc in self.registry.all():
            if (task.backend_preference is not None
                    and desc.resource_id != task.backend_preference):
                continue
            ok, _, _ = self._static_eval(desc, task)
            if not ok:
                continue
            if not self.policy.admit(desc, policy_task):
                continue
            twin = self.twins.get(desc.resource_id)
            if twin is None or twin.surrogate is None:
                continue
            valid, why = twin.valid(task.max_twin_age_ms,
                                    task.twin_min_confidence)
            out.append((desc, twin, valid, why))
        out.sort(key=lambda t: (t[2], t[1].confidence), reverse=True)
        return out

    # -- Eq. 1 terms ------------------------------------------------------------
    def _static_terms(self, desc: ResourceDescriptor, task: TaskRequest
                      ) -> Dict[str, float]:
        """Descriptor/task-shape-only terms: C, T, L, the adapter-boundary
        base of O, and locality (folded into D at score time)."""
        cap = desc.capability
        C = 1.0
        if task.repeated and cap.supports_repeated_invocation:
            C += 0.2
        T = 1.0
        if task.latency_budget_ms is not None:
            exp = cap.timing.expected_latency_ms
            T = max(0.0, min(1.0, task.latency_budget_ms / max(exp, 1e-6) / 2))
        lc = cap.lifecycle
        cost_ms = lc.warmup_ms + lc.reset_cost_ms + lc.cooldown_ms
        L = 1.0 / (1.0 + cost_ms / 1e3)
        O = {"in_process": 0.05, "http": 0.3, "external_api": 0.5}.get(
            desc.adapter_type, 0.2)
        locality = _LOCALITY_SCORE.get(desc.location, 0.5)
        return {"C": C, "T": T, "L": L, "O": O, "_locality": locality}

    def _terms(self, desc: ResourceDescriptor, task: TaskRequest) -> Dict[str, float]:
        static = self._static_terms(desc, task)
        return self._finish_terms(desc, static)

    def _finish_terms(self, desc: ResourceDescriptor,
                      static: Dict[str, float]) -> Dict[str, float]:
        """Overlay the runtime-dependent parts: twin confidence, MEASURED
        twin fidelity + drift into D, live queue pressure into O."""
        twin = self.twins.get(desc.resource_id)
        conf = twin.confidence if twin is not None else 0.5
        # fidelity_score is 1.0 until a shadow/speculation comparison has
        # actually measured divergence, so unmeasured twins score exactly as
        # before; a twin demonstrably diverging from its hardware halves D
        # even when the adapter self-reports clean drift
        fid = twin.fidelity_score if twin is not None else 1.0
        snap = self.bus.snapshot(desc.resource_id)
        drift_pen = snap.drift_score if snap is not None else 0.0
        D = (0.6 * conf * (0.5 + 0.5 * fid) * (1.0 - drift_pen)
             + 0.4 * static["_locality"])
        # live pressure: only sessions the substrate cannot absorb within its
        # max_concurrent budget count as orchestration cost, so a wide
        # substrate with free slots beats a narrow one with a waiting line
        over = max(0, self.bus.queue_depth(desc.resource_id)
                   - desc.capability.policy.max_concurrent)
        O = static["O"] + QUEUE_PENALTY * over
        return {"C": static["C"], "T": static["T"], "L": static["L"],
                "D": D, "O": O}

    def score(self, desc: ResourceDescriptor, task: TaskRequest) -> Candidate:
        ok, why, static = self._static_eval(desc, task)
        if ok:
            ok, why = self._runtime_admissible(desc, task)
        if not ok:
            return Candidate(desc.resource_id, float("-inf"), {}, False, why)
        t = self._finish_terms(desc, static)
        s = (self.w.alpha * t["C"] + self.w.beta * t["T"] + self.w.gamma * t["L"]
             + self.w.delta * t["D"] - self.w.epsilon * t["O"])
        return Candidate(desc.resource_id, s, t, True)

    def rank(self, task: TaskRequest) -> List[Candidate]:
        cands = [self.score(d, task) for d in self.registry.all()]
        return sorted(cands, key=lambda c: c.score, reverse=True)

    def select(self, task: TaskRequest) -> Optional[Candidate]:
        """Directed workflow → admission check only; else Eq. 1 ranking."""
        if task.backend_preference is not None:
            desc = self.registry.get(task.backend_preference)
            if desc is None:
                return None
            cand = self.score(desc, task)
            return cand if cand.admissible else None
        ranked = [c for c in self.rank(task) if c.admissible]
        return ranked[0] if ranked else None


# ---------------------------------------------------------------------------
# simplified baseline selectors (paper RQ2)


class RandomAdmissibleSelector(Matcher):
    """Ignores Eq. 1 entirely; uniform choice among *statically* admissible
    candidates (no runtime snapshots, no twin state)."""

    name = "random"

    def __init__(self, *args, seed: int = 0, **kw):
        super().__init__(*args, **kw)
        self._rng = random.Random(seed)

    def _static_ok(self, desc, task) -> bool:
        cap = desc.capability
        return (task.function in cap.functions
                and cap.input_signal.modality == task.input_modality
                and cap.output_signal.modality == task.output_modality)

    def select(self, task: TaskRequest) -> Optional[Candidate]:
        if task.backend_preference is not None:
            desc = self.registry.get(task.backend_preference)
            if desc is not None and self._static_ok(desc, task):
                return Candidate(desc.resource_id, 1.0, {}, True)
            return None
        cands = [d for d in self.registry.all() if self._static_ok(d, task)]
        if not cands:
            return None
        pick = self._rng.choice(cands)
        return Candidate(pick.resource_id, 1.0, {}, True)


class ModalityOnlySelector(RandomAdmissibleSelector):
    """First candidate whose modalities match — no timing/runtime semantics."""

    name = "modality-only"

    def select(self, task: TaskRequest) -> Optional[Candidate]:
        if task.backend_preference is not None:
            return super().select(task)
        for d in self.registry.all():
            if self._static_ok(d, task):
                return Candidate(d.resource_id, 1.0, {}, True)
        return None


class LatencyOnlySelector(RandomAdmissibleSelector):
    """Lowest advertised latency with a matching function — ignores modality
    details, runtime health, twins and policy."""

    name = "latency-only"

    def select(self, task: TaskRequest) -> Optional[Candidate]:
        if task.backend_preference is not None:
            return super().select(task)
        cands = [d for d in self.registry.all()
                 if task.function in d.capability.functions]
        if not cands:
            return None
        best = min(cands, key=lambda d: d.capability.timing.expected_latency_ms)
        return Candidate(best.resource_id, 1.0, {}, True)
