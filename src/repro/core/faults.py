"""Fault-injection campaign machinery (paper Table IV).

Five representative scenarios:

1. ``drifted_local_fast``   — local fast backend drifted → matcher prefers
                              the externalized fast backend directly.
2. ``local_prepare_failure``— local preparation fails → recover via fallback.
3. ``wetware_no_supervision`` — policy reject before execution.
4. ``stale_chemical_twin``  — freshness bound reject before execution.
5. ``missing_telemetry``    — postcondition check fails → fallback used.

Each scenario states its expected control-plane behavior; the campaign
returns observed-vs-expected, which tests and benchmarks assert on.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List

from repro.core.orchestrator import Orchestrator
from repro.core.tasks import TaskRequest
from repro.core.telemetry import RuntimeSnapshot


@dataclasses.dataclass
class FaultScenario:
    name: str
    description: str
    expected: str          # "success_direct" | "success_fallback" | "reject"
    inject: Callable[[Orchestrator], None]
    task: Callable[[], TaskRequest]
    target_hint: str = ""


def _set_drift(orch: Orchestrator, rid: str, drift: float) -> None:
    snap = orch.bus.snapshot(rid) or RuntimeSnapshot(rid)
    snap.drift_score = drift
    snap.health_status = "degraded" if drift > 0.3 else "healthy"
    orch.bus.update_snapshot(snap)


def _stale_twin(orch: Orchestrator, rid: str, age_s: float) -> None:
    tw = orch.twins.get(rid)
    if tw is not None:
        tw.last_sync = time.time() - age_s


def build_campaign(local_fast="memristive-local", ext_fast="fast-external",
                   wetware="wetware-synthetic", chemical="chemical-ode",
                   ) -> List[FaultScenario]:
    return [
        FaultScenario(
            name="drifted_local_fast",
            description="local fast backend reports excessive drift; matcher "
                        "should prefer the healthier externalized backend "
                        "directly (no fallback needed)",
            expected="success_direct",
            inject=lambda o: _set_drift(o, local_fast, 0.8),
            task=lambda: TaskRequest(
                function="inference", input_modality="vector",
                output_modality="vector", payload=[0.1, 0.2, 0.3, 0.4],
                required_telemetry=("execution_ms",)),
            target_hint=ext_fast,
        ),
        FaultScenario(
            name="local_prepare_failure",
            description="local fast backend fails during preparation; "
                        "orchestrator recovers through fallback",
            expected="success_fallback",
            inject=lambda o: o.registry.adapter(local_fast).inject_fault(
                "prepare_failure"),
            task=lambda: TaskRequest(
                function="inference", input_modality="vector",
                output_modality="vector", payload=[0.1, 0.2, 0.3, 0.4],
                required_telemetry=("execution_ms",)),
            target_hint=ext_fast,
        ),
        FaultScenario(
            name="wetware_no_supervision",
            description="wetware requires human supervision; the task "
                        "declares none → reject before execution",
            expected="reject",
            inject=lambda o: None,
            task=lambda: TaskRequest(
                function="screening", input_modality="spikes",
                output_modality="spikes", payload={"pattern": [1, 0, 1, 1]},
                supervision_available=False,
                required_telemetry=("viability",)),
        ),
        FaultScenario(
            name="stale_chemical_twin",
            description="chemical twin exceeds the task's freshness bound "
                        "despite nominal modality compatibility → reject",
            expected="reject",
            inject=lambda o: _stale_twin(o, chemical, age_s=3600.0),
            task=lambda: TaskRequest(
                function="assay", input_modality="concentration",
                output_modality="concentration",
                payload={"concentrations": [0.2, 0.4]},
                max_twin_age_ms=60_000.0,
                required_telemetry=("convergence_ms",)),
        ),
        FaultScenario(
            name="missing_telemetry",
            description="backend completes but drops a required telemetry "
                        "field; postcondition validation fails → fallback",
            expected="success_fallback",
            inject=lambda o: o.registry.adapter(local_fast).inject_fault(
                "drop_telemetry"),
            task=lambda: TaskRequest(
                function="inference", input_modality="vector",
                output_modality="vector", payload=[0.5, 0.5, 0.5, 0.5],
                required_telemetry=("execution_ms", "drift_score")),
            target_hint=ext_fast,
        ),
    ]


def run_campaign(make_orchestrator: Callable[[], Orchestrator],
                 scenarios: List[FaultScenario]) -> List[Dict]:
    """Run each scenario on a FRESH orchestrator (faults don't leak)."""
    results = []
    for sc in scenarios:
        orch = make_orchestrator()
        sc.inject(orch)
        result, trace = orch.submit(sc.task())
        if result.status == "completed":
            observed = "success_fallback" if trace.fallback_used else "success_direct"
        elif result.status == "rejected":
            observed = "reject"
        else:
            observed = result.status
        ok = observed == sc.expected
        if ok and sc.target_hint and result.status == "completed":
            ok = result.resource_id == sc.target_hint
        results.append({
            "scenario": sc.name,
            "description": sc.description,
            "expected": sc.expected,
            "observed": observed,
            "selected": result.resource_id or None,
            "target_hint": sc.target_hint or None,
            "attempts": trace.attempts,
            "pass": bool(ok),
        })
    return results
