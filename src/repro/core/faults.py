"""Fault-injection machinery: Table IV campaign + composable chaos harness.

Two layers:

**Serial campaign (paper Table IV).**  Five representative scenarios, each
run on a FRESH orchestrator so faults cannot leak between scenarios:

1. ``drifted_local_fast``   — local fast backend drifted → matcher prefers
                              the externalized fast backend directly.
2. ``local_prepare_failure``— local preparation fails → recover via fallback.
3. ``wetware_no_supervision`` — policy reject before execution.
4. ``stale_chemical_twin``  — freshness bound reject before execution.
5. ``missing_telemetry``    — postcondition check fails → fallback used.

**Concurrent chaos harness.**  The paper's claim is *telemetry-aware
recovery under representative faults*, which a scripted fresh-orchestrator
demo cannot exercise: real recovery happens on a live, loaded control
plane.  :class:`ChaosInjector` (any fault: drift, adapter faults, raising
invokes, stale twins — composable), :class:`ChaosScenario` (injector ×
task template × expected outcomes × expected breaker trajectory) and
:func:`run_campaign_concurrent` fire scenarios through the scheduler
against ONE shared orchestrator under background load, asserting
observed-vs-expected AND the HealthManager breaker trajectories
(quarantine → probation → re-admission), with a zero-tasks-on-quarantined
audit.  Every row carries ``mismatch_reason`` so harness failures are
actionable.
"""
from __future__ import annotations

import dataclasses
import hashlib
import inspect
import json
import random
from collections import Counter
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.orchestrator import Orchestrator
from repro.core.simclock import Clock, SYSTEM_CLOCK
from repro.core.tasks import TaskRequest
from repro.core.telemetry import RuntimeSnapshot


def _clock_of(orch: Orchestrator) -> Clock:
    """The orchestrator's injected timebase (virtual under the scenario
    simulator) — harness waits and twin aging must use it, not ``time``."""
    return getattr(orch, "clock", SYSTEM_CLOCK)


@dataclasses.dataclass
class FaultScenario:
    name: str
    description: str
    expected: str          # "success_direct" | "success_fallback" | "reject"
    inject: Callable[[Orchestrator], None]
    task: Callable[[], TaskRequest]
    target_hint: str = ""


def _set_drift(orch: Orchestrator, rid: str, drift: float) -> None:
    snap = orch.bus.snapshot(rid) or RuntimeSnapshot(rid)
    snap.drift_score = drift
    snap.health_status = "degraded" if drift > 0.3 else "healthy"
    orch.bus.update_snapshot(snap)


def _stale_twin(orch: Orchestrator, rid: str, age_s: float) -> None:
    tw = orch.twins.get(rid)
    if tw is not None:
        tw.last_sync = orch.twins.now() - age_s


def build_campaign(local_fast="memristive-local", ext_fast="fast-external",
                   wetware="wetware-synthetic", chemical="chemical-ode",
                   ) -> List[FaultScenario]:
    return [
        FaultScenario(
            name="drifted_local_fast",
            description="local fast backend reports excessive drift; matcher "
                        "should prefer the healthier externalized backend "
                        "directly (no fallback needed)",
            expected="success_direct",
            inject=lambda o: _set_drift(o, local_fast, 0.8),
            task=lambda: TaskRequest(
                function="inference", input_modality="vector",
                output_modality="vector", payload=[0.1, 0.2, 0.3, 0.4],
                required_telemetry=("execution_ms",)),
            target_hint=ext_fast,
        ),
        FaultScenario(
            name="local_prepare_failure",
            description="local fast backend fails during preparation; "
                        "orchestrator recovers through fallback",
            expected="success_fallback",
            inject=lambda o: o.registry.adapter(local_fast).inject_fault(
                "prepare_failure"),
            task=lambda: TaskRequest(
                function="inference", input_modality="vector",
                output_modality="vector", payload=[0.1, 0.2, 0.3, 0.4],
                required_telemetry=("execution_ms",)),
            target_hint=ext_fast,
        ),
        FaultScenario(
            name="wetware_no_supervision",
            description="wetware requires human supervision; the task "
                        "declares none → reject before execution",
            expected="reject",
            inject=lambda o: None,
            task=lambda: TaskRequest(
                function="screening", input_modality="spikes",
                output_modality="spikes", payload={"pattern": [1, 0, 1, 1]},
                supervision_available=False,
                required_telemetry=("viability",)),
        ),
        FaultScenario(
            name="stale_chemical_twin",
            description="chemical twin exceeds the task's freshness bound "
                        "despite nominal modality compatibility → reject",
            expected="reject",
            inject=lambda o: _stale_twin(o, chemical, age_s=3600.0),
            task=lambda: TaskRequest(
                function="assay", input_modality="concentration",
                output_modality="concentration",
                payload={"concentrations": [0.2, 0.4]},
                max_twin_age_ms=60_000.0,
                required_telemetry=("convergence_ms",)),
        ),
        FaultScenario(
            name="missing_telemetry",
            description="backend completes but drops a required telemetry "
                        "field; postcondition validation fails → fallback",
            expected="success_fallback",
            inject=lambda o: o.registry.adapter(local_fast).inject_fault(
                "drop_telemetry"),
            task=lambda: TaskRequest(
                function="inference", input_modality="vector",
                output_modality="vector", payload=[0.5, 0.5, 0.5, 0.5],
                required_telemetry=("execution_ms", "drift_score")),
            target_hint=ext_fast,
        ),
    ]


def classify(result, trace) -> str:
    """Map a (result, trace) pair onto the campaign outcome vocabulary."""
    if result.status == "completed":
        return "success_fallback" if trace.fallback_used else "success_direct"
    if result.status == "rejected":
        return "reject"
    return result.status


def run_campaign(make_orchestrator: Callable[[], Orchestrator],
                 scenarios: List[FaultScenario]) -> List[Dict]:
    """Run each scenario on a FRESH orchestrator (faults don't leak)."""
    results = []
    for sc in scenarios:
        orch = make_orchestrator()
        sc.inject(orch)
        result, trace = orch.submit(sc.task())
        observed = classify(result, trace)
        mismatch_reason = None
        if observed != sc.expected:
            mismatch_reason = (f"expected {sc.expected!r}, observed "
                               f"{observed!r} (status={result.status!r}, "
                               f"selected={result.resource_id or None!r})")
        elif (sc.target_hint and result.status == "completed"
                and result.resource_id != sc.target_hint):
            mismatch_reason = (f"completed on {result.resource_id!r} but "
                               f"target_hint was {sc.target_hint!r}")
        results.append({
            "scenario": sc.name,
            "description": sc.description,
            "expected": sc.expected,
            "observed": observed,
            "selected": result.resource_id or None,
            "target_hint": sc.target_hint or None,
            "attempts": trace.attempts,
            "pass": mismatch_reason is None,
            "mismatch_reason": mismatch_reason,
        })
    return results


# ---------------------------------------------------------------------------
# composable chaos harness (concurrent campaign on a live control plane)


@dataclasses.dataclass
class ChaosInjector:
    """A named, reversible fault: ``apply`` arms it on a live orchestrator,
    ``clear`` removes it.  Injectors compose (``compose``), so a scenario
    matrix can pair any fault combination with any task template."""

    name: str
    apply: Callable[[Orchestrator], None]
    clear: Callable[[Orchestrator], None] = lambda orch: None


def inject_drift(rid: str, drift: float) -> ChaosInjector:
    """Simulate a genuinely drifted device: publish a drifted snapshot AND
    make the adapter keep reporting that drift, so recover-on-reopen's
    snapshot refresh cannot wipe the fault (a merely-stale snapshot would
    legitimately self-heal through reset).  Clear restores the adapter and
    republishes its real state."""
    saved: Dict[str, Callable] = {}

    def apply(orch: Orchestrator) -> None:
        adapter = orch.registry.adapter(rid)
        if adapter is not None and "snapshot" not in saved:
            saved["snapshot"] = adapter.snapshot

            def drifted_snapshot():
                return RuntimeSnapshot(
                    rid, drift_score=drift,
                    health_status="degraded" if drift > 0.3 else "healthy")

            adapter.snapshot = drifted_snapshot
        _set_drift(orch, rid, drift)

    def clear(orch: Orchestrator) -> None:
        adapter = orch.registry.adapter(rid)
        if adapter is not None and "snapshot" in saved:
            adapter.snapshot = saved.pop("snapshot")
        _set_drift(orch, rid, 0.0)

    return ChaosInjector(f"drift({rid},{drift})", apply, clear)


def inject_adapter_fault(rid: str, fault: str) -> ChaosInjector:
    """Arm one of the adapter-level fault switches (``prepare_failure``,
    ``drop_telemetry``, ...); clear removes all armed adapter faults."""
    return ChaosInjector(
        name=f"adapter_fault({rid},{fault})",
        apply=lambda o: o.registry.adapter(rid).inject_fault(fault),
        clear=lambda o: o.registry.adapter(rid).clear_faults())


def inject_invoke_failure(rid: str, delay_ms: float = 0.0) -> ChaosInjector:
    """Make the adapter's ``invoke`` raise (after an optional dwell standing
    in for a hung-then-failing backend); clear restores the original."""
    saved: Dict[str, Callable] = {}

    def apply(orch: Orchestrator) -> None:
        adapter = orch.registry.adapter(rid)
        if "invoke" in saved:
            return
        saved["invoke"] = adapter.invoke

        def failing_invoke(session):
            if delay_ms:
                _clock_of(orch).sleep(delay_ms / 1e3)
            raise RuntimeError(f"chaos: injected invoke failure on {rid}")

        adapter.invoke = failing_invoke

    def clear(orch: Orchestrator) -> None:
        adapter = orch.registry.adapter(rid)
        if "invoke" in saved:
            adapter.invoke = saved.pop("invoke")

    return ChaosInjector(f"invoke_failure({rid})", apply, clear)


def inject_stale_twin(rid: str, age_s: float) -> ChaosInjector:
    """Age the twin past freshness bounds; clear re-syncs it."""

    def clear(orch: Orchestrator) -> None:
        tw = orch.twins.get(rid)
        if tw is not None:
            tw.last_sync = orch.twins.now()

    return ChaosInjector(f"stale_twin({rid},{age_s}s)",
                         lambda o: _stale_twin(o, rid, age_s), clear)


def compose(*injectors: ChaosInjector) -> ChaosInjector:
    """Apply several faults together; clear runs in reverse order."""

    def apply(orch: Orchestrator) -> None:
        for inj in injectors:
            inj.apply(orch)

    def clear(orch: Orchestrator) -> None:
        for inj in reversed(injectors):
            inj.clear(orch)

    return ChaosInjector("+".join(i.name for i in injectors), apply, clear)


@dataclasses.dataclass
class ChaosScenario:
    """One cell of a chaos matrix: injector × task template × expectations.

    ``expected`` lists every acceptable per-task outcome (under concurrency
    the same fault legitimately yields ``success_fallback`` before the
    breaker trips and ``success_direct`` after quarantine).
    ``expect_trajectory`` is an in-order subsequence the breaker history of
    ``breaker_rid`` must eventually contain — e.g. ``("open", "probation",
    "healthy")`` asserts quarantine AND re-admission after ``clear``.
    """

    name: str
    injector: ChaosInjector
    template: Callable[[int], TaskRequest]
    expected: Tuple[str, ...]
    n_tasks: int = 6
    target_hint: str = ""
    breaker_rid: str = ""
    expect_trajectory: Tuple[str, ...] = ()


def scenario_matrix(injectors: Sequence[ChaosInjector],
                    templates: Sequence[Tuple[str, Callable[[int], TaskRequest]]],
                    expected: Callable[[str, str], Tuple[str, ...]],
                    **kw) -> List[ChaosScenario]:
    """Cross product helper: every injector against every named template;
    ``expected(injector_name, template_name)`` supplies the outcome set."""
    return [
        ChaosScenario(name=f"{inj.name}x{tname}", injector=inj,
                      template=tmpl, expected=tuple(expected(inj.name, tname)),
                      **kw)
        for inj in injectors for tname, tmpl in templates
    ]


def _vector_task(i: int) -> TaskRequest:
    return TaskRequest(function="inference", input_modality="vector",
                       output_modality="vector",
                       payload=[0.1, 0.2, 0.3, 0.4],
                       required_telemetry=("execution_ms",))


def _directed_telemetry_template(rid: str) -> Callable[[int], TaskRequest]:
    """Directed tasks pin the attempt to ``rid`` regardless of ranking —
    needed to keep exercising a postcondition fault: an undirected task
    stops reaching the faulty backend after the first twin invalidation."""

    def template(i: int) -> TaskRequest:
        return TaskRequest(function="inference", input_modality="vector",
                           output_modality="vector",
                           payload=[0.5, 0.5, 0.5, 0.5],
                           backend_preference=rid,
                           required_telemetry=("execution_ms", "drift_score"))

    return template


def _unsupervised_task(i: int) -> TaskRequest:
    return TaskRequest(function="screening", input_modality="spikes",
                       output_modality="spikes",
                       payload={"pattern": [1, 0, 1, 1]},
                       supervision_available=False,
                       required_telemetry=("viability",))


def _stale_assay_task(i: int) -> TaskRequest:
    return TaskRequest(function="assay", input_modality="concentration",
                       output_modality="concentration",
                       payload={"concentrations": [0.2, 0.4]},
                       max_twin_age_ms=60_000.0,
                       required_telemetry=("convergence_ms",))


def build_concurrent_campaign(local_fast="memristive-local",
                              ext_fast="fast-external",
                              wetware="wetware-synthetic",
                              chemical="chemical-ode") -> List[ChaosScenario]:
    """The Table IV fault classes reshaped for a live, loaded control plane:
    persistent faults must trip the breaker, quarantine must reroute without
    losing tasks, and clearing the fault must re-admit through probation."""
    return [
        ChaosScenario(
            name="invoke_failure_quarantine_readmit",
            injector=inject_invoke_failure(local_fast, delay_ms=2.0),
            template=_vector_task, n_tasks=8,
            expected=("success_fallback", "success_direct"),
            breaker_rid=local_fast,
            expect_trajectory=("open", "probation", "healthy")),
        ChaosScenario(
            name="drift_quarantine_readmit",
            injector=inject_drift(local_fast, 0.8),
            template=_vector_task, n_tasks=4,
            expected=("success_direct",),
            target_hint=ext_fast,
            breaker_rid=local_fast,
            expect_trajectory=("open", "probation", "healthy")),
        ChaosScenario(
            name="prepare_failure_quarantine_readmit",
            injector=inject_adapter_fault(local_fast, "prepare_failure"),
            template=_vector_task, n_tasks=8,
            expected=("success_fallback", "success_direct"),
            breaker_rid=local_fast,
            expect_trajectory=("open", "probation", "healthy")),
        ChaosScenario(
            name="wetware_no_supervision_reject",
            injector=ChaosInjector("none", lambda o: None),
            template=_unsupervised_task, n_tasks=4,
            expected=("reject",)),
        ChaosScenario(
            name="stale_chemical_twin_reject",
            injector=inject_stale_twin(chemical, age_s=3600.0),
            template=_stale_assay_task, n_tasks=4,
            expected=("reject",)),
        ChaosScenario(
            name="missing_telemetry_quarantine_readmit",
            injector=inject_adapter_fault(local_fast, "drop_telemetry"),
            template=_directed_telemetry_template(local_fast), n_tasks=8,
            # fallback while the breaker counts failures, then the open
            # breaker shields even DIRECTED workflows from the bad backend
            expected=("success_fallback", "reject"),
            breaker_rid=local_fast,
            expect_trajectory=("open", "probation", "healthy")),
    ]


def _is_subsequence(needle: Sequence[str], haystack: Sequence[str]) -> bool:
    it = iter(haystack)
    return all(any(x == y for y in it) for x in needle)


#: keys stripped from canonicalized campaign rows: measured timings vary
#: run-to-run on a real clock and are not part of the campaign's *semantic*
#: outcome (under a virtual clock they are deterministic anyway)
_VOLATILE_KEY_MARKERS = ("_ms", "_s", "timestamp", "latency", "wall")


def _canonical(obj):
    """Thread-timing-independent canonical form for trace hashing: dicts
    sorted by key with volatile timing keys dropped, Counters flattened."""
    if isinstance(obj, dict):
        return {k: _canonical(v) for k, v in sorted(obj.items())
                if not any(m in str(k) for m in _VOLATILE_KEY_MARKERS)}
    if isinstance(obj, (list, tuple)):
        return [_canonical(v) for v in obj]
    if isinstance(obj, float):
        return round(obj, 9)
    return obj


def campaign_trace_hash(rows: Sequence[Dict], *, extra: Optional[Dict] = None
                        ) -> str:
    """Deterministic digest of a campaign's classified outcomes + breaker
    trajectories.  Two runs of the same scenario matrix with the same seed
    on a virtual clock (and one worker, so the control plane is strictly
    sequential) must produce the same hash — the seeded-determinism
    regression test and the simulator's acceptance audit both key on it."""
    payload = {"rows": _canonical(list(rows)), "extra": _canonical(extra or {})}
    blob = json.dumps(payload, sort_keys=True, separators=(",", ":"),
                      default=str)
    return hashlib.sha256(blob.encode()).hexdigest()


def _template_arity(template: Callable) -> int:
    """Positional arity of a scenario template: legacy templates take
    ``(i)``; seeded templates take ``(i, rng)`` and draw payload variation
    from the harness RNG reproducibly."""
    try:
        params = list(inspect.signature(template).parameters.values())
    except (TypeError, ValueError):
        return 1
    n = 0
    for p in params:
        if p.kind in (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD):
            n += 1
        elif p.kind is p.VAR_POSITIONAL:
            return 2
    return n


def _call_template(template: Callable, i: int,
                   rng: random.Random) -> TaskRequest:
    if _template_arity(template) >= 2:
        return template(i, rng)
    return template(i)


def run_campaign_concurrent(orch: Orchestrator,
                            scenarios: List[ChaosScenario], *,
                            scheduler=None, workers: int = 8,
                            load_template: Optional[
                                Callable[[int], TaskRequest]] = None,
                            load_tasks: int = 0,
                            trajectory_timeout_s: float = 10.0,
                            seed: Optional[int] = None) -> Dict:
    """Fire chaos scenarios through the scheduler against ONE shared, live
    orchestrator — optionally under background load — and check observed
    outcomes plus breaker-state trajectories.

    Returns ``{"rows": [...], "all_pass": bool, "audit": {...},
    "load_statuses": {...}}``.  Each row mirrors :func:`run_campaign`'s
    shape (scenario / expected / observed / pass / mismatch_reason) plus
    the breaker trajectory observed for ``breaker_rid``.

    Re-admission is *driven*: after ``clear``, a bounded trickle of real
    tasks keeps flowing until the breaker trajectory contains the expected
    subsequence (probation probes only progress when tasks arrive).

    ``seed`` pins the harness RNG (handed to two-argument templates) and is
    recorded in the result next to ``trace_hash`` — a canonical digest of
    the classified outcomes + breaker trajectories.  With a fixed seed, a
    virtual clock on the orchestrator, and ``workers=1`` (strictly
    sequential control plane, no background health ticker) two runs of the
    same matrix produce identical rows and identical ``trace_hash``.
    """
    if orch.health is None:
        raise ValueError("run_campaign_concurrent needs an orchestrator "
                         "with its HealthManager enabled")
    from repro.core.scheduler import ControlPlaneScheduler

    rng = random.Random(seed)
    own_scheduler = scheduler is None
    # a seeded campaign must not race the background probe ticker: lazy
    # promotion on the task path covers re-admission deterministically
    sched = scheduler or ControlPlaneScheduler(
        orch, workers=workers,
        health_tick_interval_s=0.0 if seed is not None else 0.05)
    sched.start()
    load_futures = []
    per_scenario_load = (load_tasks // max(1, len(scenarios))
                         if load_template is not None else 0)
    rows: List[Dict] = []
    try:
        for sc in scenarios:
            for i in range(per_scenario_load):
                load_futures.append(sched.submit_async(
                    _call_template(load_template, i, rng)))
            # a shared live plane carries breaker history across scenarios:
            # settle the target breaker back to healthy, then scope this
            # scenario's trajectory assertions to ITS OWN history window so
            # an earlier scenario's transitions can never satisfy them
            settled = True
            if sc.breaker_rid:
                settled = _settle_healthy(orch, sched, sc,
                                          timeout_s=trajectory_timeout_s)
            history_start = (len(orch.health.history(sc.breaker_rid))
                             if sc.breaker_rid else 0)
            sc.injector.apply(orch)
            try:
                results = sched.submit_many(
                    [_call_template(sc.template, i, rng)
                     for i in range(sc.n_tasks)])
                observed = Counter(classify(r, t) for r, t in results)
                selected = sorted({r.resource_id for r, _ in results
                                   if r.resource_id})
                mismatch = None
                unexpected = {o: n for o, n in observed.items()
                              if o not in sc.expected}
                if unexpected:
                    mismatch = (f"expected only {sc.expected}, but observed "
                                f"{unexpected} (selected={selected})")
                bad_target = [r.resource_id for r, _ in results
                              if sc.target_hint and r.status == "completed"
                              and r.resource_id != sc.target_hint]
                if mismatch is None and bad_target:
                    mismatch = (f"{len(bad_target)} task(s) completed on "
                                f"{sorted(set(bad_target))} but target_hint "
                                f"was {sc.target_hint!r}")
            finally:
                sc.injector.clear(orch)
            trajectory_ok = True
            if sc.expect_trajectory and sc.breaker_rid:
                trajectory_ok = _drive_trajectory(
                    orch, sched, sc, history_start,
                    timeout_s=trajectory_timeout_s)
            trajectory = (orch.health.trajectory(
                sc.breaker_rid)[history_start:] if sc.breaker_rid else [])
            if mismatch is None and not settled:
                mismatch = (f"breaker for {sc.breaker_rid!r} could not be "
                            "settled back to healthy before the scenario")
            if mismatch is None and not trajectory_ok:
                mismatch = (f"breaker trajectory {trajectory} never "
                            f"contained {sc.expect_trajectory} within "
                            f"{trajectory_timeout_s}s")
            rows.append({
                "scenario": sc.name,
                "injector": sc.injector.name,
                "expected": list(sc.expected),
                "observed": dict(observed),
                "selected": selected,
                "target_hint": sc.target_hint or None,
                "breaker_rid": sc.breaker_rid or None,
                "breaker_trajectory": trajectory,
                "pass": mismatch is None,
                "mismatch_reason": mismatch,
            })
        load_results = [f.result(timeout=120) for f in load_futures]
    finally:
        if own_scheduler:
            sched.shutdown()
    load_statuses = dict(Counter(r.status for r, _ in load_results))
    audit = orch.health.audit()
    return {
        "rows": rows,
        "all_pass": all(r["pass"] for r in rows),
        "audit": audit,
        "policy_leak_free": orch.policy.fully_released(),
        "load_statuses": load_statuses,
        "seed": seed,
        "trace_hash": campaign_trace_hash(
            rows, extra={"audit": audit, "load_statuses": load_statuses}),
    }


def _drive_trajectory(orch: Orchestrator, sched, sc: ChaosScenario,
                      history_start: int, *, timeout_s: float) -> bool:
    """Trickle real tasks until the breaker history SINCE THIS SCENARIO
    contains the expected subsequence (probation → healthy needs actual
    probe traffic)."""
    clock = _clock_of(orch)
    deadline = clock.monotonic() + timeout_s
    while not _is_subsequence(
            sc.expect_trajectory,
            orch.health.trajectory(sc.breaker_rid)[history_start:]):
        if clock.monotonic() > deadline:
            return False
        sched.submit_many([sc.template(-1)])
        clock.sleep(0.01)
    return True


def _settle_healthy(orch: Orchestrator, sched, sc: ChaosScenario, *,
                    timeout_s: float) -> bool:
    """Drive the scenario's breaker back to HEALTHY (no fault armed) so the
    scenario starts from a known state; real tasks feed the probes."""
    from repro.core.health import BreakerState

    clock = _clock_of(orch)
    deadline = clock.monotonic() + timeout_s
    while orch.health.state(sc.breaker_rid) is not BreakerState.HEALTHY:
        if clock.monotonic() > deadline:
            return False
        sched.submit_many([sc.template(-1)])
        clock.sleep(0.01)
    return True
