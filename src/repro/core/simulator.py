"""Planet-scale scenario harness: deterministic virtual-time fleet
simulation with continuous invariant audits.

The chaos harness (:mod:`repro.core.faults`) exercises ONE live
orchestrator with a handful of substrates in real time.  This module
scales the same recovery machinery to *fleet* shape: thousands of
simulated planes and tens of thousands of substrates run in-process on a
:class:`~repro.core.simclock.VirtualClock`, so a simulated hour of
diurnal waves, flash crowds, partitions and breaker storms costs only the
wall-time of the event processing — zero real sleeps on the simulated
path (enforced by :func:`~repro.core.simclock.forbid_real_sleep`).

What is real and what is modeled
--------------------------------

The *control-plane* components under test are the production classes:

- one :class:`~repro.core.health.HealthManager` per plane (virtual
  monotonic clock) drives real circuit breakers for every substrate —
  cooldowns, probation trickles and fidelity trips all run the shipped
  code paths;
- one :class:`~repro.core.policy.PolicyManager` per plane enforces
  concurrency and probation-probe slots;
- one :class:`~repro.core.telemetry.TelemetryBus` per plane (virtual
  clock) carries health / ``twin_shadow`` / breaker events;
- per-substrate :class:`~repro.core.twin.TwinState` ages against the
  virtual clock; twin-fallback serving uses the real ``valid()`` gate;
- multi-hop forwarding uses the real
  :func:`~repro.core.topology.forward_task` budget arithmetic on real
  :class:`~repro.core.tasks.TaskRequest` objects.

Only the *data plane* is modeled: substrate outcomes are drawn from a
seeded RNG instead of invoking hardware adapters.

Invariants audited continuously
-------------------------------

Every simulated run emits a flat trace of event dicts; falsifiable
auditor functions (:data:`AUDITORS`) re-derive each invariant from the
recorded evidence, so a buggy simulator — or a mock trace in the test
suite — is *caught*, not trusted:

- **breaker legality + continuity** — every recorded transition is in
  :data:`~repro.core.health.LEGAL_BREAKER` and chains from the previous
  recorded state (first transition starts at ``healthy``);
- **twin validity** — no task is ever served from a twin whose recorded
  evidence (invalidation, staleness, confidence) says it was invalid;
- **budget arithmetic** — every federation hop decrements the hop budget
  by exactly 1 and the deadline budget by exactly the wire margin;
- **policy-slot balance** — every acquired concurrency slot is released
  exactly once, per session, never going negative;
- **session-id uniqueness** — no two tasks share a session id.

Same seed ⇒ identical trace ⇒ identical :func:`event_trace_hash` — the
determinism contract ``bench_scenarios`` and the test suite assert on.
"""
from __future__ import annotations

import dataclasses
import hashlib
import heapq
import json
import math
import time
import types
from collections import Counter
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import random

from repro.core.health import HealthManager, LEGAL_BREAKER, BreakerState
from repro.core.policy import PolicyManager
from repro.core.simclock import VirtualClock, forbid_real_sleep
from repro.core.tasks import TaskRequest
from repro.core.telemetry import TelemetryBus, TelemetryEvent
from repro.core.topology import (DEFAULT_HOP_BUDGET, HOP_WIRE_MARGIN_MS,
                                 budget_admissible, forward_task,
                                 remaining_budget_ms)
from repro.core.twin import TwinState

__all__ = [
    "SimScenario", "FleetSimulator", "scenario_matrix", "event_trace_hash",
    "run_audits", "AUDITORS", "DEFAULT_SCENARIO_BUILDERS",
    "diurnal_wave", "flash_crowd", "regional_partition",
    "cascading_breaker_storm", "twin_fidelity_collapse",
    "rolling_protocol_upgrade",
]

#: twin-fallback staleness bound used by the simulator's serving gate
TWIN_MAX_AGE_MS = 120_000.0
#: protocol versions a rolling upgrade walks through, oldest first
PROTO_VERSIONS = ("v1.0", "v1.1", "v1.2")


# ---------------------------------------------------------------------------
# scenario DSL


@dataclasses.dataclass
class SimScenario:
    """One entry of the scenario matrix: fleet shape + traffic profile +
    scripted fault events.

    ``rate_profile(frac)`` maps elapsed-fraction-of-run → arrival-rate
    multiplier (diurnal waves, flash crowds).  ``events`` is a list of
    ``(at_s, action, params)`` triples dispatched at virtual instants;
    actions are the simulator verbs (``partition_region``,
    ``arm_faults``, ``twin_collapse``, ``upgrade_wave``, …).
    """

    name: str
    description: str = ""
    planes: int = 100
    substrates_per_plane: int = 10
    regions: int = 4
    duration_s: float = 600.0
    tick_s: float = 10.0
    #: fleet-wide task arrivals per virtual second (before the profile)
    base_rate: float = 10.0
    #: fraction of leaf tasks that take the multi-hop federation path
    forward_fraction: float = 0.15
    #: fraction of tasks that request twin-fallback on failure
    twin_fraction: float = 0.25
    #: virtual seconds between fleet-wide twin sync refreshes
    twin_sync_interval_s: float = 30.0
    rate_profile: Optional[Callable[[float], float]] = None
    events: List[Tuple[float, str, Dict]] = dataclasses.field(
        default_factory=list)

    def rate_at(self, frac: float) -> float:
        mult = self.rate_profile(frac) if self.rate_profile else 1.0
        return max(0.0, self.base_rate * mult)


def _scaled(name: str, description: str, *, planes: int,
            substrates_per_plane: int, duration_s: float,
            **kw) -> SimScenario:
    return SimScenario(name=name, description=description, planes=planes,
                       substrates_per_plane=substrates_per_plane,
                       duration_s=duration_s, **kw)


def diurnal_wave(*, planes: int = 100, substrates_per_plane: int = 10,
                 duration_s: float = 600.0) -> SimScenario:
    """Sinusoidal day/night traffic: rate swings 0.3×–1.7× over the run."""
    return _scaled(
        "diurnal-wave", "sinusoidal day/night arrival wave",
        planes=planes, substrates_per_plane=substrates_per_plane,
        duration_s=duration_s,
        rate_profile=lambda f: 1.0 + 0.7 * math.sin(2 * math.pi * f))


def flash_crowd(*, planes: int = 100, substrates_per_plane: int = 10,
                duration_s: float = 600.0) -> SimScenario:
    """Steady load with an 8× arrival spike over the middle tenth."""
    def profile(f: float) -> float:
        return 8.0 if 0.45 <= f < 0.55 else 1.0
    return _scaled(
        "flash-crowd", "8x arrival spike over the middle tenth of the run",
        planes=planes, substrates_per_plane=substrates_per_plane,
        duration_s=duration_s, rate_profile=profile)


def regional_partition(*, planes: int = 100, substrates_per_plane: int = 10,
                       duration_s: float = 600.0) -> SimScenario:
    """Region 1 loses inter-region connectivity for the middle third:
    forwarded tasks drop at the partition boundary and the region's twins
    age past the staleness bound (twin sync cannot reach them)."""
    sc = _scaled(
        "regional-partition",
        "region 1 partitioned for the middle third of the run",
        planes=planes, substrates_per_plane=substrates_per_plane,
        duration_s=duration_s)
    sc.events = [
        (duration_s * 0.30, "partition_region", {"region": 1}),
        (duration_s * 0.65, "heal_region", {"region": 1}),
    ]
    return sc


def cascading_breaker_storm(*, planes: int = 100,
                            substrates_per_plane: int = 10,
                            duration_s: float = 600.0) -> SimScenario:
    """Hard faults arm on a growing set of substrate cohorts (every 10th
    plane's substrate 0, then 1, then 2): breakers trip in cascade, clear
    mid-run, and re-admission flows through probation probes."""
    sc = _scaled(
        "breaker-storm",
        "cascading hard faults across substrate cohorts, then recovery",
        planes=planes, substrates_per_plane=substrates_per_plane,
        duration_s=duration_s)
    cohorts = min(3, substrates_per_plane)
    for k in range(cohorts):
        sc.events.append((duration_s * (0.20 + 0.07 * k), "arm_faults",
                          {"cohort": k, "fail_p": 0.98}))
    sc.events.append((duration_s * 0.55, "clear_faults", {}))
    return sc


def twin_fidelity_collapse(*, planes: int = 100,
                           substrates_per_plane: int = 10,
                           duration_s: float = 600.0) -> SimScenario:
    """Correlated twin-fidelity collapse in region 0: measured shadow
    divergence storms trip fidelity breakers AND invalidate the twins, so
    twin-fallback serving must refuse until recalibration."""
    sc = _scaled(
        "twin-collapse",
        "correlated measured-divergence collapse in region 0",
        planes=planes, substrates_per_plane=substrates_per_plane,
        duration_s=duration_s, twin_fraction=0.5)
    sc.events = [
        (duration_s * 0.30, "twin_collapse", {"region": 0, "fail_p": 0.9}),
        (duration_s * 0.70, "twin_restore", {"region": 0}),
    ]
    return sc


def rolling_protocol_upgrade(*, planes: int = 100,
                             substrates_per_plane: int = 10,
                             duration_s: float = 600.0) -> SimScenario:
    """Mixed-fleet protocol upgrade: three waves walk the fleet from
    v1.0 through v1.2 while cross-version forwarding keeps negotiating
    the older minor on every hop."""
    sc = _scaled(
        "rolling-upgrade",
        "three-wave v1.0 -> v1.1 -> v1.2 fleet upgrade under load",
        planes=planes, substrates_per_plane=substrates_per_plane,
        duration_s=duration_s, forward_fraction=0.3)
    sc.events = [
        (duration_s * 0.20, "upgrade_wave", {"modulo": 3, "phase": 0,
                                             "version": "v1.1"}),
        (duration_s * 0.40, "upgrade_wave", {"modulo": 3, "phase": 1,
                                             "version": "v1.1"}),
        (duration_s * 0.55, "upgrade_wave", {"modulo": 3, "phase": 2,
                                             "version": "v1.1"}),
        (duration_s * 0.70, "upgrade_wave", {"modulo": 1, "phase": 0,
                                             "version": "v1.2"}),
    ]
    return sc


DEFAULT_SCENARIO_BUILDERS: Tuple[Callable[..., SimScenario], ...] = (
    diurnal_wave, flash_crowd, regional_partition, cascading_breaker_storm,
    twin_fidelity_collapse, rolling_protocol_upgrade,
)


def scenario_matrix(*, planes: int = 100, substrates_per_plane: int = 10,
                    duration_s: float = 600.0,
                    builders: Sequence[Callable[..., SimScenario]] =
                    DEFAULT_SCENARIO_BUILDERS) -> List[SimScenario]:
    """The full scenario matrix at one fleet scale: every builder
    instantiated with the same plane/substrate/duration shape."""
    return [b(planes=planes, substrates_per_plane=substrates_per_plane,
              duration_s=duration_s) for b in builders]


# ---------------------------------------------------------------------------
# trace hashing


def event_trace_hash(trace: Sequence[Dict]) -> str:
    """Canonical digest of a simulated trace.  Virtual timestamps are a
    pure function of the event sequence, so they are INCLUDED — two runs
    hash equal iff they produced bit-identical behavior."""
    h = hashlib.sha256()
    for ev in trace:
        h.update(json.dumps(ev, sort_keys=True, separators=(",", ":"),
                            default=str).encode())
        h.update(b"\n")
    return h.hexdigest()


# ---------------------------------------------------------------------------
# invariant auditors — falsifiable: they re-derive each invariant from the
# recorded evidence, so they catch both simulator bugs and doctored traces

_LEGAL_BY_VALUE: Dict[str, Tuple[str, ...]] = {
    src.value: tuple(d.value for d in dsts)
    for src, dsts in LEGAL_BREAKER.items()
}

_MAX_VIOLATIONS_REPORTED = 25


def _capped(violations: List[str]) -> List[str]:
    if len(violations) > _MAX_VIOLATIONS_REPORTED:
        extra = len(violations) - _MAX_VIOLATIONS_REPORTED
        return violations[:_MAX_VIOLATIONS_REPORTED] + [
            f"... {extra} more violation(s) suppressed"]
    return violations


def audit_breaker_legality(trace: Sequence[Dict]) -> List[str]:
    """Every breaker transition is legal AND continuous per resource:
    ``src`` must equal the previously recorded ``dst`` (implicit start is
    ``healthy``) and ``src -> dst`` must appear in LEGAL_BREAKER."""
    v: List[str] = []
    last: Dict[Tuple, str] = {}
    for ev in trace:
        if ev.get("kind") != "breaker":
            continue
        key = (ev.get("plane"), ev.get("rid"))
        src, dst = ev.get("src"), ev.get("dst")
        prev = last.get(key, BreakerState.HEALTHY.value)
        if src != prev:
            v.append(f"breaker discontinuity for {key}: transition claims "
                     f"src={src!r} but last recorded state was {prev!r}")
        if dst not in _LEGAL_BY_VALUE.get(src, ()):
            v.append(f"illegal breaker transition {src!r} -> {dst!r} "
                     f"for {key}")
        last[key] = dst
    return _capped(v)


def audit_twin_validity(trace: Sequence[Dict]) -> List[str]:
    """No serve from an invalid twin: for every ``twin_serve`` event,
    re-derive validity from the recorded evidence (invalidation reason,
    age vs bound, confidence vs floor) instead of trusting the flag."""
    v: List[str] = []
    for ev in trace:
        if ev.get("kind") != "twin_serve":
            continue
        where = f"session {ev.get('session')!r} on {ev.get('rid')!r}"
        if not ev.get("valid", False):
            v.append(f"twin served while flagged invalid: {where}")
        if ev.get("invalidation_reason"):
            v.append(f"twin served while invalidated "
                     f"({ev['invalidation_reason']!r}): {where}")
        age, bound = ev.get("age_ms"), ev.get("max_age_ms")
        if age is not None and bound is not None and age > bound:
            v.append(f"twin served while stale ({age:.0f}ms > "
                     f"{bound:.0f}ms): {where}")
        conf, floor = ev.get("confidence"), ev.get("min_confidence")
        if conf is not None and floor is not None and conf < floor:
            v.append(f"twin served below confidence floor ({conf:.2f} < "
                     f"{floor:.2f}): {where}")
    return _capped(v)


def audit_budget_arithmetic(trace: Sequence[Dict]) -> List[str]:
    """Hop/deadline budget arithmetic is EXACT: each hop decrements the
    hop budget by 1 and the deadline budget by precisely the wire margin
    (no drift, no rounding)."""
    v: List[str] = []
    for ev in trace:
        if ev.get("kind") != "hop":
            continue
        where = f"session {ev.get('session')!r} via {ev.get('src')!r}"
        if ev.get("hop_after") != ev.get("hop_before") - 1:
            v.append(f"hop budget not decremented by exactly 1 "
                     f"({ev.get('hop_before')} -> {ev.get('hop_after')}): "
                     f"{where}")
        before, after = ev.get("budget_before"), ev.get("budget_after")
        margin = ev.get("margin_ms", HOP_WIRE_MARGIN_MS)
        if before is not None:
            if after != before - margin:
                v.append(f"deadline budget arithmetic inexact "
                         f"({before!r} - {margin!r} != {after!r}): {where}")
        elif after is not None:
            v.append(f"deadline budget appeared from nowhere "
                     f"(None -> {after!r}): {where}")
    return _capped(v)


def audit_policy_slots(trace: Sequence[Dict]) -> List[str]:
    """Concurrency-slot accounting balances: per substrate the running
    acquire/release count never goes negative and ends at zero, and each
    session releases exactly what it acquired."""
    v: List[str] = []
    balance: Dict[Tuple, int] = {}
    per_session: Dict[Tuple, int] = {}
    for ev in trace:
        kind = ev.get("kind")
        if kind not in ("slot_acquire", "slot_release"):
            continue
        key = (ev.get("plane"), ev.get("rid"))
        skey = (ev.get("session"), ev.get("rid"))
        delta = 1 if kind == "slot_acquire" else -1
        balance[key] = balance.get(key, 0) + delta
        per_session[skey] = per_session.get(skey, 0) + delta
        if balance[key] < 0:
            v.append(f"slot released without acquire on {key} "
                     f"(session {ev.get('session')!r})")
    for key, n in balance.items():
        if n > 0:
            v.append(f"{n} leaked slot(s) on {key}")
    for (session, rid), n in per_session.items():
        if n != 0:
            v.append(f"session {session!r} acquire/release imbalance "
                     f"({n:+d}) on {rid!r}")
    return _capped(v)


def audit_session_uniqueness(trace: Sequence[Dict]) -> List[str]:
    v: List[str] = []
    seen: set = set()
    for ev in trace:
        if ev.get("kind") != "session":
            continue
        sid = ev.get("session")
        if sid in seen:
            v.append(f"duplicate session id {sid!r}")
        seen.add(sid)
    return _capped(v)


AUDITORS: Dict[str, Callable[[Sequence[Dict]], List[str]]] = {
    "breaker_legality": audit_breaker_legality,
    "twin_validity": audit_twin_validity,
    "budget_arithmetic": audit_budget_arithmetic,
    "policy_slots": audit_policy_slots,
    "session_uniqueness": audit_session_uniqueness,
}


def run_audits(trace: Sequence[Dict]) -> Dict[str, List[str]]:
    """Run every registered auditor; returns ``{name: [violations...]}``
    (empty lists mean the invariant held)."""
    return {name: fn(trace) for name, fn in AUDITORS.items()}


# ---------------------------------------------------------------------------
# fleet model


def _desc_shim(rid: str, max_concurrent: int):
    """The minimal descriptor surface PolicyManager.acquire consumes —
    the simulator models the data plane, not the registry."""
    return types.SimpleNamespace(
        resource_id=rid,
        capability=types.SimpleNamespace(
            policy=types.SimpleNamespace(max_concurrent=max_concurrent)))


class _SimSubstrate:
    __slots__ = ("rid", "desc", "base_fail_p", "fault_fail_p", "twin",
                 "latency_ms")

    def __init__(self, rid: str, now: Callable[[], float],
                 latency_ms: float, base_fail_p: float):
        self.rid = rid
        self.desc = _desc_shim(rid, max_concurrent=4)
        self.base_fail_p = base_fail_p
        self.fault_fail_p: Optional[float] = None   # armed fault override
        self.latency_ms = latency_ms
        self.twin = TwinState(twin_id=f"twin:{rid}", resource_id=rid,
                              time_fn=now)
        self.twin.last_sync = now()
        self.twin.calibration_ts = now()

    def fail_p(self) -> float:
        return (self.fault_fail_p if self.fault_fail_p is not None
                else self.base_fail_p)


class _SimPlane:
    __slots__ = ("plane_id", "index", "region", "tier", "proto", "bus",
                 "policy", "health", "substrates", "partitioned")

    def __init__(self, plane_id: str, index: int, region: int, tier: str,
                 clock: VirtualClock, substrates: int, rng: random.Random):
        self.plane_id = plane_id
        self.index = index
        self.region = region
        self.tier = tier                        # leaf | regional | core
        self.proto = PROTO_VERSIONS[0]
        self.partitioned = False
        self.bus = TelemetryBus(history=8, clock=clock)
        self.policy = PolicyManager()
        self.health = HealthManager(self.bus, self.policy,
                                    cooldown_s=5.0, probes_to_close=2,
                                    clock=clock.monotonic)
        self.substrates = [
            _SimSubstrate(f"{plane_id}/s{j}", clock.now,
                          latency_ms=1.0 + rng.random() * 4.0,
                          base_fail_p=0.002 + rng.random() * 0.008)
            for j in range(substrates)
        ]


# ---------------------------------------------------------------------------
# the simulator


class FleetSimulator:
    """Single-threaded discrete-event simulator over a virtual clock.

    Construction builds the fleet (real per-plane health/policy/telemetry
    stacks on the shared :class:`VirtualClock`); :meth:`run` executes the
    scenario's event heap — arrival ticks, twin syncs, scripted fault
    actions — appending every observable to ``self.trace`` and returning
    a report with audit results, the trace hash and the real-sleep count
    (which must be zero).
    """

    def __init__(self, scenario: SimScenario, seed: int = 0):
        self.sc = scenario
        self.seed = seed
        self.clock = VirtualClock()
        self.rng = random.Random(seed)
        self.trace: List[Dict] = []
        self._task_seq = 0
        self._events_processed = 0
        self._outcomes: Counter = Counter()
        self._breaker_transitions = 0
        self._proto_pairs: Counter = Counter()

        n_regions = max(1, scenario.regions)
        self.planes: List[_SimPlane] = []
        for i in range(scenario.planes):
            region = i % n_regions
            # one core plane, one regional hub per region, the rest leaves
            if i == 0:
                tier = "core"
            elif i <= n_regions:
                tier = "regional"
            else:
                tier = "leaf"
            plane = _SimPlane(f"{scenario.name}-p{i:04d}", i, region, tier,
                              self.clock, scenario.substrates_per_plane,
                              self.rng)
            plane.bus.subscribe(self._make_breaker_listener(plane))
            self.planes.append(plane)
        self._regional: Dict[int, _SimPlane] = {
            p.region: p for p in self.planes if p.tier == "regional"}
        self._core: _SimPlane = self.planes[0]
        self._leaves: List[_SimPlane] = [p for p in self.planes
                                         if p.tier == "leaf"] or self.planes

    # -- trace ----------------------------------------------------------------
    def _record(self, kind: str, **fields) -> None:
        ev = {"t": round(self.clock.monotonic(), 6), "kind": kind}
        ev.update(fields)
        self.trace.append(ev)

    def _make_breaker_listener(self, plane: _SimPlane):
        def listen(ev: TelemetryEvent, _plane=plane) -> None:
            if ev.kind == "breaker":
                self._breaker_transitions += 1
                self._record("breaker", plane=_plane.plane_id,
                             rid=ev.resource_id, src=ev.fields["from"],
                             dst=ev.fields["to"], reason=ev.fields["reason"])
        return listen

    # -- scripted scenario actions --------------------------------------------
    def _dispatch(self, action: str, params: Dict) -> None:
        self._record("scenario_event", action=action, **params)
        if action == "partition_region":
            for p in self.planes:
                if p.region == params["region"]:
                    p.partitioned = True
        elif action == "heal_region":
            for p in self.planes:
                if p.region == params["region"]:
                    p.partitioned = False
        elif action == "arm_faults":
            cohort, fail_p = params["cohort"], params["fail_p"]
            for p in self.planes:
                if p.index % 10 == 0 and cohort < len(p.substrates):
                    p.substrates[cohort].fault_fail_p = fail_p
        elif action == "clear_faults":
            for p in self.planes:
                for s in p.substrates:
                    s.fault_fail_p = None
        elif action == "twin_collapse":
            for p in self.planes:
                if p.region != params["region"]:
                    continue
                for s in p.substrates:
                    # the collapse takes the hardware down WITH its twin:
                    # the serving gate must refuse the fallback, not lean
                    # on an invalidated surrogate
                    if "fail_p" in params:
                        s.fault_fail_p = params["fail_p"]
                    s.twin.invalidation_reason = "correlated fidelity collapse"
                    s.twin.confidence = 0.05
                    # measured-divergence storm: the real fidelity trip
                    # needs a streak of beyond-OPEN comparisons
                    for _ in range(2):
                        p.bus.emit(TelemetryEvent(
                            s.rid, "twin_shadow",
                            {"divergence": 0.99, "tolerance": 0.05}))
        elif action == "twin_restore":
            now = self.clock.now()
            for p in self.planes:
                if p.region != params["region"]:
                    continue
                for s in p.substrates:
                    s.fault_fail_p = None
                    s.twin.invalidation_reason = ""
                    s.twin.confidence = 1.0
                    s.twin.last_sync = now
                    s.twin.calibration_ts = now
        elif action == "upgrade_wave":
            modulo, phase = params["modulo"], params["phase"]
            for p in self.planes:
                if p.index % modulo == phase:
                    p.proto = params["version"]
        else:
            raise ValueError(f"unknown scenario action {action!r}")

    def _twin_sync(self) -> None:
        """Fleet-wide twin refresh; partitioned regions are unreachable,
        so their twins keep aging toward the staleness bound."""
        now = self.clock.now()
        refreshed = 0
        for p in self.planes:
            if p.partitioned:
                continue
            for s in p.substrates:
                if not s.twin.invalidation_reason:
                    s.twin.last_sync = now
                    s.twin.observations += 1
                    refreshed += 1
        self._record("twin_sync", refreshed=refreshed)

    # -- task path ------------------------------------------------------------
    def _next_session(self) -> str:
        sid = f"{self.sc.name}/{self.seed}/s{self._task_seq:07d}"
        self._task_seq += 1
        return sid

    def _forward_chain(self, origin: _SimPlane) -> List[_SimPlane]:
        chain = []
        hub = self._regional.get(origin.region)
        if hub is not None and hub is not origin:
            chain.append(hub)
        if self._core is not origin and (not chain or
                                         chain[-1] is not self._core):
            chain.append(self._core)
        return chain

    def _run_task(self) -> None:
        sc, rng = self.sc, self.rng
        sid = self._next_session()
        origin = self._leaves[rng.randrange(len(self._leaves))]
        self._record("session", session=sid, plane=origin.plane_id)
        wants_twin = rng.random() < sc.twin_fraction

        exec_plane = origin
        if origin.tier == "leaf" and rng.random() < sc.forward_fraction:
            task = TaskRequest(function="inference", input_modality="vector",
                              output_modality="vector",
                              latency_budget_ms=60.0, task_id=sid)
            src = origin
            for hop_target in self._forward_chain(origin):
                if src.partitioned != hop_target.partitioned or (
                        src.partitioned and src.region != hop_target.region):
                    self._record("partition_drop", session=sid,
                                 src=src.plane_id, dst=hop_target.plane_id)
                    self._outcomes["partition_drop"] += 1
                    return
                ok, why = budget_admissible(task)
                if not ok:
                    self._record("hop_refused", session=sid,
                                 src=src.plane_id, reason=why)
                    self._outcomes["budget_refused"] += 1
                    return
                hop_before = (task.hop_budget if task.hop_budget is not None
                              else DEFAULT_HOP_BUDGET)
                budget_before = remaining_budget_ms(task)
                fwd = forward_task(task, src.plane_id)
                self._record(
                    "hop", session=sid, src=src.plane_id,
                    dst=hop_target.plane_id, hop_before=hop_before,
                    hop_after=fwd.hop_budget, budget_before=budget_before,
                    budget_after=fwd.deadline_budget_ms,
                    margin_ms=HOP_WIRE_MARGIN_MS)
                self._proto_pairs[(src.proto, hop_target.proto)] += 1
                task, src = fwd, hop_target
            exec_plane = src

        self._execute(sid, exec_plane, wants_twin)

    def _execute(self, sid: str, plane: _SimPlane, wants_twin: bool) -> None:
        rng = self.rng
        subs = plane.substrates
        start = rng.randrange(len(subs))
        tried: List[_SimSubstrate] = []
        for attempt in range(min(3, len(subs))):
            sub = subs[(start + attempt) % len(subs)]
            tried.append(sub)
            if not plane.policy.acquire(sub.desc, timeout_s=0.0):
                self._outcomes["busy"] += 1
                continue
            self._record("slot_acquire", session=sid, plane=plane.plane_id,
                         rid=sub.rid)
            try:
                allowed, token, reason = plane.health.begin_attempt(sub.rid)
                if not allowed:
                    self._record("refused", session=sid, rid=sub.rid,
                                 reason=reason)
                    self._outcomes["quarantine_refused"] += 1
                    continue
                ok = rng.random() >= sub.fail_p()
                latency = sub.latency_ms * (1.0 + rng.random())
                plane.health.finish_attempt(
                    token, ok, kind="simulated fault" if not ok else "",
                    latency_ms=latency)
                self._record("outcome", session=sid, plane=plane.plane_id,
                             rid=sub.rid, ok=ok,
                             probe=bool(token and token.probe))
                if ok:
                    self._outcomes["completed"] += 1
                    return
                self._outcomes["failed_attempt"] += 1
            finally:
                plane.policy.release(sub.desc)
                self._record("slot_release", session=sid,
                             plane=plane.plane_id, rid=sub.rid)
            if attempt + 1 < min(3, len(subs)):
                self._record("reroute", session=sid, plane=plane.plane_id)
        # hardware path exhausted — twin fallback if the task asked for it
        if wants_twin and tried:
            self._try_twin(sid, plane, tried[0])
        else:
            self._outcomes["exhausted"] += 1

    def _try_twin(self, sid: str, plane: _SimPlane,
                  sub: _SimSubstrate) -> None:
        tw = sub.twin
        valid, why = tw.valid(TWIN_MAX_AGE_MS)
        evidence = dict(
            session=sid, plane=plane.plane_id, rid=sub.rid, valid=valid,
            reason=why, age_ms=round(tw.age_ms(), 3),
            max_age_ms=TWIN_MAX_AGE_MS,
            confidence=round(tw.confidence, 4),
            min_confidence=TwinState.DEFAULT_MIN_CONFIDENCE,
            invalidation_reason=tw.invalidation_reason or None)
        if valid:
            self._record("twin_serve", **evidence)
            self._outcomes["twin_served"] += 1
        else:
            self._record("twin_refused", **evidence)
            self._outcomes["twin_refused"] += 1

    # -- event loop -----------------------------------------------------------
    def _build_heap(self) -> List[Tuple[float, int, str, Dict]]:
        sc = self.sc
        heap: List[Tuple[float, int, str, Dict]] = []
        seq = 0
        t = sc.tick_s
        while t <= sc.duration_s:
            heap.append((t, seq, "tick", {}))
            seq += 1
            t += sc.tick_s
        t = sc.twin_sync_interval_s
        while t <= sc.duration_s:
            heap.append((t, seq, "twin_sync", {}))
            seq += 1
            t += sc.twin_sync_interval_s
        for at_s, action, params in sc.events:
            heap.append((at_s, seq, action, dict(params)))
            seq += 1
        heapq.heapify(heap)
        return heap

    def run(self) -> Dict:
        """Execute the scenario; returns the report dict.  The entire
        simulated path runs under :func:`forbid_real_sleep` — any real
        ``time.sleep`` raises, which is the zero-real-sleep guarantee."""
        sc = self.sc
        wall_start = time.perf_counter()
        heap = self._build_heap()
        with forbid_real_sleep(strict=True) as sleep_counter:
            while heap:
                at_s, _seq, kind, params = heapq.heappop(heap)
                self.clock.advance_to(at_s)
                self._events_processed += 1
                if kind == "tick":
                    frac = at_s / sc.duration_s
                    expected = sc.rate_at(frac) * sc.tick_s
                    n = int(expected)
                    if self.rng.random() < expected - n:
                        n += 1
                    for _ in range(n):
                        self._run_task()
                elif kind == "twin_sync":
                    self._twin_sync()
                else:
                    self._dispatch(kind, params)
        wall_s = time.perf_counter() - wall_start

        violations = run_audits(self.trace)
        leaked = [p.plane_id for p in self.planes
                  if not p.policy.fully_released()]
        if leaked:
            violations.setdefault("policy_slots", []).extend(
                f"live PolicyManager reports leaked slots on {pid}"
                for pid in leaked[:5])
        started_open = sum(p.health.audit()["started_while_open"]
                           for p in self.planes)
        if started_open:
            violations.setdefault("breaker_legality", []).append(
                f"{started_open} attempt(s) started while quarantined")
        return {
            "scenario": sc.name,
            "description": sc.description,
            "seed": self.seed,
            "planes": sc.planes,
            "substrates": sc.planes * sc.substrates_per_plane,
            "virtual_duration_s": sc.duration_s,
            "tasks": self._task_seq,
            "events_processed": self._events_processed,
            "trace_events": len(self.trace),
            "outcomes": dict(self._outcomes),
            "breaker_transitions": self._breaker_transitions,
            "proto_pairs": {f"{a}->{b}": n
                            for (a, b), n in sorted(self._proto_pairs.items())},
            "violations": violations,
            "violations_total": sum(len(v) for v in violations.values()),
            "trace_hash": event_trace_hash(self.trace),
            "real_sleep_calls": sleep_counter["calls"],
            "virtual_sleeps": self.clock.virtual_sleeps,
            "wall_s": round(wall_s, 4),
        }


def run_matrix(scenarios: Sequence[SimScenario], seed: int = 0) -> List[Dict]:
    """Run every scenario in the matrix (each with its own fleet) and
    return the per-scenario reports."""
    return [FleetSimulator(s, seed=seed).run() for s in scenarios]
