"""Plane topology: identity, federation graph, and multi-hop budgets.

PR 4's federation was a single hop (edge → cloud); the paper frames
phys-MCP as the control plane of a *multi-tier* edge-cloud continuum, so
planes must chain (device → edge → fog → cloud) without two failure modes
ad-hoc single-hop code never had to face:

- **cycles** — a plane transitively re-registering itself (A federates B,
  B federates C, someone federates A into C) would forward tasks in a loop
  forever.  Every plane therefore carries a stable :class:`PlaneIdentity`
  (``plane_id``), every gateway exposes its transitive *reachable set* of
  plane ids (``GET /v1/topology``), and federation refuses with
  ``FEDERATION_CYCLE`` whenever the registering parent already appears in
  the child's reachable set.
- **unbounded forwarding** — substrate latency envelopes must be respected
  end-to-end (Momeni et al.), which a per-plane deadline cannot guarantee
  once tasks hop: each forward decrements a ``hop_budget`` and subtracts a
  wire margin from ``deadline_budget_ms``; a plane whose remaining budget
  cannot absorb another hop keeps the task local or rejects it with the
  structured ``DEADLINE`` code.

Both budgets live on :class:`~repro.core.tasks.TaskRequest` (additive
MINOR protocol fields), so they survive the wire unchanged and every plane
along the chain enforces them with the same code paths — the matcher
refuses to *place* a budget-exhausted task on a federated plane, which is
strictly earlier (and cheaper) than the remote side rejecting it.
"""
from __future__ import annotations

import os
import threading
from typing import Dict, FrozenSet, Iterable, Optional, Tuple

from repro.core.errors import ControlPlaneError, ErrorCode
from repro.core.tasks import TaskRequest

#: wire margin (ms) subtracted from a task's remaining deadline budget per
#: forwarding hop — matches the transport margin the federated descriptor
#: advertises, so the budget math and the matcher's T term agree
HOP_WIRE_MARGIN_MS = 5.0

#: hop budget stamped onto a task at its FIRST forward when the client did
#: not set one: deep enough for any sane tier chain, finite so a
#: mis-configured topology can never forward forever
DEFAULT_HOP_BUDGET = 8


def new_plane_id(name: str = "plane") -> str:
    """Stable-for-the-process, globally-unique plane identity.  The name
    prefix keeps logs readable; the token keeps two planes that picked the
    same name (every test calls one "edge") distinct."""
    return f"{name}-{os.getpid() % 0xFFFF:04x}{os.urandom(3).hex()}"


class PlaneTopology:
    """One plane's view of the federation graph: its own identity plus the
    transitive reachable set of every child plane federated into it.

    Thread-safe; owned by the :class:`~repro.core.orchestrator.Orchestrator`
    and shared with the gateway (which serves it at ``/v1/topology``) and
    with :class:`~repro.substrates.remote_plane.RemotePlaneAdapter` (which
    checks cycles against it before registering a child).
    """

    def __init__(self, name: str = "plane", plane_id: Optional[str] = None):
        self.name = name
        self.plane_id = plane_id or new_plane_id(name)
        self._children: Dict[str, FrozenSet[str]] = {}
        self._lock = threading.Lock()

    def set_name(self, name: str) -> None:
        """Adopt a human-readable name (the gateway's ``plane=``) without
        re-minting the identity."""
        self.name = name

    # -- federation graph -----------------------------------------------------
    def reachable(self) -> FrozenSet[str]:
        """Every plane id a task submitted here could be forwarded to:
        this plane plus the transitive closure of its federated children."""
        with self._lock:
            out = {self.plane_id}
            for child_set in self._children.values():
                out |= child_set
            return frozenset(out)

    def add_child(self, child_plane_id: str,
                  child_reachable: Iterable[str]) -> None:
        """Record a federated child plane.  Refuses with
        ``FEDERATION_CYCLE`` when this plane is already reachable *through*
        the child — registering it would let a forwarded task come home."""
        reach = frozenset(child_reachable) | {child_plane_id}
        if self.plane_id in reach:
            raise ControlPlaneError(
                ErrorCode.FEDERATION_CYCLE,
                f"federating plane {child_plane_id!r} into "
                f"{self.plane_id!r} would create a cycle (this plane is "
                f"reachable through it)",
                {"plane_id": self.plane_id,
                 "child_plane_id": child_plane_id,
                 "child_reachable": sorted(reach)})
        with self._lock:
            self._children[child_plane_id] = reach

    def remove_child(self, child_plane_id: str) -> None:
        with self._lock:
            self._children.pop(child_plane_id, None)

    def children(self) -> Tuple[str, ...]:
        with self._lock:
            return tuple(sorted(self._children))

    def to_dict(self) -> Dict:
        return {"plane_id": self.plane_id, "name": self.name,
                "children": list(self.children()),
                "reachable": sorted(self.reachable())}


# ---------------------------------------------------------------------------
# multi-hop budgets


def remaining_budget_ms(task: TaskRequest) -> Optional[float]:
    """The task's remaining end-to-end deadline budget: the explicit
    ``deadline_budget_ms`` once any hop has stamped one, else the client's
    original latency budget (which SEEDS the hop budget at the first
    forward), else None (unbounded)."""
    if task.deadline_budget_ms is not None:
        return task.deadline_budget_ms
    return task.latency_budget_ms


def budget_admissible(task: TaskRequest,
                      margin_ms: float = HOP_WIRE_MARGIN_MS
                      ) -> Tuple[bool, str]:
    """May this task absorb ONE more federation hop?  Consulted by the
    matcher for ``federated_plane`` candidates — refusing placement here is
    what turns budget exhaustion into a structured ``DEADLINE`` rejection
    instead of a remote-side timeout."""
    if task.hop_budget is not None and task.hop_budget <= 0:
        return False, "hop budget exhausted (0 hops remaining)"
    budget = remaining_budget_ms(task)
    if budget is not None and budget <= margin_ms:
        return False, (f"deadline budget {budget:.1f}ms cannot absorb "
                       f"another hop (wire margin {margin_ms:.1f}ms)")
    return True, "ok"


def forward_task(task: TaskRequest, via_plane_id: str,
                 margin_ms: float = HOP_WIRE_MARGIN_MS,
                 default_hop_budget: int = DEFAULT_HOP_BUDGET) -> TaskRequest:
    """The wire form of one federation hop: decrement the hop budget
    (stamping the default on a task that never carried one), subtract the
    wire margin from the remaining deadline budget, and append the
    forwarding plane to the route.

    Raises ``DEADLINE`` when either budget is exhausted — callers normally
    never see this (the matcher refuses placement first via
    :func:`budget_admissible`); it is the defense line for directed tasks
    that bypass ranking.
    """
    ok, why = budget_admissible(task, margin_ms)
    if not ok:
        raise ControlPlaneError(
            ErrorCode.DEADLINE,
            f"cannot forward task {task.task_id}: {why}",
            {"task_id": task.task_id, "route": list(task.route),
             "via": via_plane_id})
    hops = (task.hop_budget if task.hop_budget is not None
            else default_hop_budget)
    budget = remaining_budget_ms(task)
    return task.clone(
        hop_budget=hops - 1,
        deadline_budget_ms=(budget - margin_ms if budget is not None
                            else None),
        route=task.route + (via_plane_id,))
