"""Executable twin tier: shadow, fallback and speculative serving.

The :class:`TwinExecutor` drives adapters' executable surrogates
(:class:`~repro.core.twin.TwinSurrogate`) in three modes:

- **shadow** — the twin runs concurrently with the real invocation (on the
  executor's shadow pool while a scheduler worker drives the hardware); the
  outputs are compared and the MEASURED divergence — not adapter-self-
  reported drift — feeds :meth:`TwinSyncManager.observe_divergence` (twin
  confidence + fidelity) and, via ``twin_shadow`` telemetry events, the
  HealthManager's fidelity trips.
- **fallback** — when hardware is quarantined (breaker open), saturated past
  the orchestrator's queue-factor threshold, or a deadline lapsed while
  queued, tasks that opt in (``twin_mode="fallback"``) are served by a
  *valid* twin instead of rejected, with ``served_by: twin`` provenance and
  degraded-confidence accounting in result telemetry and the
  OrchestrationTrace.
- **speculate** — the twin answers immediately; real hardware confirms
  asynchronously (:meth:`ControlPlaneScheduler.submit_speculative`) and a
  beyond-tolerance mismatch retro-invalidates the twin.

Serve-time validity is checked ATOMICALLY (under the TwinSyncManager lock)
and every serve is logged with the validity + confidence captured at that
instant — ``audit()['twin_serves_invalid']`` must stay 0, which the fidelity
test suite and ``bench_twin`` assert.
"""
from __future__ import annotations

import itertools
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Dict, List, Optional, Tuple

from repro.core.invocation import InvocationResult
from repro.core.tasks import TaskRequest
from repro.core.telemetry import TelemetryBus, TelemetryEvent
from repro.core.twin import TwinNotReady, TwinSyncManager

_serve_ids = itertools.count(1)


class TwinUnavailable(RuntimeError):
    """No valid executable twin can serve this task right now."""


class TwinExecutor:
    """Runs executable twins for the orchestrator (shadow / fallback /
    speculate).  Thread-safe; the shadow pool is created lazily so control
    planes that never use twins spawn no extra threads."""

    SHADOW_TIMEOUT_S = 30.0
    SHADOW_WORKERS = 4

    #: ONE process-wide shadow pool shared by every executor: orchestrators
    #: are created freely (per chaos scenario, per test) and have no close
    #: lifecycle, so a per-instance pool would leak its threads; the shared
    #: pool is lazily created once and bounded at SHADOW_WORKERS threads no
    #: matter how many control planes exist
    _shared_pool: Optional[ThreadPoolExecutor] = None  # guarded_by: _shared_pool_lock
    _shared_pool_lock = threading.Lock()

    def __init__(self, twins: TwinSyncManager, bus: TelemetryBus):
        self.twins = twins
        self.bus = bus
        self._lock = threading.Lock()
        self._serve_log: List[Dict] = []     # guarded_by: _lock
        self._counters: Dict[str, int] = {   # guarded_by: _lock
            "twin_serves": 0,
            "twin_serves_invalid": 0,     # MUST stay 0: serve-validity invariant
            "twin_serve_refusals": 0,
            "speculations": 0,
            "speculations_confirmed": 0,
            "retro_invalidated": 0,
            "shadow_runs": 0,
            "shadow_not_ready": 0,
            "shadow_failures": 0,
        }

    # -- shadow mode ----------------------------------------------------------
    @classmethod
    def _shadow_pool(cls) -> ThreadPoolExecutor:
        with TwinExecutor._shared_pool_lock:
            if TwinExecutor._shared_pool is None:
                TwinExecutor._shared_pool = ThreadPoolExecutor(
                    max_workers=cls.SHADOW_WORKERS,
                    thread_name_prefix="phys-mcp-twin-shadow")
            return TwinExecutor._shared_pool

    def shadow_start(self, task: TaskRequest, rid: str) -> Optional[Future]:
        """Launch the twin concurrently with the real invocation.  Returns
        None when the resource has no executable twin."""
        tw = self.twins.get(rid)
        if tw is None or tw.surrogate is None:
            return None
        return self._shadow_pool().submit(tw.surrogate.simulate, task)

    def shadow_finish(self, task: TaskRequest, rid: str,
                      result: InvocationResult,
                      fut: Future) -> Optional[float]:
        """Join the shadow run and compare against the real result.  Returns
        the measured divergence (None when the twin could not answer); feeds
        the twin-sync manager and emits a ``twin_shadow`` event either way
        it *can*."""
        tw = self.twins.get(rid)
        if tw is None or tw.surrogate is None:
            return None
        try:
            raw = fut.result(timeout=self.SHADOW_TIMEOUT_S)
        except TwinNotReady:
            with self._lock:
                self._counters["shadow_not_ready"] += 1
            return None
        except Exception:                                  # noqa: BLE001
            with self._lock:
                self._counters["shadow_failures"] += 1
            return None
        sur = tw.surrogate
        div = float(sur.divergence(result.output, raw.get("output")))
        with self._lock:
            self._counters["shadow_runs"] += 1
        self.twins.observe_divergence(rid, div, sur.tolerance)
        self.bus.emit(TelemetryEvent(rid, "twin_shadow", {
            "divergence": round(div, 6), "tolerance": sur.tolerance,
            "within": div <= sur.tolerance, "mode": "shadow",
            "task_id": task.task_id}))
        return div

    @staticmethod
    def shadow_abandon(fut: Optional[Future]) -> None:
        """Drop a shadow run whose real attempt failed: cancel if still
        queued, otherwise let it finish and swallow its outcome."""
        if fut is None or fut.cancel():
            return
        fut.add_done_callback(lambda f: f.exception())

    def observe(self, task: TaskRequest, rid: str,
                result: InvocationResult) -> None:
        """Feed a successful real invocation to the surrogate's learning
        hook (record/roofline twins stay current).  Never raises."""
        tw = self.twins.get(rid)
        if tw is None or tw.surrogate is None:
            return
        try:
            tw.surrogate.observe(task, {"output": result.output,
                                        "telemetry": result.telemetry})
        except Exception:                                  # noqa: BLE001
            pass

    # -- twin-served execution (fallback / speculate) --------------------------
    def serve(self, task: TaskRequest, rid: str, mode: str,
              reason: str = "") -> InvocationResult:
        """Serve ``task`` from the resource's twin, refusing unless the twin
        is VALID at serve time (validity + confidence captured atomically).
        Raises :class:`TwinUnavailable` / :class:`TwinNotReady` on refusal.
        """
        tw, ok, why, conf = self.twins.check_serve(
            rid, task.max_twin_age_ms, task.twin_min_confidence)
        if tw is None or not ok:
            with self._lock:
                self._counters["twin_serve_refusals"] += 1
            raise TwinUnavailable(why)
        if tw.surrogate is None:
            with self._lock:
                self._counters["twin_serve_refusals"] += 1
            raise TwinUnavailable("twin is not executable")
        try:
            raw = tw.surrogate.simulate(task)
        except TwinNotReady:
            with self._lock:
                self._counters["twin_serve_refusals"] += 1
            raise
        except Exception as e:                             # noqa: BLE001
            # a crashing surrogate must refuse cleanly, exactly like real
            # hardware failing an attempt — never escape into the caller
            with self._lock:
                self._counters["twin_serve_refusals"] += 1
            raise TwinUnavailable(f"twin simulate failed: {e}") from e
        telemetry = dict(raw.get("telemetry", {}))
        missing = [f for f in task.required_telemetry if f not in telemetry]
        if missing:
            with self._lock:
                self._counters["twin_serve_refusals"] += 1
            raise TwinUnavailable(
                f"twin cannot satisfy telemetry contract (missing {missing})")
        serve_id = next(_serve_ids)
        telemetry.update({
            "served_by": "twin",
            "twin_id": tw.twin_id,
            "twin_kind": tw.kind,
            "twin_mode": mode,
            "twin_confidence": round(conf, 4),
            # twin answers are honest about their epistemic status: anything
            # below full confidence is flagged for downstream accounting
            "degraded_confidence": bool(conf < 1.0),
        })
        if reason:
            telemetry["twin_serve_reason"] = reason
        result = InvocationResult(
            task_id=task.task_id, resource_id=rid, status="completed",
            output=raw.get("output"), telemetry=telemetry,
            artifacts=dict(raw.get("artifacts", {})),
            timing_ms={"backend_ms": float(raw.get("backend_ms", 0.0)),
                       "total_ms": float(raw.get("backend_ms", 0.0)),
                       "observation_ms": float(
                           telemetry.get("observation_ms", 0.0))},
            contracts={}, session_id=f"twin-serve-{serve_id:05d}")
        entry = {
            "serve_id": serve_id, "task_id": task.task_id,
            "resource_id": rid, "twin_id": tw.twin_id, "mode": mode,
            "valid_at_serve": ok, "confidence_at_serve": round(conf, 4),
            "reason": reason, "at": self.twins.now(),
        }
        with self._lock:
            self._serve_log.append(entry)
            self._counters["twin_serves"] += 1
            if not ok:          # unreachable by construction; audited anyway
                self._counters["twin_serves_invalid"] += 1
        self.bus.emit(TelemetryEvent(rid, "twin_serve", dict(entry)))
        return result

    def serve_fallback(self, task: TaskRequest, matcher, reason: str
                       ) -> Tuple[Optional[InvocationResult], List[str]]:
        """Fallback mode: serve an opted-in task from the best valid twin
        instead of rejecting it.  Returns ``(result, refusal_reasons)`` —
        result None when no twin could serve; the refusal reasons (per
        candidate twin) are surfaced in the rejection message."""
        refusals: List[str] = []
        for desc, tw, ok, why in matcher.twin_candidates(task):
            rid = desc.resource_id
            if not ok:
                refusals.append(f"{rid}: {why}")
                with self._lock:
                    self._counters["twin_serve_refusals"] += 1
                continue
            try:
                return self.serve(task, rid, "fallback", reason), refusals
            except (TwinUnavailable, TwinNotReady) as e:
                refusals.append(f"{rid}: {e}")
        if not refusals:
            refusals.append("no executable twin for this task shape")
        return None, refusals

    # -- speculation ----------------------------------------------------------
    def speculate(self, task: TaskRequest, matcher
                  ) -> Optional[Tuple[InvocationResult, str]]:
        """Speculate mode: answer immediately from the best valid twin.
        Returns ``(speculative_result, resource_id)`` or None when no twin
        can speculate (caller falls back to plain real execution)."""
        for desc, tw, ok, why in matcher.twin_candidates(task):
            if not ok:
                continue
            try:
                result = self.serve(task, desc.resource_id, "speculate")
            except (TwinUnavailable, TwinNotReady):
                continue
            with self._lock:
                self._counters["speculations"] += 1
            return result, desc.resource_id
        return None

    def confirm_speculation(self, task: TaskRequest, rid: str,
                            twin_result: InvocationResult,
                            real_result: InvocationResult) -> Dict:
        """Compare a speculative twin answer against the asynchronous real
        confirmation; retro-invalidate the twin on a beyond-tolerance
        mismatch.  A failed/rejected real run leaves the twin alone (the
        hardware's inability to confirm is not evidence the twin is wrong)
        but reports ``confirmed=False``."""
        verdict = {"resource_id": rid, "confirmed": False,
                   "divergence": None, "retro_invalidated": False,
                   "reason": ""}
        tw = self.twins.get(rid)
        if real_result.status != "completed":
            verdict["reason"] = (f"real execution did not complete "
                                 f"(status={real_result.status})")
        elif tw is None or tw.surrogate is None:
            verdict["reason"] = "twin disappeared before confirmation"
        else:
            sur = tw.surrogate
            div = float(sur.divergence(real_result.output, twin_result.output))
            verdict["divergence"] = round(div, 6)
            self.twins.observe_divergence(rid, div, sur.tolerance)
            if div > sur.tolerance:
                reason = (f"speculation mismatch: divergence {div:.4f} > "
                          f"tolerance {sur.tolerance} (task {task.task_id})")
                self.twins.invalidate(rid, reason)
                verdict["retro_invalidated"] = True
                verdict["reason"] = reason
                with self._lock:
                    self._counters["retro_invalidated"] += 1
            else:
                verdict["confirmed"] = True
                with self._lock:
                    self._counters["speculations_confirmed"] += 1
        self.bus.emit(TelemetryEvent(rid, "twin_speculation", dict(
            verdict, task_id=task.task_id)))
        return verdict

    # -- observability --------------------------------------------------------
    def audit(self) -> Dict[str, int]:
        with self._lock:
            return dict(self._counters)

    def serve_log(self) -> List[Dict]:
        with self._lock:
            return [dict(e) for e in self._serve_log]
