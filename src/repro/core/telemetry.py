"""Telemetry plane: runtime snapshots + event bus (paper §IV-B).

Adapters publish :class:`RuntimeSnapshot`s (health, drift, readiness,
age-of-information) which the matcher consults alongside static descriptors
(paper §VII-A: "the matcher consults lightweight runtime snapshots such as
health_status, drift_score, and age_of_information_ms").  The bus forwards
events to local consumers (twin-sync manager, supervisors, benchmarks).

The bus is fully thread-safe: ``subscribe`` is locked, ``snapshot`` returns
copy-on-read views (callers never observe in-place mutation of stored
state), and per-resource ``queue_depth`` counters are maintained live by the
orchestrator/scheduler so the matcher can score against instantaneous
substrate pressure.  ``epoch`` increments on every stored-snapshot change
(a cheap change-detection handle for consumers polling the store).
"""
from __future__ import annotations

import dataclasses
import threading
from collections import defaultdict, deque
from typing import Callable, Dict, List, Optional

from repro.core.simclock import Clock, SYSTEM_CLOCK

HEALTH = ("healthy", "degraded", "failed")


@dataclasses.dataclass
class RuntimeSnapshot:
    resource_id: str
    health_status: str = "healthy"             # healthy | degraded | failed
    drift_score: float = 0.0                   # 0 = calibrated, 1 = unusable
    readiness: str = "ready"                   # ready | preparing | busy | down
    age_of_information_ms: float = 0.0         # staleness of this snapshot
    viability: Optional[float] = None          # wetware-specific
    contamination: Optional[float] = None      # chemical-specific
    queue_depth: int = 0
    # stamped by the clock-owning bus at update_snapshot; None = never
    # stored (a raw default_factory=time.time here would mix wall epochs
    # into virtual-time runs and make twins look fresher than now)
    last_updated: Optional[float] = None
    extra: Dict = dataclasses.field(default_factory=dict)

    def aged(self, now: Optional[float] = None) -> "RuntimeSnapshot":
        """Copy with age_of_information_ms recomputed (copy-on-read: the
        stored snapshot is never mutated, so concurrent readers are safe).
        ``now`` lets a clock-owning caller (the bus) age against its own
        timebase; an unstamped snapshot has age 0."""
        if self.last_updated is None:
            return dataclasses.replace(self, age_of_information_ms=0.0)
        if now is None:
            now = SYSTEM_CLOCK.now()
        return dataclasses.replace(
            self, age_of_information_ms=(now - self.last_updated) * 1e3)

    def to_dict(self, now: Optional[float] = None) -> Dict:
        return dataclasses.asdict(self.aged(now))


@dataclasses.dataclass
class TelemetryEvent:
    resource_id: str
    kind: str                                  # result | health | drift | lifecycle
    fields: Dict
    # the bus restamps at emit() from its injected clock; None = not yet
    # published (events never cross the wire unstamped)
    timestamp: Optional[float] = None


class TelemetryBus:
    """In-process pub/sub with bounded per-resource history (thread-safe)."""

    def __init__(self, history: int = 256, clock: Optional[Clock] = None):
        self._subs: List[Callable[[TelemetryEvent], None]] = []  # guarded_by: _lock
        self._history: Dict[str, deque] = defaultdict(           # guarded_by: _lock
            lambda: deque(maxlen=history))
        self._snapshots: Dict[str, RuntimeSnapshot] = {}         # guarded_by: _lock
        self._queue_depth: Dict[str, int] = defaultdict(int)     # guarded_by: _lock
        self._epoch = 0                                          # guarded_by: _lock
        self._lock = threading.Lock()
        # injectable timebase: stamps events/snapshots and computes ages —
        # under the scenario simulator's VirtualClock every timestamp is a
        # deterministic function of the event sequence
        self.clock: Clock = clock or SYSTEM_CLOCK

    @property
    def epoch(self) -> int:
        """Monotonic snapshot-store version; bumps on update_snapshot."""
        with self._lock:
            return self._epoch

    def subscribe(self, fn: Callable[[TelemetryEvent], None]) -> None:
        with self._lock:
            self._subs.append(fn)

    def unsubscribe(self, fn: Callable[[TelemetryEvent], None]) -> None:
        """Detach a subscriber (no-op if absent) — consumers with a shorter
        lifetime than the bus (e.g. a gateway's telemetry log) must detach
        on close or they leak into every future emit."""
        with self._lock:
            try:
                self._subs.remove(fn)
            except ValueError:
                pass

    def emit(self, event: TelemetryEvent) -> None:
        # the bus owns the timebase: restamp at publication so subscribers
        # (twin sync, health, stream severity) all see one consistent —
        # and, under a virtual clock, deterministic — timeline
        event.timestamp = self.clock.now()
        with self._lock:
            self._history[event.resource_id].append(event)
            subs = list(self._subs)
        for fn in subs:
            fn(event)

    def update_snapshot(self, snap: RuntimeSnapshot) -> None:
        now = self.clock.now()
        stored = dataclasses.replace(snap, last_updated=now)
        with self._lock:
            self._snapshots[snap.resource_id] = stored
            self._epoch += 1
        self.emit(TelemetryEvent(snap.resource_id, "health",
                                 stored.to_dict(now)))

    def snapshot(self, resource_id: str) -> Optional[RuntimeSnapshot]:
        """Aged copy of the stored snapshot with the LIVE queue depth
        overlaid — safe for the caller to read or mutate freely."""
        with self._lock:
            snap = self._snapshots.get(resource_id)
            depth = self._queue_depth.get(resource_id, 0)
        if snap is None:
            return None
        view = snap.aged(self.clock.now())
        view.queue_depth = depth
        return view

    # -- live per-resource pressure ------------------------------------------
    def adjust_queue_depth(self, resource_id: str, delta: int) -> int:
        """Atomically add ``delta`` to a resource's in-flight/waiting count
        (maintained by the orchestrator around admission + invocation)."""
        with self._lock:
            depth = max(0, self._queue_depth[resource_id] + delta)
            self._queue_depth[resource_id] = depth
            return depth

    def queue_depth(self, resource_id: str) -> int:
        with self._lock:
            return self._queue_depth.get(resource_id, 0)

    def history(self, resource_id: str) -> List[TelemetryEvent]:
        with self._lock:
            return list(self._history[resource_id])
