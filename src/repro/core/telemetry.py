"""Telemetry plane: runtime snapshots + event bus (paper §IV-B).

Adapters publish :class:`RuntimeSnapshot`s (health, drift, readiness,
age-of-information) which the matcher consults alongside static descriptors
(paper §VII-A: "the matcher consults lightweight runtime snapshots such as
health_status, drift_score, and age_of_information_ms").  The bus forwards
events to local consumers (twin-sync manager, supervisors, benchmarks).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import defaultdict, deque
from typing import Callable, Dict, List, Optional

HEALTH = ("healthy", "degraded", "failed")


@dataclasses.dataclass
class RuntimeSnapshot:
    resource_id: str
    health_status: str = "healthy"             # healthy | degraded | failed
    drift_score: float = 0.0                   # 0 = calibrated, 1 = unusable
    readiness: str = "ready"                   # ready | preparing | busy | down
    age_of_information_ms: float = 0.0         # staleness of this snapshot
    viability: Optional[float] = None          # wetware-specific
    contamination: Optional[float] = None      # chemical-specific
    queue_depth: int = 0
    last_updated: float = dataclasses.field(default_factory=time.time)
    extra: Dict = dataclasses.field(default_factory=dict)

    def aged(self) -> "RuntimeSnapshot":
        self.age_of_information_ms = (time.time() - self.last_updated) * 1e3
        return self

    def to_dict(self) -> Dict:
        self.aged()
        return dataclasses.asdict(self)


@dataclasses.dataclass
class TelemetryEvent:
    resource_id: str
    kind: str                                  # result | health | drift | lifecycle
    fields: Dict
    timestamp: float = dataclasses.field(default_factory=time.time)


class TelemetryBus:
    """In-process pub/sub with bounded per-resource history."""

    def __init__(self, history: int = 256):
        self._subs: List[Callable[[TelemetryEvent], None]] = []
        self._history: Dict[str, deque] = defaultdict(
            lambda: deque(maxlen=history))
        self._snapshots: Dict[str, RuntimeSnapshot] = {}
        self._lock = threading.Lock()

    def subscribe(self, fn: Callable[[TelemetryEvent], None]) -> None:
        self._subs.append(fn)

    def emit(self, event: TelemetryEvent) -> None:
        with self._lock:
            self._history[event.resource_id].append(event)
        for fn in list(self._subs):
            fn(event)

    def update_snapshot(self, snap: RuntimeSnapshot) -> None:
        snap.last_updated = time.time()
        with self._lock:
            self._snapshots[snap.resource_id] = snap
        self.emit(TelemetryEvent(snap.resource_id, "health", snap.to_dict()))

    def snapshot(self, resource_id: str) -> Optional[RuntimeSnapshot]:
        snap = self._snapshots.get(resource_id)
        return snap.aged() if snap is not None else None

    def history(self, resource_id: str) -> List[TelemetryEvent]:
        with self._lock:
            return list(self._history[resource_id])
