"""Capability registry: discovery over resource descriptors (paper §IV-B).

Supports queries like "find a substrate that accepts spike-like event input
and supports low-latency repeated invocation" via structured filters, plus
the directed path (lookup by resource id).
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional

from repro.core.descriptors import ResourceDescriptor


class CapabilityRegistry:
    def __init__(self):
        self._resources: Dict[str, ResourceDescriptor] = {}
        self._adapters: Dict[str, object] = {}

    def register(self, desc: ResourceDescriptor, adapter) -> None:
        self._resources[desc.resource_id] = desc
        self._adapters[desc.resource_id] = adapter

    def unregister(self, resource_id: str) -> None:
        self._resources.pop(resource_id, None)
        self._adapters.pop(resource_id, None)

    def get(self, resource_id: str) -> Optional[ResourceDescriptor]:
        return self._resources.get(resource_id)

    def adapter(self, resource_id: str):
        return self._adapters.get(resource_id)

    def all(self) -> List[ResourceDescriptor]:
        return list(self._resources.values())

    def discover(self, *, function: Optional[str] = None,
                 input_modality: Optional[str] = None,
                 output_modality: Optional[str] = None,
                 latency_regime: Optional[str] = None,
                 repeated: Optional[bool] = None,
                 substrate_class: Optional[str] = None,
                 predicate: Optional[Callable[[ResourceDescriptor], bool]] = None,
                 ) -> List[ResourceDescriptor]:
        out = []
        for d in self._resources.values():
            cap = d.capability
            if function is not None and function not in cap.functions:
                continue
            if input_modality is not None and cap.input_signal.modality != input_modality:
                continue
            if output_modality is not None and cap.output_signal.modality != output_modality:
                continue
            if latency_regime is not None and cap.timing.latency_regime != latency_regime:
                continue
            if repeated and not cap.supports_repeated_invocation:
                continue
            if substrate_class is not None and d.substrate_class != substrate_class:
                continue
            if predicate is not None and not predicate(d):
                continue
            out.append(d)
        return out
