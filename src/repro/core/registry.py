"""Capability registry: discovery over resource descriptors (paper §IV-B).

Supports queries like "find a substrate that accepts spike-like event input
and supports low-latency repeated invocation" via structured filters, plus
the directed path (lookup by resource id).

The registry is thread-safe and versioned: ``epoch`` increments on every
register/unregister, so the matcher can cache per-task admissibility and
static scoring work across many concurrent tasks and invalidate the cache
exactly when the fleet composition changes.

Fleet-change listeners: ``subscribe`` registers a callback invoked (outside
the lock) as ``fn(action, desc, epoch)`` on every register/unregister.  The
orchestrator forwards these onto the TelemetryBus as ``registry`` events —
the descriptor change feed parent planes follow over the telemetry stream
to track a child fleet live instead of re-fetching descriptors.
"""
from __future__ import annotations

import threading
from typing import Callable, Dict, List, Optional

from repro.core.descriptors import ResourceDescriptor


class CapabilityRegistry:
    def __init__(self):
        self._resources: Dict[str, ResourceDescriptor] = {}
        self._adapters: Dict[str, object] = {}
        self._listeners: List[Callable[[str, ResourceDescriptor, int], None]] = []
        self._epoch = 0
        self._lock = threading.RLock()

    @property
    def epoch(self) -> int:
        """Monotonic fleet version; bumps on register/unregister."""
        with self._lock:
            return self._epoch

    def subscribe(self, fn: Callable[[str, ResourceDescriptor, int], None]
                  ) -> None:
        """Fleet-change listener: ``fn(action, desc, epoch)`` with action in
        {"register", "unregister"}; called outside the registry lock."""
        with self._lock:
            self._listeners.append(fn)

    def _notify(self, action: str, desc: ResourceDescriptor,
                epoch: int) -> None:
        with self._lock:
            listeners = list(self._listeners)
        for fn in listeners:
            fn(action, desc, epoch)

    def register(self, desc: ResourceDescriptor, adapter) -> None:
        with self._lock:
            self._resources[desc.resource_id] = desc
            self._adapters[desc.resource_id] = adapter
            self._epoch += 1
            epoch = self._epoch
        self._notify("register", desc, epoch)

    def unregister(self, resource_id: str) -> None:
        with self._lock:
            desc = self._resources.pop(resource_id, None)
            self._adapters.pop(resource_id, None)
            self._epoch += 1
            epoch = self._epoch
        if desc is not None:
            self._notify("unregister", desc, epoch)

    def get(self, resource_id: str) -> Optional[ResourceDescriptor]:
        with self._lock:
            return self._resources.get(resource_id)

    def adapter(self, resource_id: str):
        with self._lock:
            return self._adapters.get(resource_id)

    def all(self) -> List[ResourceDescriptor]:
        with self._lock:
            return list(self._resources.values())

    def discover(self, *, function: Optional[str] = None,
                 input_modality: Optional[str] = None,
                 output_modality: Optional[str] = None,
                 latency_regime: Optional[str] = None,
                 repeated: Optional[bool] = None,
                 substrate_class: Optional[str] = None,
                 predicate: Optional[Callable[[ResourceDescriptor], bool]] = None,
                 ) -> List[ResourceDescriptor]:
        out = []
        for d in self.all():
            cap = d.capability
            if function is not None and function not in cap.functions:
                continue
            if input_modality is not None and cap.input_signal.modality != input_modality:
                continue
            if output_modality is not None and cap.output_signal.modality != output_modality:
                continue
            if latency_regime is not None and cap.timing.latency_regime != latency_regime:
                continue
            if repeated and not cap.supports_repeated_invocation:
                continue
            if substrate_class is not None and d.substrate_class != substrate_class:
                continue
            if predicate is not None and not predicate(d):
                continue
            out.append(d)
        return out
