"""Concurrent control-plane scheduler: queued admission + worker pool.

The paper's control loop (§IV-D) processes one task at a time; real PNN
serving is many-client, so this module turns the orchestrator's
match → admit → invoke → validate path into a sustained-throughput pipeline:

- a bounded task queue gives explicit backpressure (a full queue blocks the
  producer instead of growing without bound);
- a worker pool keeps many tasks in flight so every substrate's
  ``max_concurrent`` budget stays saturated instead of serializing behind a
  single control loop;
- per-task deadlines bound both queue wait and substrate admission
  (tasks whose deadline lapses while queued are rejected without ever
  touching a substrate);
- results are futures, so clients can pipeline (``submit_async``), batch
  (``submit_many``) or quiesce (``drain``).

``Orchestrator.submit`` remains the one-shot synchronous path; both go
through ``Orchestrator.execute``, so scheduling changes placement *timing*
but never placement *semantics*.
"""
from __future__ import annotations

import queue
import threading
from collections import deque
from concurrent.futures import Future
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.core.errors import ErrorCode
from repro.core.invocation import InvocationResult
from repro.core.orchestrator import Orchestrator, OrchestrationTrace
from repro.core.simclock import Clock, SYSTEM_CLOCK
from repro.core.tasks import TaskRequest

_STOP = object()


class SchedulerClosed(RuntimeError):
    pass


class ControlPlaneScheduler:
    """Bounded-queue, worker-pool front end over an :class:`Orchestrator`.

    Usage::

        with ControlPlaneScheduler(orch, workers=16) as sched:
            futs = [sched.submit_async(t) for t in tasks]
            results = [f.result() for f in futs]

    or batched: ``results = sched.submit_many(tasks)``.
    """

    def __init__(self, orchestrator: Orchestrator, workers: int = 8,
                 queue_size: int = 256,
                 default_deadline_s: Optional[float] = None,
                 health_tick_interval_s: float = 0.05,
                 clock: Optional[Clock] = None):
        if workers < 1:
            raise ValueError("workers must be >= 1")
        self.orchestrator = orchestrator
        self.workers = workers
        self.default_deadline_s = default_deadline_s
        # injectable time source: defaults to the orchestrator's clock so
        # scheduler deadlines and the orchestrator's admission deadlines
        # share one timebase (virtual under the scenario simulator)
        self.clock: Clock = clock or getattr(orchestrator, "clock",
                                             SYSTEM_CLOCK)
        # background probe cadence for the health manager (0 disables):
        # cooled-down breakers half-open on the tick, not only when a task
        # happens to rank the resource
        self.health_tick_interval_s = health_tick_interval_s
        self._health_stop = threading.Event()
        self._health_thread: Optional[threading.Thread] = None  # guarded_by: _lock
        self._queue: "queue.Queue" = queue.Queue(maxsize=queue_size)
        self._threads: List[threading.Thread] = []              # guarded_by: _lock
        self._started = False                                   # guarded_by: _lock
        self._closed = False                                    # guarded_by: _lock
        self._lock = threading.Lock()
        self._idle = threading.Condition(self._lock)
        # notified whenever a worker takes an item off the bounded queue —
        # producers blocked on a full queue park here instead of polling
        self._space = threading.Condition(self._lock)
        self._pending = 0   # guarded_by: _lock — queued + in-flight tasks
        self._stats_lock = threading.Lock()
        self._status_counts: Dict[str, int] = {}    # guarded_by: _stats_lock
        self._per_resource: Dict[str, int] = {}     # guarded_by: _stats_lock
        self._latencies_ms: List[float] = []        # guarded_by: _stats_lock
        # recent completion timestamps: the observed DRAIN RATE for
        # retry_after_s (end-to-end latencies include queue wait, which
        # would inflate a backoff hint exactly when the queue is busy)
        self._done_times: "deque[float]" = deque(maxlen=32)  # guarded_by: _stats_lock
        self._first_enqueue: Optional[float] = None          # guarded_by: _stats_lock
        self._last_done: Optional[float] = None              # guarded_by: _stats_lock

    # -- lifecycle ------------------------------------------------------------
    def start(self) -> "ControlPlaneScheduler":
        with self._lock:
            if self._closed:
                raise SchedulerClosed("scheduler already shut down")
            if self._started:
                return self
            self._started = True
            for i in range(self.workers):
                t = threading.Thread(target=self._worker, daemon=True,
                                     name=f"phys-mcp-worker-{i}")
                t.start()
                self._threads.append(t)
            if (self.health_tick_interval_s
                    and getattr(self.orchestrator, "health", None) is not None):
                self._health_thread = threading.Thread(
                    target=self._health_probe_loop, daemon=True,
                    name="phys-mcp-health-ticker")
                self._health_thread.start()
        return self

    def __enter__(self) -> "ControlPlaneScheduler":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.shutdown(wait=exc == (None, None, None))

    def shutdown(self, wait: bool = True) -> None:
        # setting _closed under the lock before any sentinel is enqueued
        # guarantees no real task can land behind a sentinel: submit_async
        # re-checks _closed under this same lock right before its put
        with self._lock:
            if self._closed:
                return
            self._closed = True
            started = self._started
            threads = list(self._threads)
            # snapshot under the lock: start() writes _health_thread while
            # holding _lock, so an unlocked read below could miss it
            health_thread = self._health_thread
        self._health_stop.set()
        with self._lock:
            # wake producers parked on queue space so they observe _closed
            self._space.notify_all()
        if started:
            for _ in range(self.workers):
                self._queue.put((_STOP, None, None, 0.0))
            if wait:
                for t in threads:
                    t.join()
                if health_thread is not None:
                    health_thread.join()

    def _health_probe_loop(self) -> None:
        """Background probe ticks: periodically promote cooled-down OPEN
        breakers to PROBATION so re-admission does not depend on task
        arrival timing.  Exceptions never kill the ticker.  The wait goes
        through the injected clock, so a virtual-clock deployment ticks in
        virtual time."""
        health = self.orchestrator.health
        while not self.clock.wait_event(self._health_stop,
                                        self.health_tick_interval_s):
            try:
                health.tick()
            except Exception:              # noqa: BLE001 — keep ticking
                pass

    # -- submission -----------------------------------------------------------
    def submit_async(self, task: TaskRequest,
                     deadline_s: Optional[float] = None
                     ) -> "Future[Tuple[InvocationResult, OrchestrationTrace]]":
        """Enqueue one task; returns a future resolving to the same
        ``(result, trace)`` pair ``Orchestrator.submit`` gives.  Blocks for
        queue space when the bounded queue is full (backpressure)."""
        self.start()                 # raises SchedulerClosed when shut down
        fut: Future = Future()
        # only an EXPLICIT deadline (per-call or scheduler default) rejects
        # tasks that lapse while queued; a task's latency_budget_ms stays the
        # soft signal it is on the serial path (Orchestrator.execute pins it
        # to bound admission blocking identically in both modes)
        budget = deadline_s if deadline_s is not None \
            else self.default_deadline_s
        clock = self.clock
        deadline = (clock.monotonic() + budget) if budget is not None else None
        enqueued = clock.monotonic()
        # closed-check + enqueue are atomic w.r.t. shutdown(), so a task is
        # either rejected here or is guaranteed to sit ahead of the stop
        # sentinels.  A full queue parks the producer on the _space
        # condition (workers notify after every dequeue, shutdown notifies
        # all), so backpressure costs no polling: the producer wakes the
        # moment a slot frees instead of rediscovering it up to 10ms late.
        with self._lock:
            while True:
                if self._closed:
                    raise SchedulerClosed("scheduler already shut down")
                try:
                    self._queue.put_nowait((task, fut, deadline, enqueued))
                except queue.Full:
                    clock.wait_for(
                        self._space,
                        lambda: self._closed or not self._queue.full())
                else:
                    self._pending += 1
                    break
        # _first_enqueue belongs to the stats group (read in stats() under
        # _stats_lock); stamp it AFTER releasing _lock so the two locks are
        # never nested
        with self._stats_lock:
            if self._first_enqueue is None:
                self._first_enqueue = enqueued
        return fut

    def submit_many(self, tasks: Sequence[TaskRequest],
                    deadline_s: Optional[float] = None, wait: bool = True
                    ) -> Union[List[Tuple[InvocationResult, OrchestrationTrace]],
                               List[Future]]:
        """Enqueue a batch.  With ``wait=True`` (default) blocks until every
        task resolved and returns ``(result, trace)`` pairs in submission
        order; with ``wait=False`` returns the unresolved futures instead."""
        futs = [self.submit_async(t, deadline_s=deadline_s) for t in tasks]
        if not wait:
            return futs
        return [f.result() for f in futs]

    def submit_speculative(self, task: TaskRequest,
                           deadline_s: Optional[float] = None
                           ) -> Tuple[Optional[InvocationResult], Future]:
        """Speculate mode: a VALID executable twin answers immediately; the
        real execution is enqueued for asynchronous confirmation.

        Returns ``(speculative_result, confirmation_future)``.  When a twin
        could speculate, the future resolves to ``(real_result, trace,
        verdict)`` where the verdict records confirmed / divergence /
        retro_invalidated — a beyond-tolerance mismatch retro-invalidates
        the twin (its next ``valid()`` fails until an explicit re-sync).
        When no valid twin exists the speculative result is None and the
        future is the plain ``submit_async`` future resolving to
        ``(result, trace)``.
        """
        self.start()
        orch = self.orchestrator
        spec = orch.twin_exec.speculate(task, orch.matcher)
        # the confirmation run must execute on real hardware: strip the twin
        # mode (clone() un-aliases the metadata dict) from the enqueued copy
        confirm_task = task.clone(twin_mode=None) \
            if hasattr(task, "clone") else task
        real_fut = self.submit_async(confirm_task, deadline_s=deadline_s)
        if spec is None:
            return None, real_fut
        twin_result, rid = spec
        confirm_fut: Future = Future()

        def _confirm(f: Future) -> None:
            try:
                real_result, trace = f.result()
            except BaseException as e:          # noqa: BLE001 — via future
                confirm_fut.set_exception(e)
                return
            verdict = orch.twin_exec.confirm_speculation(
                task, rid, twin_result, real_result)
            confirm_fut.set_result((real_result, trace, verdict))

        real_fut.add_done_callback(_confirm)
        return twin_result, confirm_fut

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Block until every enqueued task has resolved (or timeout).
        Returns True when the scheduler is fully quiesced."""
        clock = self.clock
        end = None if timeout is None else clock.monotonic() + timeout
        with self._idle:
            while self._pending > 0:
                remaining = None if end is None else end - clock.monotonic()
                if remaining is not None and remaining <= 0:
                    return False
                clock.wait_for(self._idle, lambda: self._pending == 0,
                               timeout=remaining)
        return True

    # -- worker loop ----------------------------------------------------------
    def _worker(self) -> None:
        while True:
            task, fut, deadline, enqueued = self._queue.get()
            with self._lock:
                self._space.notify()       # one queue slot freed
            if task is _STOP:
                return
            try:
                if not fut.set_running_or_notify_cancel():
                    continue
                if deadline is not None and self.clock.monotonic() > deadline:
                    # queue saturation endpoint: an opted-in task whose
                    # deadline lapsed while queued is served by a valid twin
                    # instead of rejected (same funnel as the orchestrator's)
                    try:
                        result, trace = self.orchestrator._reject_or_twin(
                            task, OrchestrationTrace(task.task_id),
                            "deadline exceeded while queued",
                            code=ErrorCode.DEADLINE)
                    except BaseException as e:  # noqa: BLE001 — via future
                        fut.set_exception(e)
                        self._account(None, enqueued)
                        continue
                    fut.set_result((result, trace))
                    self._account(result, enqueued)
                    continue
                try:
                    result, trace = self.orchestrator.execute(
                        task, deadline=deadline)
                except BaseException as e:   # noqa: BLE001 — surfaced via future
                    fut.set_exception(e)
                    self._account(None, enqueued)
                    continue
                fut.set_result((result, trace))
                self._account(result, enqueued)
            finally:
                with self._idle:
                    self._pending -= 1
                    self._idle.notify_all()

    def _account(self, result: Optional[InvocationResult],
                 enqueued: float) -> None:
        now = self.clock.monotonic()
        with self._stats_lock:
            status = result.status if result is not None else "error"
            self._status_counts[status] = \
                self._status_counts.get(status, 0) + 1
            if result is not None and result.resource_id:
                self._per_resource[result.resource_id] = \
                    self._per_resource.get(result.resource_id, 0) + 1
            self._latencies_ms.append((now - enqueued) * 1e3)
            self._done_times.append(now)
            self._last_done = now

    # -- observability --------------------------------------------------------
    def stats(self) -> Dict:
        """Live counters: status mix, per-substrate placement, end-to-end
        latency percentiles (enqueue → resolve) and observed throughput."""
        with self._stats_lock:
            lats = sorted(self._latencies_ms)
            counts = dict(self._status_counts)
            per_resource = dict(self._per_resource)
            first, last = self._first_enqueue, self._last_done
        done = len(lats)
        wall_s = (last - first) if (first is not None and last is not None
                                    and last > first) else None

        def pct(p: float) -> Optional[float]:
            if not lats:
                return None
            return lats[min(done - 1, int(p * (done - 1)))]

        return {
            "done": done,
            "pending": self.pending,
            "statuses": counts,
            "per_resource": per_resource,
            "p50_ms": pct(0.50),
            "p95_ms": pct(0.95),
            "wall_s": wall_s,
            "tasks_per_s": (done / wall_s) if wall_s else None,
        }

    @property
    def pending(self) -> int:
        with self._lock:
            return self._pending

    #: retry_after_s clamps: never tell a client "retry immediately" into a
    #: saturated queue, never park it for more than this many seconds
    MIN_RETRY_AFTER_S = 0.05
    MAX_RETRY_AFTER_S = 5.0

    def retry_after_s(self) -> float:
        """Informed-backoff hint for QUEUE_SATURATED rejections: how long
        until this plane has likely worked off its current backlog, from
        the OBSERVED recent drain rate (completions per second across the
        worker pool — enqueue-to-resolve latencies would double-count the
        queue wait the backlog already represents).  Clamped so clients
        neither hammer nor stall."""
        with self._lock:
            backlog = self._pending
        with self._stats_lock:
            times = list(self._done_times)
        if len(times) >= 2 and times[-1] > times[0]:
            drain_per_s = (len(times) - 1) / (times[-1] - times[0])
            est = backlog / drain_per_s
        else:
            # no drain history yet: assume fast tasks, stay near the floor
            est = backlog * 0.01
        return round(min(self.MAX_RETRY_AFTER_S,
                         max(self.MIN_RETRY_AFTER_S, est)), 3)
