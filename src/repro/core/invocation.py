"""Invocation manager: sessions, contracts, normalized results (paper §IV-B).

Every backend — chemical twin, synthetic wetware, memristive, HTTP-external,
Cortical-Labs-style API, TPU pod — returns the SAME normalized result keys
(:data:`RESULT_KEYS`).  That stability is the paper's RQ1 invocation
portability claim (shared-key ratio 1.0), while backend-specific payloads
live under ``output``/``telemetry``/``artifacts``.

Concurrency: session-id allocation is lock-protected (process-unique ids
even across orchestrator instances), and prepare/recover sequences hold the
substrate's lifecycle lock so concurrent sessions serialize per resource —
overlapping invocations on ``max_concurrent > 1`` substrates are handled by
the lifecycle manager's active-session accounting.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Dict, Optional

from repro.core.contracts import SessionContracts, contracts_from_descriptor
from repro.core.descriptors import ResourceDescriptor
from repro.core.errors import AdmissionRefused, ErrorCode, classify_rejection
from repro.core.lifecycle import LifecycleManager, LifecycleState
from repro.core.tasks import TaskRequest
from repro.core.telemetry import TelemetryBus, TelemetryEvent

RESULT_KEYS = ("task_id", "resource_id", "status", "output", "telemetry",
               "artifacts", "timing_ms", "contracts", "session_id")

_session_counter = 0
_session_lock = threading.Lock()


def _next_session_id() -> str:
    global _session_counter
    with _session_lock:
        _session_counter += 1
        return f"session-{_session_counter:05d}"


@dataclasses.dataclass
class Session:
    session_id: str
    task: TaskRequest
    descriptor: ResourceDescriptor
    contracts: SessionContracts
    state: str = "created"        # created | prepared | running | done | failed
    started_at: float = 0.0


@dataclasses.dataclass
class InvocationResult:
    task_id: str
    resource_id: str
    status: str                   # completed | rejected | failed | invalidated
    output: Any
    telemetry: Dict
    artifacts: Dict
    timing_ms: Dict
    contracts: Dict
    session_id: str

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    # -- wire forms -----------------------------------------------------------
    def to_wire(self) -> Dict:
        """Faithful serialization; identical to ``to_dict`` today but kept
        distinct so the wire shape can evolve independently of logging."""
        return self.to_dict()

    @classmethod
    def from_wire(cls, d: Dict) -> "InvocationResult":
        from repro.core.descriptors import known_fields

        return cls(**known_fields(cls, d))

    @property
    def error_code(self) -> Optional[str]:
        """Structured taxonomy code for non-completed results (None when
        completed)."""
        code = self.telemetry.get("error_code") if self.telemetry else None
        if code is None and self.status in ("rejected", "failed",
                                            "invalidated"):
            reason = (self.telemetry or {}).get("reason", "")
            code = classify_rejection(reason).value
        return code


class InvocationError(RuntimeError):
    def __init__(self, phase: str, message: str):
        super().__init__(message)
        self.phase = phase


class InvocationManager:
    def __init__(self, registry, lifecycle: LifecycleManager, bus: TelemetryBus):
        self.registry = registry
        self.lifecycle = lifecycle
        self.bus = bus

    def open_session(self, task: TaskRequest, desc: ResourceDescriptor) -> Session:
        contracts = contracts_from_descriptor(desc, task,
                                              now=self.bus.clock.now())
        return Session(_next_session_id(), task, desc, contracts)

    def _recover_if_needed(self, session: Session,
                           phase: str = "prepare") -> None:
        """Run the descriptor's recovery mode if the substrate is parked in
        NEEDS_RESET (or FAILED, so a faulted substrate re-selected after
        fallback is re-armed instead of wedging the state machine).  Caller
        must hold the substrate's lifecycle lock.

        A physical reset must never fire while other sessions are still on
        the hardware — in that case this attempt fails (and falls back)
        rather than invalidating in-flight work."""
        rid = session.descriptor.resource_id
        if self.lifecycle.state(rid) not in (LifecycleState.NEEDS_RESET,
                                             LifecycleState.FAILED):
            return
        in_flight = self.lifecycle.active_sessions(rid)
        if in_flight > 0:
            raise InvocationError(
                phase, f"{rid} awaiting recovery with {in_flight} "
                       "session(s) still in flight")
        adapter = self.registry.adapter(rid)
        modes = session.descriptor.capability.lifecycle.recovery_modes
        mode = modes[0] if modes else "soft"
        adapter.reset(mode)
        self.lifecycle.recover(rid, mode)
        self.bus.emit(TelemetryEvent(rid, "lifecycle",
                                     {"phase": "recover", "mode": mode}))

    def prepare(self, session: Session) -> None:
        """Lifecycle preparation: warm-up / priming / calibration.

        A substrate parked in NEEDS_RESET is recovered first using its
        descriptor's recovery mode (flush / rest / reprogram) — lifecycle
        transitions are part of the effective execution cost (paper §V-B).
        The whole sequence holds the substrate's lifecycle lock, so
        concurrent prepares serialize per resource; if another session has
        the substrate RUNNING, the state machine is left alone (the
        substrate is already warm) and only the adapter-level prepare runs.
        """
        rid = session.descriptor.resource_id
        adapter = self.registry.adapter(rid)
        t0 = time.perf_counter()

        def adapter_prepare() -> float:
            try:
                adapter.prepare(session)
            except Exception as e:
                self.lifecycle.fail(rid, "prepare")
                raise InvocationError("prepare", str(e)) from e
            return (time.perf_counter() - t0) * 1e3

        with self.lifecycle.lock(rid):
            self._recover_if_needed(session)
            did_transition = False
            if self.lifecycle.state(rid) in (LifecycleState.UNINITIALIZED,
                                             LifecycleState.READY):
                self.lifecycle.prepare(rid)
                did_transition = True
            if did_transition:
                # substrate-wide warm-up/calibration: adapter prepare runs
                # under the resource lock (serialized per substrate)
                dur = adapter_prepare()
                self.lifecycle.ready(rid)
        if not did_transition:
            # substrate already warm (e.g. RUNNING with overlapping
            # sessions): session-level prepare needs no state transition,
            # so don't serialize concurrent sessions behind the lock
            dur = adapter_prepare()
        session.state = "prepared"
        self.bus.emit(TelemetryEvent(rid, "lifecycle",
                                     {"phase": "prepare", "ms": dur}))

    def invoke(self, session: Session) -> InvocationResult:
        rid = session.descriptor.resource_id
        adapter = self.registry.adapter(rid)
        with self.lifecycle.lock(rid):
            # a concurrent session may have parked the substrate in
            # NEEDS_RESET between our prepare and invoke
            self._recover_if_needed(session, phase="invoke")
            self.lifecycle.run(rid)
        session.state = "running"
        session.started_at = time.perf_counter()
        try:
            raw = adapter.invoke(session)
        except AdmissionRefused:
            # predictive refusal, not a substrate fault: close the session
            # cleanly so breakers/lifecycle never see it as a failure
            self.lifecycle.complete(rid)
            session.state = "done"
            raise
        except Exception as e:
            # this session holds a RUNNING slot; release only its own so
            # overlapping sessions' complete() accounting stays balanced
            self.lifecycle.fail(rid, "invoke", held_slot=True)
            session.state = "failed"
            raise InvocationError("invoke", str(e)) from e
        elapsed_ms = (time.perf_counter() - session.started_at) * 1e3
        needs_reset = bool(raw.get("needs_reset", False))
        self.lifecycle.complete(rid, needs_reset=needs_reset)
        session.state = "done"
        telemetry = dict(raw.get("telemetry", {}))
        result = InvocationResult(
            task_id=session.task.task_id,
            resource_id=rid,
            status="completed",
            output=raw.get("output"),
            telemetry=telemetry,
            artifacts=dict(raw.get("artifacts", {})),
            timing_ms={"backend_ms": raw.get("backend_ms", elapsed_ms),
                       "total_ms": elapsed_ms,
                       "observation_ms": telemetry.get("observation_ms",
                                                       elapsed_ms)},
            contracts=session.contracts.to_dict(),
            session_id=session.session_id,
        )
        self.bus.emit(TelemetryEvent(rid, "result", dict(
            telemetry, status=result.status, backend_ms=result.timing_ms["backend_ms"])))
        return result

    def rejected(self, task: TaskRequest, reason: str,
                 code: Optional[ErrorCode] = None) -> InvocationResult:
        """Terminal rejection carrying BOTH the prose reason and the
        structured taxonomy code (classified from the reason when the
        caller doesn't know it)."""
        if code is None:
            code = classify_rejection(reason)
        return InvocationResult(
            task_id=task.task_id, resource_id="", status="rejected",
            output=None,
            telemetry={"reason": reason, "error_code": code.value},
            artifacts={}, timing_ms={}, contracts={}, session_id="")
