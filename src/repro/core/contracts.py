"""Session contracts (paper §V-B), established at invocation time.

Descriptors describe the *resource*; contracts bind a *session*:

- :class:`TimingContract`   — when outputs are authoritative for this session,
- :class:`LifecycleContract` — which transitions wrap the session,
- :class:`TelemetryContract` — which observations are delivered, and which of
  them update the twin plane.

The orchestrator's postcondition check (paper §VII-A) validates an
invocation result *against its contracts* — missing required telemetry or a
violated validity bound triggers fallback, which is RQ2's recovery behavior.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class TimingContract:
    expected_latency_ms: float
    observation_window_ms: float
    min_stabilization_ms: float = 0.0
    deadline_ms: Optional[float] = None      # hard per-session deadline
    delivery: str = "sampled"                # sampled | streamed | event

    def result_authoritative(self, elapsed_ms: float) -> bool:
        return elapsed_ms >= self.min_stabilization_ms

    def within_deadline(self, elapsed_ms: float) -> bool:
        return self.deadline_ms is None or elapsed_ms <= self.deadline_ms


@dataclasses.dataclass(frozen=True)
class LifecycleContract:
    prepare_actions: Tuple[str, ...] = ()    # e.g. ("warmup", "calibrate")
    cleanup_actions: Tuple[str, ...] = ()    # e.g. ("flush",), ("rest",)
    mandatory_recovery_ms: float = 0.0
    reset_after: bool = False


@dataclasses.dataclass(frozen=True)
class TelemetryContract:
    required_fields: Tuple[str, ...]
    optional_fields: Tuple[str, ...] = ()
    twin_linked_fields: Tuple[str, ...] = ()
    delivery: str = "with_result"            # with_result | streamed

    def validate(self, telemetry: Dict) -> Tuple[bool, Tuple[str, ...]]:
        missing = tuple(f for f in self.required_fields if f not in telemetry)
        return (not missing), missing


@dataclasses.dataclass
class SessionContracts:
    timing: TimingContract
    lifecycle: LifecycleContract
    telemetry: TelemetryContract
    # stamped by the session opener from its injected clock (None = not
    # stamped; never defaulted to wall time — see the clock-seam rule)
    created_at: Optional[float] = None

    def to_dict(self) -> Dict:
        return {
            "timing": dataclasses.asdict(self.timing),
            "lifecycle": dataclasses.asdict(self.lifecycle),
            "telemetry": dataclasses.asdict(self.telemetry),
            "created_at": self.created_at,
        }


def contracts_from_descriptor(desc, task,
                              now: Optional[float] = None) -> SessionContracts:
    """Derive session contracts from a capability descriptor + task request.

    ``now`` stamps ``created_at`` from the caller's injected clock (the
    session opener passes its bus clock so virtual-time runs stay fully
    virtual)."""
    cap = desc.capability
    timing = TimingContract(
        expected_latency_ms=cap.timing.expected_latency_ms,
        observation_window_ms=cap.timing.observation_window_ms,
        min_stabilization_ms=cap.timing.min_stabilization_ms,
        deadline_ms=task.latency_budget_ms,
    )
    lifecycle = LifecycleContract(
        prepare_actions=("warmup",) if cap.lifecycle.warmup_ms > 0 else (),
        cleanup_actions=cap.lifecycle.recovery_modes[:1],
        mandatory_recovery_ms=cap.lifecycle.cooldown_ms,
    )
    required = tuple(task.required_telemetry) or cap.observability.telemetry_fields[:1]
    telemetry = TelemetryContract(
        required_fields=required,
        optional_fields=cap.observability.telemetry_fields,
        twin_linked_fields=cap.observability.twin_linked_fields,
    )
    return SessionContracts(timing, lifecycle, telemetry, created_at=now)
