"""Telemetry-driven health manager: per-resource circuit breakers.

The paper's Table IV exercises recovery as a scripted one-shot campaign;
physical substrates drift, degrade and fail over their *lifetime*, so
recovery must be a continuous control loop on the live control plane.  The
HealthManager subscribes to the :class:`TelemetryBus` and drives one
circuit breaker per resource through

    healthy -> degraded -> open (quarantined) -> probation -> healthy

- **healthy → degraded** — soft signals: moderate drift or a rising error
  rate.  Degraded resources stay admissible (the matcher's runtime terms
  already de-prefer them); the state is an early-warning hysteresis band.
- **→ open** — hard signals: consecutive failures, windowed error rate,
  drift beyond the matcher's hard limit, a ``failed`` health snapshot,
  sustained twin-fidelity collapse (measured shadow divergence — see
  ``twin_shadow`` events), or (when enabled) sustained latency blow-up.
  Open means *quarantined*: the
  matcher refuses the resource outright, so no new session ever starts on
  it.
- **open → probation** — after a cooldown (exponential backoff across
  re-opens) the breaker half-opens.  Probation routes a *bounded trickle*
  of real tasks through the resource: concurrent probes are capped by the
  :class:`~repro.core.policy.PolicyManager` probe-slot budget, and the
  lifecycle plane re-arms a substrate parked in FAILED/NEEDS_RESET before
  the first probe (recover-on-reopen).
- **probation → healthy** — enough consecutive probe successes re-admit
  the resource (counters and cooldown reset).  Any probe failure re-opens
  the breaker with a longer cooldown.

Thresholds are derived from the resource descriptor
(:meth:`HealthThresholds.from_descriptor`); every transition is validated
against :data:`LEGAL_BREAKER` and recorded (timestamped) so tests,
the chaos harness and ``bench_recovery`` can assert on trajectories and
measure time-to-quarantine / time-to-readmit.  All state is guarded by one
reentrant lock; telemetry events are emitted *outside* the lock.
"""
from __future__ import annotations

import dataclasses
import enum
from collections import deque
from typing import TYPE_CHECKING, Callable, Dict, List, Optional, Tuple

import threading

from repro.core.simclock import SYSTEM_CLOCK
from repro.core.telemetry import TelemetryBus, TelemetryEvent

if TYPE_CHECKING:  # pragma: no cover - annotations only
    from repro.core.policy import PolicyManager
    from repro.core.registry import CapabilityRegistry


class BreakerState(enum.Enum):
    HEALTHY = "healthy"
    DEGRADED = "degraded"
    OPEN = "open"             # quarantined: matcher refuses the resource
    PROBATION = "probation"   # half-open: bounded trickle of real tasks


#: legal breaker transitions — the property suite asserts every recorded
#: transition is in this map no matter what telemetry sequence arrives
LEGAL_BREAKER: Dict[BreakerState, Tuple[BreakerState, ...]] = {
    BreakerState.HEALTHY: (BreakerState.DEGRADED, BreakerState.OPEN),
    BreakerState.DEGRADED: (BreakerState.HEALTHY, BreakerState.OPEN),
    BreakerState.OPEN: (BreakerState.PROBATION,),
    BreakerState.PROBATION: (BreakerState.HEALTHY, BreakerState.OPEN),
}


class BreakerError(RuntimeError):
    """An internal attempt at an illegal breaker transition (a bug)."""


@dataclasses.dataclass(frozen=True)
class HealthThresholds:
    """Trip points for one resource's breaker, derived from its descriptor."""

    consecutive_failures_to_open: int = 3
    window: int = 16                       # outcomes kept for rate estimates
    min_samples: int = 6                   # rate thresholds need this many
    error_rate_to_open: float = 0.5
    error_rate_to_degrade: float = 0.25
    drift_to_degrade: float = 0.3
    drift_to_open: float = 0.5             # matches matcher.DRIFT_LIMIT
    #: multiple of the descriptor's expected latency that trips the breaker
    #: (None disables latency tripping — physical dwell is often legitimate)
    latency_factor_to_open: Optional[float] = None
    expected_latency_ms: float = 1.0
    #: twin-fidelity trips: MEASURED shadow divergence expressed as a
    #: multiple of the surrogate's declared tolerance (``twin_shadow``
    #: events).  A resource whose twin repeatedly disagrees with it this
    #: badly is misbehaving even if its self-reported drift looks clean.
    #: Divergence metrics clip at 1.0, so the effective trip divergences
    #: are capped (:data:`FIDELITY_DEGRADE_DIV_CAP` /
    #: :data:`FIDELITY_OPEN_DIV_CAP`) to stay reachable for
    #: high-tolerance surrogates (tolerance >= 1/excess).
    fidelity_excess_to_degrade: float = 1.5
    fidelity_excess_to_open: float = 3.0
    #: consecutive beyond-OPEN-threshold comparisons required to quarantine
    #: (one noisy comparison must not quarantine a healthy substrate; a
    #: merely-degraded comparison breaks the streak)
    fidelity_streak_to_open: int = 2

    #: effective-divergence ceilings for the fidelity trip points: a metric
    #: reporting total disagreement (1.0) must be able to quarantine any
    #: surrogate, whatever its declared tolerance
    FIDELITY_OPEN_DIV_CAP = 0.95
    FIDELITY_DEGRADE_DIV_CAP = 0.75

    def fidelity_trip_divergences(self, tolerance: float
                                  ) -> Tuple[float, float]:
        """(degrade_divergence, open_divergence) for one surrogate's
        declared tolerance, with the reachability caps applied."""
        tol = max(tolerance, 1e-9)
        open_div = min(self.fidelity_excess_to_open * tol,
                       self.FIDELITY_OPEN_DIV_CAP)
        degrade_div = min(self.fidelity_excess_to_degrade * tol,
                          self.FIDELITY_DEGRADE_DIV_CAP, open_div)
        return degrade_div, open_div

    @classmethod
    def from_descriptor(cls, desc, **overrides) -> "HealthThresholds":
        kw = dict(expected_latency_ms=desc.capability.timing.expected_latency_ms)
        kw.update(overrides)
        return cls(**kw)


@dataclasses.dataclass
class BreakerTransition:
    resource_id: str
    src: str
    dst: str
    reason: str
    at: float                              # manager clock (monotonic)


@dataclasses.dataclass
class AttemptToken:
    """Handed out by :meth:`HealthManager.begin_attempt`; carries whether the
    attempt consumed a probation probe slot (must be returned via
    :meth:`HealthManager.finish_attempt` exactly once) and the breaker state
    at issuance — the quarantine audit trips on any token issued while
    OPEN, independently of the refusal gate."""

    resource_id: str
    probe: bool = False
    finished: bool = False
    issued_state: str = BreakerState.HEALTHY.value


class _Breaker:
    """Per-resource mutable breaker record (internal, lock-protected)."""

    def __init__(self, thresholds: HealthThresholds, cooldown_s: float):
        self.state = BreakerState.HEALTHY
        self.thresholds = thresholds
        self.outcomes: deque = deque(maxlen=thresholds.window)
        self.latencies: deque = deque(maxlen=thresholds.window)
        self.consecutive_failures = 0
        self.last_drift = 0.0
        self.fidelity_bad_streak = 0
        self.opened_at: Optional[float] = None
        self.base_cooldown_s = cooldown_s
        self.cooldown_s = cooldown_s
        self.probe_successes = 0
        self.open_reason = ""
        #: False from half-open until recover-on-reopen completed — probes
        #: are refused meanwhile, so no session ever runs on un-rearmed
        #: hardware and the recoverer never races an early probe
        self.rearmed = True

    def error_rate(self) -> float:
        if not self.outcomes:
            return 0.0
        return 1.0 - (sum(self.outcomes) / len(self.outcomes))


class HealthManager:
    """Continuous, concurrency-safe recovery loop over the telemetry plane.

    Construction wires a bus subscription (snapshot/health events feed the
    drift path); attempt outcomes are reported explicitly by the
    orchestrator via :meth:`begin_attempt` / :meth:`finish_attempt`, which
    also enforce the quarantine ("no session starts while open") and the
    probation trickle budget.
    """

    def __init__(self, bus: TelemetryBus, policy: "PolicyManager",
                 registry: Optional["CapabilityRegistry"] = None, *,
                 cooldown_s: float = 5.0,
                 cooldown_backoff: float = 2.0,
                 cooldown_max_s: float = 60.0,
                 probe_budget: int = 1,
                 probes_to_close: int = 3,
                 thresholds: Optional[Dict] = None,
                 clock: Optional[Callable[[], float]] = None,
                 recoverer: Optional[Callable[[str], bool]] = None):
        self.bus = bus
        self.policy = policy
        self.registry = registry
        self.cooldown_s = cooldown_s
        self.cooldown_backoff = cooldown_backoff
        self.cooldown_max_s = cooldown_max_s
        self.probe_budget = max(1, probe_budget)
        self.probes_to_close = max(1, probes_to_close)
        self._threshold_overrides = dict(thresholds or {})
        # monotonic timebase for cooldown/probation timing; default is the
        # process clock seam (virtual under the scenario simulator)
        self.clock = clock if clock is not None else SYSTEM_CLOCK.monotonic
        self.recoverer = recoverer
        self._breakers: Dict[str, _Breaker] = {}               # guarded_by: _lock
        self._history: Dict[str, List[BreakerTransition]] = {}  # guarded_by: _lock
        self._lock = threading.RLock()
        # audit counters for the chaos harness / stress suite
        self._refused_while_open = 0       # guarded_by: _lock
        self._refused_probe_budget = 0     # guarded_by: _lock
        self._refused_awaiting_rearm = 0   # guarded_by: _lock
        self._started_while_open = 0       # guarded_by: _lock — MUST stay 0
        bus.subscribe(self._on_event)

    # -- breaker bookkeeping --------------------------------------------------
    def _breaker(self, rid: str) -> _Breaker:  # planelint: holds(_lock)
        br = self._breakers.get(rid)
        if br is None:
            th = HealthThresholds(**self._threshold_overrides)
            if self.registry is not None:
                desc = self.registry.get(rid)
                if desc is not None:
                    th = HealthThresholds.from_descriptor(
                        desc, **self._threshold_overrides)
            br = self._breakers[rid] = _Breaker(th, self.cooldown_s)
            self._history.setdefault(rid, [])
        return br

    def _transition(self, rid: str, br: _Breaker, dst: BreakerState,  # planelint: holds(_lock)
                    reason: str, pending: List[BreakerTransition]) -> None:
        src = br.state
        if dst is src:
            return
        if dst not in LEGAL_BREAKER[src]:
            raise BreakerError(
                f"illegal breaker transition {src.value} -> {dst.value} "
                f"for {rid} ({reason!r})")
        br.state = dst
        tr = BreakerTransition(rid, src.value, dst.value, reason, self.clock())
        self._history[rid].append(tr)
        pending.append(tr)

    def _emit(self, pending: List[BreakerTransition]) -> None:
        for tr in pending:
            self.bus.emit(TelemetryEvent(
                tr.resource_id, "breaker",
                {"from": tr.src, "to": tr.dst, "reason": tr.reason}))

    def _open(self, rid: str, br: _Breaker, reason: str,
              pending: List[BreakerTransition], reopen: bool = False) -> None:
        self._transition(rid, br, BreakerState.OPEN, reason, pending)
        br.opened_at = self.clock()
        br.open_reason = reason
        br.probe_successes = 0
        br.consecutive_failures = 0
        br.outcomes.clear()
        br.latencies.clear()
        if reopen:
            br.cooldown_s = min(self.cooldown_max_s,
                                br.cooldown_s * self.cooldown_backoff)

    def _close(self, rid: str, br: _Breaker, reason: str,
               pending: List[BreakerTransition]) -> None:
        self._transition(rid, br, BreakerState.HEALTHY, reason, pending)
        br.cooldown_s = br.base_cooldown_s
        br.opened_at = None
        br.open_reason = ""
        br.probe_successes = 0
        br.consecutive_failures = 0
        br.outcomes.clear()
        br.latencies.clear()

    def _maybe_promote(self, rid: str, br: _Breaker,
                       pending: List[BreakerTransition]) -> None:
        """OPEN → PROBATION once the cooldown elapsed (half-open)."""
        if br.state is not BreakerState.OPEN or br.opened_at is None:
            return
        if self.clock() - br.opened_at < br.cooldown_s:
            return
        self._transition(rid, br, BreakerState.PROBATION,
                         f"cooldown {br.cooldown_s:.2f}s elapsed", pending)
        br.probe_successes = 0
        br.rearmed = self.recoverer is None    # gate probes until re-armed

    # -- telemetry coupling ---------------------------------------------------
    def _on_event(self, ev: TelemetryEvent) -> None:
        if ev.kind == "twin_shadow":
            self._on_fidelity(ev)
            return
        if ev.kind not in ("health",):
            return
        drift = ev.fields.get("drift_score")
        status = ev.fields.get("health_status")
        pending: List[BreakerTransition] = []
        with self._lock:
            br = self._breaker(ev.resource_id)
            if drift is not None:
                br.last_drift = float(drift)
            th = br.thresholds
            if br.state in (BreakerState.HEALTHY, BreakerState.DEGRADED):
                if status == "failed":
                    self._open(ev.resource_id, br,
                               "snapshot reported failed health", pending)
                elif drift is not None and br.last_drift >= th.drift_to_open:
                    self._open(ev.resource_id, br,
                               f"drift {br.last_drift:.2f} >= "
                               f"{th.drift_to_open}", pending)
                elif (drift is not None
                      and br.last_drift >= th.drift_to_degrade
                      and br.state is BreakerState.HEALTHY):
                    self._transition(ev.resource_id, br, BreakerState.DEGRADED,
                                     f"drift {br.last_drift:.2f} >= "
                                     f"{th.drift_to_degrade}", pending)
                elif (br.state is BreakerState.DEGRADED and drift is not None
                      and br.last_drift < th.drift_to_degrade
                      and br.error_rate() < th.error_rate_to_degrade):
                    self._close(ev.resource_id, br,
                                f"drift recovered ({br.last_drift:.2f})",
                                pending)
        self._emit(pending)

    def _on_fidelity(self, ev: TelemetryEvent) -> None:
        """Fidelity-driven trips: measured twin-vs-real divergence
        (``twin_shadow`` events from the TwinExecutor) beyond a multiple of
        the surrogate's declared tolerance degrades and — sustained —
        quarantines the resource.  This is the paper's twin-synchronization
        claim turned into a recovery signal: the divergence is MEASURED
        against real outputs, so it catches misbehavior that adapter-self-
        reported drift misses."""
        div = float(ev.fields.get("divergence", 0.0))
        tol = max(float(ev.fields.get("tolerance", 1.0)), 1e-9)
        pending: List[BreakerTransition] = []
        with self._lock:
            br = self._breaker(ev.resource_id)
            th = br.thresholds
            degrade_div, open_div = th.fidelity_trip_divergences(tol)
            if div < degrade_div:
                br.fidelity_bad_streak = 0
            elif br.state in (BreakerState.HEALTHY, BreakerState.DEGRADED):
                if div >= open_div:
                    # only beyond-OPEN comparisons count as the consecutive
                    # streak; a degrade-band comparison breaks it below
                    br.fidelity_bad_streak += 1
                    if br.fidelity_bad_streak >= th.fidelity_streak_to_open:
                        self._open(
                            ev.resource_id, br,
                            f"twin fidelity collapse: measured divergence "
                            f"{div:.3f} >= {open_div:.3f} "
                            f"(tolerance {tol})", pending)
                        br.fidelity_bad_streak = 0
                else:
                    br.fidelity_bad_streak = 0
                if br.state is BreakerState.HEALTHY:
                    self._transition(
                        ev.resource_id, br, BreakerState.DEGRADED,
                        f"twin divergence {div:.3f} >= {degrade_div:.3f} "
                        f"(tolerance {tol})", pending)
        self._emit(pending)

    # -- admission ------------------------------------------------------------
    def admissible(self, rid: str) -> Tuple[bool, str]:
        """Matcher-facing admission term.  OPEN resources are quarantined;
        PROBATION resources are admissible only once re-armed and while a
        probe slot is free (non-reserving check — the reservation happens
        at attempt time).

        A cooled-down breaker is lazily promoted here, which runs one
        recover-on-reopen (adapter reset) on the calling thread — at most
        once per open→probation cycle.  Serial deployments need this (no
        background ticker exists); under a scheduler the ticker usually
        promotes first, keeping resets off the matching path."""
        pending: List[BreakerTransition] = []
        with self._lock:
            br = self._breaker(rid)
            self._maybe_promote(rid, br, pending)
        self._emit(pending)
        self._recover_if_promoted(rid, pending)
        with self._lock:
            br = self._breaker(rid)
            state, reason, rearmed = br.state, br.open_reason, br.rearmed
        if state is BreakerState.OPEN:
            return False, f"circuit open (quarantined): {reason}"
        if state is BreakerState.PROBATION:
            if not rearmed:
                return False, "probation awaiting re-arm"
            if self.policy.probes_held(rid) >= self.probe_budget:
                return False, "probation trickle budget exhausted"
        return True, "ok"

    def _recover_if_promoted(self, rid: str,
                             pending: List[BreakerTransition]) -> None:
        """Recover-on-reopen: when a breaker just half-opened, re-arm the
        substrate (lifecycle recovery + fresh snapshot) before probing.
        Runs outside the manager lock; a failing recovery re-opens."""
        if self.recoverer is None:
            return
        if not any(tr.dst == BreakerState.PROBATION.value for tr in pending):
            return
        try:
            recovered = self.recoverer(rid)
            why = "" if recovered else "recover-on-reopen unavailable " \
                                      "(busy or unregistered substrate)"
        except Exception as e:                       # noqa: BLE001
            recovered, why = False, f"recover-on-reopen failed: {e}"
        if recovered:
            with self._lock:
                br = self._breaker(rid)
                if br.state is BreakerState.PROBATION:
                    br.rearmed = True      # probes may flow now
            return
        # probing un-rearmed hardware would break the re-arm guarantee:
        # go back to OPEN with backoff and retry the recovery later
        reopen_pending: List[BreakerTransition] = []
        with self._lock:
            br = self._breaker(rid)
            if br.state is BreakerState.PROBATION:
                self._open(rid, br, why, reopen_pending, reopen=True)
        self._emit(reopen_pending)

    def tick(self) -> None:
        """Background probe tick (driven by the scheduler): promote every
        cooled-down OPEN breaker into PROBATION.  Time comes from the
        injectable constructor ``clock``."""
        pending: List[BreakerTransition] = []
        with self._lock:
            for rid, br in list(self._breakers.items()):
                self._maybe_promote(rid, br, pending)
        self._emit(pending)
        # group recoveries per promoted resource (outside the lock)
        for rid in {tr.resource_id for tr in pending
                    if tr.dst == BreakerState.PROBATION.value}:
            self._recover_if_promoted(
                rid, [tr for tr in pending if tr.resource_id == rid])

    # -- attempt lifecycle (orchestrator-facing) ------------------------------
    def begin_attempt(self, rid: str
                      ) -> Tuple[bool, Optional[AttemptToken], str]:
        """Gate one execution attempt.  Returns ``(allowed, token, reason)``;
        the token must be handed back through :meth:`finish_attempt`."""
        pending: List[BreakerTransition] = []
        try:
            with self._lock:
                br = self._breaker(rid)
                self._maybe_promote(rid, br, pending)
                if br.state is BreakerState.OPEN:
                    self._refused_while_open += 1
                    return False, None, \
                        f"circuit open (quarantined): {br.open_reason}"
                if br.state is BreakerState.PROBATION:
                    if not br.rearmed:
                        self._refused_awaiting_rearm += 1
                        return False, None, "probation awaiting re-arm"
                    if not self.policy.acquire_probe(rid, self.probe_budget):
                        self._refused_probe_budget += 1
                        return False, None, "probation trickle budget exhausted"
                    return True, AttemptToken(rid, probe=True,
                                              issued_state=br.state.value), "ok"
                return True, AttemptToken(rid, probe=False,
                                          issued_state=br.state.value), "ok"
        finally:
            self._emit(pending)
            self._recover_if_promoted(rid, pending)

    def finish_attempt(self, token: Optional[AttemptToken], ok: bool,
                       kind: str = "", latency_ms: Optional[float] = None
                       ) -> None:
        """Report the outcome of an attempt started with
        :meth:`begin_attempt` (probe slots are always returned)."""
        if token is None or token.finished:
            return
        token.finished = True
        rid = token.resource_id
        pending: List[BreakerTransition] = []
        try:
            with self._lock:
                br = self._breaker(rid)
                if token.issued_state == BreakerState.OPEN.value:
                    # quarantine invariant violated: some path handed out a
                    # token while the breaker was open (begin_attempt must
                    # refuse) — record it so audits catch the regression
                    self._started_while_open += 1
                th = br.thresholds
                br.outcomes.append(1 if ok else 0)
                if latency_ms is not None:
                    br.latencies.append(latency_ms)
                if ok:
                    br.consecutive_failures = 0
                else:
                    br.consecutive_failures += 1

                if token.probe and br.state is BreakerState.PROBATION:
                    if ok:
                        br.probe_successes += 1
                        if br.probe_successes >= self.probes_to_close:
                            self._close(rid, br,
                                        f"{br.probe_successes} probe "
                                        "successes", pending)
                    else:
                        self._open(rid, br, f"probe failed: {kind}",
                                   pending, reopen=True)
                    return

                if br.state not in (BreakerState.HEALTHY,
                                    BreakerState.DEGRADED):
                    return                 # tripped mid-flight: no-op
                if not ok:
                    n = len(br.outcomes)
                    rate = br.error_rate()
                    if br.consecutive_failures >= \
                            th.consecutive_failures_to_open:
                        self._open(rid, br,
                                   f"{br.consecutive_failures} consecutive "
                                   f"failures ({kind})", pending)
                    elif n >= th.min_samples and rate >= th.error_rate_to_open:
                        self._open(rid, br,
                                   f"error rate {rate:.2f} over {n} attempts",
                                   pending)
                    elif (rate >= th.error_rate_to_degrade
                          and br.state is BreakerState.HEALTHY):
                        self._transition(rid, br, BreakerState.DEGRADED,
                                         f"error rate {rate:.2f}", pending)
                else:
                    if self._latency_tripped(br):
                        self._open(rid, br, "sustained latency blow-up",
                                   pending)
                    elif (br.state is BreakerState.DEGRADED
                          and br.last_drift < th.drift_to_degrade
                          and len(br.outcomes) >= th.min_samples
                          and br.error_rate() < th.error_rate_to_degrade):
                        self._close(rid, br, "error rate recovered", pending)
        finally:
            if token.probe:
                self.policy.release_probe(rid)
            self._emit(pending)

    def _latency_tripped(self, br: _Breaker) -> bool:
        th = br.thresholds
        if th.latency_factor_to_open is None:
            return False
        if len(br.latencies) < th.min_samples:
            return False
        xs = sorted(br.latencies)
        p50 = xs[len(xs) // 2]
        return p50 > th.latency_factor_to_open * th.expected_latency_ms

    # -- observability --------------------------------------------------------
    def state(self, rid: str) -> BreakerState:
        with self._lock:
            return self._breaker(rid).state

    def history(self, rid: str) -> List[BreakerTransition]:
        with self._lock:
            return list(self._history.get(rid, []))

    def trajectory(self, rid: str) -> List[str]:
        """Destination states in transition order (starts implicit healthy)."""
        return [tr.dst for tr in self.history(rid)]

    def audit(self) -> Dict[str, int]:
        with self._lock:
            return {
                "refused_while_open": self._refused_while_open,
                "refused_probe_budget": self._refused_probe_budget,
                "refused_awaiting_rearm": self._refused_awaiting_rearm,
                "started_while_open": self._started_while_open,
                "probes_outstanding": sum(
                    self.policy.probe_outstanding().values()),
            }

    def status(self) -> Dict[str, Dict]:
        with self._lock:
            out = {}
            for rid, br in self._breakers.items():
                out[rid] = {
                    "state": br.state.value,
                    "error_rate": round(br.error_rate(), 4),
                    "consecutive_failures": br.consecutive_failures,
                    "last_drift": round(br.last_drift, 4),
                    "cooldown_s": br.cooldown_s,
                    "open_reason": br.open_reason or None,
                    "transitions": len(self._history.get(rid, [])),
                }
            return out
