"""whisper-large-v3 — encoder-decoder audio backbone (frontend stubbed).

[arXiv:2212.04356; unverified]  32L enc + 32L dec, d_model=1280 20H d_ff=5120
vocab=51866.  Conv/audio frontend is a STUB per assignment: ``input_specs()``
provides precomputed frame embeddings (1500, d_model).  LayerNorm, GELU FFN,
learned-positional behaviour approximated with RoPE-free absolute embeddings.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="whisper-large-v3",
    family="encdec",
    num_layers=32,                   # decoder layers
    encoder_layers=32,
    encoder_frames=1500,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    ffn_activation="gelu",
    norm="layernorm",
    qkv_bias=True,
    max_context=65536,               # decoder is quadratic attention → long_500k skipped
    source="[arXiv:2212.04356; unverified]",
))
