"""rwkv6-7b — RWKV-6 "Finch", attention-free, data-dependent decay.

[arXiv:2404.05892; hf]  32L d_model=4096 d_ff=14336 vocab=65536.
Attention-free: O(1) decode state per layer → long_500k RUNS (max_context=None).
"""
from repro.configs.base import ArchConfig, RWKVConfig, register

CONFIG = register(ArchConfig(
    name="rwkv6-7b",
    family="rwkv",
    num_layers=32,
    d_model=4096,
    num_heads=64,                    # 4096 / head_dim 64
    num_kv_heads=64,
    head_dim=64,
    d_ff=14336,
    vocab_size=65536,
    block_pattern=("rwkv",),
    ffn_activation="relu_sq_rwkv",   # RWKV channel-mix: relu(x)^2 gated by receptance
    norm="layernorm",
    max_context=None,                # attention-free: unbounded context
    microbatches=4,
    rwkv=RWKVConfig(head_dim=64, decay_lora=64, mix_lora=32, gate_lora=64),
    source="[arXiv:2404.05892; hf]",
))
