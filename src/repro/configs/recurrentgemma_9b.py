"""recurrentgemma-9b — Griffin-style hybrid: RG-LRU + local attention, 1:2.

[arXiv:2402.19427; unverified]  38L d_model=4096 16H (MQA kv=1) d_ff=12288
vocab=256000.  Block pattern (recurrent, recurrent, local_attn); local window
2048.  Bounded decode state (LRU state + window KV) → long_500k RUNS.
"""
from repro.configs.base import ArchConfig, RecurrentConfig, register

CONFIG = register(ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    block_pattern=("recurrent", "recurrent", "local_attn"),
    ffn_activation="gelu",           # GeGLU in the paper; gated gelu implemented
    local_window=2048,
    max_context=None,                # bounded state: LRU + 2048-window KV
    microbatches=4,
    recurrent=RecurrentConfig(lru_width=4096, conv_width=4, c=8.0),
    source="[arXiv:2402.19427; unverified]",
))
