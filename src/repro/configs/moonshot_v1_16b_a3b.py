"""moonshot-v1-16b-a3b — Moonlight-16B-A3B MoE, 64 experts top-6.

[hf:moonshotai/Moonlight-16B-A3B; hf]  48L d_model=2048 16H (GQA kv=16)
d_ff=1408 vocab=163840, MoE 64e top-6.  DeepSeek-V3-family MoE: 2 shared
experts, first layer dense (dense d_ff = 11264).
"""
from repro.configs.base import ArchConfig, MoEConfig, register

CONFIG = register(ArchConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=11264,                      # dense first-layer FFN (8/3 * d scaled)
    vocab_size=163840,
    microbatches=4,
    moe=MoEConfig(num_experts=64, top_k=6, expert_d_ff=1408,
                  num_shared_experts=2, shared_d_ff=2816, first_moe_layer=1),
    source="[hf:moonshotai/Moonlight-16B-A3B; hf]",
))
