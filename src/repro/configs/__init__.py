"""Assigned-architecture configs. Importing this package registers all archs."""
from repro.configs.base import (  # noqa: F401
    ARCH_REGISTRY,
    ArchConfig,
    MLAConfig,
    MoEConfig,
    RecurrentConfig,
    RWKVConfig,
    ShapeSpec,
    SHAPES,
    get_config,
    list_archs,
    reduced,
    register,
    supports_shape,
)

# one module per assigned architecture — import order is alphabetical
from repro.configs import command_r_35b  # noqa: F401,E402
from repro.configs import deepseek_v2_236b  # noqa: F401,E402
from repro.configs import internlm2_20b  # noqa: F401,E402
from repro.configs import llama_3_2_vision_90b  # noqa: F401,E402
from repro.configs import moonshot_v1_16b_a3b  # noqa: F401,E402
from repro.configs import nemotron_4_340b  # noqa: F401,E402
from repro.configs import qwen2_5_32b  # noqa: F401,E402
from repro.configs import recurrentgemma_9b  # noqa: F401,E402
from repro.configs import rwkv6_7b  # noqa: F401,E402
from repro.configs import whisper_large_v3  # noqa: F401,E402
