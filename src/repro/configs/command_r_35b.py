"""command-r-35b — dense GQA, no-bias, LayerNorm.

[hf:CohereForAI/c4ai-command-r-v01; unverified]  40L d_model=8192 64H
(GQA kv=8) d_ff=22528 vocab=256000.  (The HF model uses a parallel
attn+FFN block; the assignment line specifies only "GQA, no-bias", so the
standard sequential pre-norm block is used — noted here for provenance.)
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="command-r-35b",
    family="dense",
    num_layers=40,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22528,
    vocab_size=256000,
    norm="layernorm",
    qkv_bias=False,
    tie_embeddings=True,
    microbatches=4,
    source="[hf:CohereForAI/c4ai-command-r-v01; unverified]",
))
