"""deepseek-v2-236b — MLA (kv_lora=512) + MoE (2 shared + 160 routed, top-6).

[arXiv:2405.04434; hf]  60L d_model=5120 128H d_ff=1536(MoE) vocab=102400.
MLA: q_lora=1536, kv_lora=512, qk_nope=128, qk_rope=64, v_head=128.
First layer dense FFN d_ff=12288. bf16 optimizer moments so the 256-chip
single-pod HBM budget holds (DESIGN.md §5.4).
"""
from repro.configs.base import ArchConfig, MLAConfig, MoEConfig, register

CONFIG = register(ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,                # MLA: latent-compressed, heads share kv_lora cache
    d_ff=12288,                      # dense first-layer FFN
    vocab_size=102400,
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(num_experts=160, top_k=6, expert_d_ff=1536,
                  num_shared_experts=2, shared_d_ff=3072, first_moe_layer=1),
    moment_dtype="bfloat16",
    microbatches=8,
    remat_policy="full",
    grad_accum_dtype="bfloat16",
    source="[arXiv:2405.04434; hf]",
))
