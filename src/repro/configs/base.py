"""Architecture configuration system.

Every assigned architecture is expressed as an :class:`ArchConfig` — a single
frozen dataclass that the model builder (``repro.models.model``) consumes.
Configs register themselves into :data:`ARCH_REGISTRY` at import time via
:func:`register`; ``repro.configs`` imports every ``<arch>.py`` so that
``get_config("<id>")`` works everywhere (launcher, tests, benchmarks).

Layer kinds
-----------
The decoder stack is described by a repeating *block pattern* of layer kinds:

- ``"attn"``        — global causal self-attention (GQA)
- ``"local_attn"``  — sliding-window causal self-attention
- ``"recurrent"``   — RG-LRU gated linear recurrence block
- ``"rwkv"``        — RWKV-6 time-mix block (data-dependent decay)

Cross-attention (vision) and encoder-decoder (whisper) wiring is expressed
with dedicated fields rather than layer kinds, since they change the input
signature of the model.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts settings (GShard/DeepSeek style routed experts)."""

    num_experts: int
    top_k: int
    expert_d_ff: int
    num_shared_experts: int = 0
    shared_d_ff: Optional[int] = None          # defaults to expert_d_ff * shared
    capacity_factor: float = 1.25
    router_dtype: str = "float32"
    # index of first MoE layer; earlier layers use the dense FFN
    first_moe_layer: int = 1

    @property
    def shared_ff(self) -> int:
        if self.shared_d_ff is not None:
            return self.shared_d_ff
        return self.expert_d_ff * max(self.num_shared_experts, 1)


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 multi-head latent attention settings."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class RecurrentConfig:
    """RG-LRU (Griffin/RecurrentGemma) recurrent-block settings."""

    lru_width: int = 4096
    conv_width: int = 4
    # c constant in a_t = exp(-c * softplus(Lambda) * sigmoid(W_a x))
    c: float = 8.0


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    """RWKV-6 (Finch) time-mix settings."""

    head_dim: int = 64
    decay_lora: int = 64
    mix_lora: int = 32
    gate_lora: int = 64


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | rwkv | hybrid | encdec | vision
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None   # default d_model // num_heads
    # block pattern, tiled over num_layers (e.g. ("recurrent","recurrent","local_attn"))
    block_pattern: Tuple[str, ...] = ("attn",)
    # FFN activation: "swiglu" | "squared_relu" | "gelu" | "relu_sq_rwkv"
    ffn_activation: str = "swiglu"
    qkv_bias: bool = False
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    local_window: int = 4096         # for "local_attn" layers
    # sub-quadratic context support: None = quadratic attention (long_500k skips)
    max_context: Optional[int] = 131072
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    recurrent: Optional[RecurrentConfig] = None
    rwkv: Optional[RWKVConfig] = None
    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    encoder_frames: int = 1500       # precomputed frame embeddings (frontend stub)
    # --- vision cross-attention (llama-3.2-vision) ---
    cross_attn_every: int = 0        # every Nth layer is a gated cross-attn layer
    num_image_tokens: int = 1600     # precomputed patch embeddings (frontend stub)
    # --- numerics ---
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # optimizer moment dtype ("float32" default, "bfloat16" for the 236B/340B
    # archs so the single-pod 256 x 16GB HBM budget holds — see DESIGN.md §5.4)
    moment_dtype: str = "float32"
    remat_policy: str = "full"       # nothing | dots | full | moe (hillclimb)
    grad_accum_dtype: str = "float32"  # bf16 halves the accumulator for giants
    microbatches: int = 1            # gradient-accumulation steps per train step
    attn_chunk: int = 512            # online-softmax query-block size
    xent_chunk: int = 256            # chunked cross-entropy sequence block
    use_pallas: bool = False         # TPU target path; CPU dry-run uses pure JAX
    source: str = ""                 # provenance note [citation; tier]

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    def layer_kinds(self) -> Tuple[str, ...]:
        """The per-layer kind list, tiling ``block_pattern`` to num_layers."""
        pat = self.block_pattern
        reps = (self.num_layers + len(pat) - 1) // len(pat)
        return tuple((pat * reps)[: self.num_layers])

    def kind_counts(self) -> dict:
        kinds = self.layer_kinds()
        return {k: kinds.count(k) for k in sorted(set(kinds))}

    @property
    def dtype(self):
        return jnp.dtype(self.compute_dtype)

    def num_params(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6·N·D)."""
        from repro.models.model import count_params  # local import to avoid cycle
        return count_params(self)

    def num_active_params(self) -> int:
        from repro.models.model import count_params
        return count_params(self, active_only=True)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                        # "train" | "prefill" | "decode"


SHAPES: dict = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


ARCH_REGISTRY: dict = {}


def register(cfg: ArchConfig) -> ArchConfig:
    ARCH_REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    import repro.configs  # noqa: F401  — triggers per-arch module imports

    if name not in ARCH_REGISTRY:
        raise KeyError(f"unknown architecture {name!r}; known: {sorted(ARCH_REGISTRY)}")
    return ARCH_REGISTRY[name]


def list_archs() -> Sequence[str]:
    import repro.configs  # noqa: F401

    return sorted(ARCH_REGISTRY)


def supports_shape(cfg: ArchConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Admission check for an (arch × shape) cell.

    This is the control-plane capability check: quadratic-attention archs do
    not advertise 500k contexts, so the long_500k cell is rejected by the
    descriptor rather than silently attempted (DESIGN.md §4).
    """
    if shape.kind == "decode" and cfg.family == "encdec" and shape.seq_len > 65536:
        return False, "enc-dec decoder context bound"
    if cfg.max_context is not None and shape.seq_len > cfg.max_context:
        return False, (
            f"{cfg.name} is quadratic-attention (max_context={cfg.max_context}); "
            f"{shape.name} ({shape.seq_len}) requires sub-quadratic decode state"
        )
    return True, "ok"


def reduced(cfg: ArchConfig, **overrides) -> ArchConfig:
    """A tiny same-family config for CPU smoke tests.

    Keeps the layer-kind *pattern* (so at least one full pattern repetition
    runs), shrinks widths/experts/vocab.
    """
    small = dict(
        num_layers=max(len(cfg.block_pattern) * 2, 2),
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2),
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        encoder_layers=2 if cfg.encoder_layers else 0,
        encoder_frames=16 if cfg.encoder_layers else 1500,
        cross_attn_every=cfg.cross_attn_every and 2,
        num_image_tokens=8 if cfg.cross_attn_every else 1600,
        local_window=16,
        attn_chunk=16,
        xent_chunk=32,
        microbatches=1,
        moment_dtype="float32",
        param_dtype="float32",
        compute_dtype="float32",
    )
    if cfg.moe is not None:
        small["moe"] = dataclasses.replace(
            cfg.moe, num_experts=4, top_k=2, expert_d_ff=64,
            num_shared_experts=min(cfg.moe.num_shared_experts, 1),
            shared_d_ff=64 if cfg.moe.num_shared_experts else None,
            first_moe_layer=min(cfg.moe.first_moe_layer, 1),
            # drop-free on CPU so decode/forward parity is exact: capacity
            # drops legitimately differ with sequence length otherwise
            capacity_factor=8.0,
        )
    if cfg.mla is not None:
        small["mla"] = MLAConfig(kv_lora_rank=32, q_lora_rank=48,
                                 qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16)
        small["head_dim"] = None
    if cfg.recurrent is not None:
        small["recurrent"] = RecurrentConfig(lru_width=64, conv_width=4, c=8.0)
    if cfg.rwkv is not None:
        small["rwkv"] = RWKVConfig(head_dim=16, decay_lora=8, mix_lora=8, gate_lora=8)
        small["num_heads"] = 4
        small["head_dim"] = 16
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
