"""nemotron-4-340b — dense GQA with squared-ReLU FFN.

[arXiv:2402.16819; unverified]  96L d_model=18432 96H (GQA kv=8) d_ff=73728
vocab=256000.  Squared-ReLU MLP (no gating).  bf16 optimizer moments are
mandatory at this size for the single-pod HBM budget (DESIGN.md §5.4).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="nemotron-4-340b",
    family="dense",
    num_layers=96,
    d_model=18432,
    num_heads=96,
    num_kv_heads=8,
    head_dim=192,
    d_ff=73728,
    vocab_size=256000,
    ffn_activation="squared_relu",
    norm="layernorm",
    moment_dtype="bfloat16",
    grad_accum_dtype="bfloat16",
    microbatches=8,
    remat_policy="full",
    source="[arXiv:2402.16819; unverified]",
))
