"""llama-3.2-vision-90b — 100L backbone with gated cross-attn image layers.

[hf:meta-llama/Llama-3.2-11B-Vision; unverified]  100L d_model=8192 64H
(GQA kv=8) d_ff=28672 vocab=128256.  Every 5th layer is a gated cross-attn
layer attending to precomputed image patch embeddings (vision frontend STUB:
``input_specs()`` provides (B, 1600, d_model) patch embeddings).
bf16 optimizer moments (90B-class, DESIGN.md §5.4).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llama-3.2-vision-90b",
    family="vision",
    num_layers=100,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    cross_attn_every=5,
    num_image_tokens=1600,
    moment_dtype="bfloat16",
    microbatches=8,
    remat_policy="full",
    source="[hf:meta-llama/Llama-3.2-11B-Vision; unverified]",
))
