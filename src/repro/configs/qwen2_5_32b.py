"""qwen2.5-32b — dense GQA with QKV bias.

[hf:Qwen/Qwen2.5-0.5B; hf]  64L d_model=5120 40H (GQA kv=8) d_ff=27648
vocab=152064.  40 heads over 16-way tensor parallel is non-divisible —
GSPMD pads; the inefficiency shows up in the roofline table (hillclimb axis).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen2.5-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=27648,
    vocab_size=152064,
    qkv_bias=True,
    attn_chunk=256,          # 40 heads replicated over model axis — keep score blocks small
    microbatches=4,
    source="[hf:Qwen/Qwen2.5-0.5B; hf]",
))
