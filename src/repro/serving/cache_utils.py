"""Decode-cache utilities: growing a prefill cache into a decode cache.

Prefill emits caches sized to the prompt; decode wants ``max_seq`` slots.
``extend_cache`` right-pads the sequence axis of global KV leaves and
re-rolls ring-buffered local-window leaves so that slot ``p % window`` holds
absolute position ``p`` (the invariant ``decode_attention`` relies on).

``write_slots`` is the continuous-batching primitive: it scatters the batch
rows of one cache (a fresh per-request prefill, already extended to decode
shape) into chosen batch slots of the shared decode cache, so sequences can
join and leave the running decode batch without touching other rows.

``write_prefill_paged`` / ``gather_pages`` are the paged-serving variants:
pageable leaves (global attn K/V, MLA latents) live in a shared
``(num_pages+1, page_size, ...)`` pool indexed through per-row page tables,
while resident leaves (ring-buffer window, recurrent/rwkv carries, cross
K/V) keep the slot-granular layout.  A bool ``flags`` tree (from
``repro.models.paged_cache_flags``) tells the two layouts apart — leaf
names alone cannot (``k``/``v`` is paged under global attention but
resident under a local ring buffer).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# leaf name -> seq axis (in the unstacked (B, S, ...) layout); stacked leaves
# gain a leading layer axis
_SEQ_LEAVES = {"k": 1, "v": 1, "c_kv": 1, "k_rope": 1}


def _leaf_name(path):
    for p in reversed(path):
        if isinstance(p, jax.tree_util.DictKey):
            return p.key
    return None


def _stacked(path) -> bool:
    return any(isinstance(p, jax.tree_util.DictKey) and p.key == "blocks"
               for p in path)


def _fit_seq(name, tmpl, src, prompt_len: int):
    """Fit a prefill seq leaf into a decode-shaped template (pad the seq
    axis, or ring-roll + keep-latest for bounded windows)."""
    base_rank = 3 if name in ("c_kv", "k_rope") else 4
    ax = _SEQ_LEAVES[name] + (src.ndim - base_rank)
    src_len = src.shape[ax]
    tmpl_len = tmpl.shape[ax]
    if src_len < prompt_len:
        # ring buffer (local window): slot p % w must hold position p
        w = src_len
        shift = prompt_len % w
        src = jnp.roll(src, shift, axis=ax)
    if src.shape[ax] <= tmpl_len:
        pad = [(0, 0)] * src.ndim
        pad[ax] = (0, tmpl_len - src.shape[ax])
        return jnp.pad(src, pad)
    # template window smaller than source: keep the latest slots
    sl = [slice(None)] * src.ndim
    sl[ax] = slice(src.shape[ax] - tmpl_len, None)
    return src[tuple(sl)]


def extend_cache(template, prefill_cache, prompt_len: int):
    """Fit ``prefill_cache`` into ``template`` (zeros of decode shape)."""

    def f(path, tmpl, src):
        name = _leaf_name(path)
        tmpl = jnp.asarray(tmpl)
        src = jnp.asarray(src).astype(tmpl.dtype)
        if src.shape == tmpl.shape:
            return src
        if name in _SEQ_LEAVES:
            return _fit_seq(name, tmpl, src, prompt_len)
        raise ValueError(
            f"cache leaf {name!r}: prefill shape {src.shape} does not fit "
            f"decode template {tmpl.shape}")

    return jax.tree_util.tree_map_with_path(f, template, prefill_cache)


def write_slots(cache, rows, slots):
    """Scatter the batch rows of ``rows`` into ``cache`` at indices ``slots``.

    ``rows`` must have the same tree structure and per-leaf trailing shape as
    ``cache`` with batch size ``len(slots)`` (typically 1: one freshly
    prefilled request claiming a freed slot).  Leaves under the scan-stacked
    ``"blocks"`` group carry a leading layer axis, so their batch axis is 1;
    every other leaf is batch-leading.
    """
    slots = jnp.asarray(slots, jnp.int32)

    def f(path, dst, src):
        dst = jnp.asarray(dst)
        src = jnp.asarray(src).astype(dst.dtype)
        if _stacked(path):
            return dst.at[:, slots].set(src)
        return dst.at[slots].set(src)

    return jax.tree_util.tree_map_with_path(f, cache, rows)


def write_prefill_paged(flags, cache, prefill_cache, pages, slot,
                        prompt_len: int, page_size: int):
    """Scatter one B=1 prefill into the paged decode cache.

    Pageable leaves: the prefilled tokens (zero-padded to whole pages) are
    scattered into pool rows ``pages`` — one page id per token block, in
    block order.  Prefix reuse passes only the *suffix* prefill here with
    the suffix's (private) pages; the suffix always starts page-aligned
    because only whole pages are ever shared.  Resident leaves: the row is
    fitted (``extend_cache`` semantics) and scattered at batch ``slot``.
    """
    pages = jnp.asarray(pages, jnp.int32)
    slot = jnp.asarray(slot, jnp.int32)
    n = pages.shape[0]

    def f(path, flag, dst, src):
        dst = jnp.asarray(dst)
        src = jnp.asarray(src).astype(dst.dtype)
        stacked = _stacked(path)
        if flag:
            s = src[:, 0] if stacked else src[0]       # drop the B=1 axis
            ax = 1 if stacked else 0                   # seq axis after drop
            pad_n = n * page_size - s.shape[ax]
            if pad_n:
                spec = [(0, 0)] * s.ndim
                spec[ax] = (0, pad_n)
                s = jnp.pad(s, spec)
            s = s.reshape(s.shape[:ax] + (n, page_size) + s.shape[ax + 1:])
            return dst.at[:, pages].set(s) if stacked else dst.at[pages].set(s)
        name = _leaf_name(path)
        tmpl = dst[:, :1] if stacked else dst[:1]
        if src.shape != tmpl.shape:
            if name not in _SEQ_LEAVES:
                raise ValueError(
                    f"cache leaf {name!r}: prefill shape {src.shape} does "
                    f"not fit decode row {tmpl.shape}")
            src = _fit_seq(name, tmpl, src, prompt_len)
        return dst.at[:, slot].set(src) if stacked else dst.at[slot].set(src)

    return jax.tree_util.tree_map_with_path(f, flags, cache, prefill_cache)


def gather_pages(flags, cache, pages):
    """Gather pool pages into contiguous past leaves for prefix reuse.

    Every leaf must be pageable (prefix sharing is gated to pure attn/mla
    stacks); returns ``(1, n_pages * page_size, ...)`` leaves (with the
    leading layer axis preserved for stacked ``blocks`` leaves) shaped like
    a B=1 prefill of the shared prefix.
    """
    pages = jnp.asarray(pages, jnp.int32)

    def f(path, flag, leaf):
        if not flag:
            raise ValueError(
                f"prefix gather hit a non-paged leaf {_leaf_name(path)!r}")
        leaf = jnp.asarray(leaf)
        if _stacked(path):
            g = leaf[:, pages]                         # (reps, n, ps, ...)
            return g.reshape((g.shape[0], 1, g.shape[1] * g.shape[2])
                             + g.shape[3:])
        g = leaf[pages]                                # (n, ps, ...)
        return g.reshape((1, g.shape[0] * g.shape[1]) + g.shape[2:])

    return jax.tree_util.tree_map_with_path(f, flags, cache)
