"""Decode-cache utilities: growing a prefill cache into a decode cache.

Prefill emits caches sized to the prompt; decode wants ``max_seq`` slots.
``extend_cache`` right-pads the sequence axis of global KV leaves and
re-rolls ring-buffered local-window leaves so that slot ``p % window`` holds
absolute position ``p`` (the invariant ``decode_attention`` relies on).

``write_slots`` is the continuous-batching primitive: it scatters the batch
rows of one cache (a fresh per-request prefill, already extended to decode
shape) into chosen batch slots of the shared decode cache, so sequences can
join and leave the running decode batch without touching other rows.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# leaf name -> seq axis (in the unstacked (B, S, ...) layout); stacked leaves
# gain a leading layer axis
_SEQ_LEAVES = {"k": 1, "v": 1, "c_kv": 1, "k_rope": 1}


def _leaf_name(path):
    for p in reversed(path):
        if isinstance(p, jax.tree_util.DictKey):
            return p.key
    return None


def extend_cache(template, prefill_cache, prompt_len: int):
    """Fit ``prefill_cache`` into ``template`` (zeros of decode shape)."""

    def f(path, tmpl, src):
        name = _leaf_name(path)
        tmpl = jnp.asarray(tmpl)
        src = jnp.asarray(src).astype(tmpl.dtype)
        if src.shape == tmpl.shape:
            return src
        if name in _SEQ_LEAVES:
            base_rank = 3 if name in ("c_kv", "k_rope") else 4
            ax = _SEQ_LEAVES[name] + (src.ndim - base_rank)
            src_len = src.shape[ax]
            tmpl_len = tmpl.shape[ax]
            if src_len < prompt_len:
                # ring buffer (local window): slot p % w must hold position p
                w = src_len
                shift = prompt_len % w
                src = jnp.roll(src, shift, axis=ax)
            if src.shape[ax] <= tmpl_len:
                pad = [(0, 0)] * src.ndim
                pad[ax] = (0, tmpl_len - src.shape[ax])
                out = jnp.pad(src, pad)
                return out
            # template window smaller than source: keep the latest slots
            sl = [slice(None)] * src.ndim
            sl[ax] = slice(src.shape[ax] - tmpl_len, None)
            return src[tuple(sl)]
        raise ValueError(
            f"cache leaf {name!r}: prefill shape {src.shape} does not fit "
            f"decode template {tmpl.shape}")

    return jax.tree_util.tree_map_with_path(f, template, prefill_cache)


def write_slots(cache, rows, slots):
    """Scatter the batch rows of ``rows`` into ``cache`` at indices ``slots``.

    ``rows`` must have the same tree structure and per-leaf trailing shape as
    ``cache`` with batch size ``len(slots)`` (typically 1: one freshly
    prefilled request claiming a freed slot).  Leaves under the scan-stacked
    ``"blocks"`` group carry a leading layer axis, so their batch axis is 1;
    every other leaf is batch-leading.
    """
    slots = jnp.asarray(slots, jnp.int32)

    def f(path, dst, src):
        dst = jnp.asarray(dst)
        src = jnp.asarray(src).astype(dst.dtype)
        stacked = any(isinstance(p, jax.tree_util.DictKey) and p.key == "blocks"
                      for p in path)
        if stacked:
            return dst.at[:, slots].set(src)
        return dst.at[slots].set(src)

    return jax.tree_util.tree_map_with_path(f, cache, rows)
