from repro.serving.cache_utils import (extend_cache, gather_pages,  # noqa: F401
                                       write_prefill_paged, write_slots)
from repro.serving.engine import Request, ServingEngine  # noqa: F401
from repro.serving.kv_pages import (PagePool, PoolExhausted,  # noqa: F401
                                    PrefixCache)
