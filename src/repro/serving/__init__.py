from repro.serving.cache_utils import extend_cache, write_slots  # noqa: F401
from repro.serving.engine import Request, ServingEngine  # noqa: F401
