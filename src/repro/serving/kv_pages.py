"""Block-granular KV page allocator + refcounted prefix cache.

The slot-granular decode cache reserved ``max_seq`` tokens of HBM per batch
slot regardless of actual request length, so the substrate's descriptor
lied about capacity (ISSUE 10 / ROADMAP item 1).  This module is the
python-side bookkeeping of the paged replacement:

- :class:`PagePool` — a fixed pool of ``num_pages`` KV pages of
  ``page_size`` tokens each.  Page ids are ``1..num_pages``; id 0 is the
  *null page*, a trash row in the device pool tensors that dead batch rows
  write into and no one ever reads (``kv_valid`` masks it).  Pages are
  refcounted so the prefix cache can share them across requests; a
  *reservation* counter implements conservative admission: a request
  reserves its worst-case page need up front, which guarantees that
  on-demand allocation during decode can never fail (see
  :meth:`PagePool.alloc`).
- :class:`PrefixCache` — chain-hash of *full* prompt token blocks → page
  id.  A request whose prompt shares a cached prefix prefills only its
  suffix and increfs the shared pages.  Only whole pages are ever shared
  and decode always writes at positions >= the prompt length, so shared
  pages are immutable — copy-on-write semantics without ever copying.

Thread discipline: both classes are caller-synchronized (the engine holds
its lock around every call); they keep no locks of their own so the
hypothesis property tests can drive them single-threaded.
"""
from __future__ import annotations

import collections
import hashlib
from typing import Dict, List, Optional, Tuple

import numpy as np


class PoolExhausted(RuntimeError):
    """Allocation asked for more pages than are free (after eviction).

    Under conservative reservation accounting this is unreachable for
    reserved work — seeing it means a caller allocated without reserving.
    """


class PagePool:
    """Fixed free-list pool of refcounted KV pages (ids ``1..num_pages``)."""

    def __init__(self, num_pages: int, page_size: int):
        if num_pages < 1:
            raise ValueError(f"pool needs at least one page, got {num_pages}")
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        # LIFO free list: freshly freed pages are reused first (their pool
        # rows are warm); pop() order on a fresh pool is 1, 2, 3, ...
        self._free: List[int] = list(range(self.num_pages, 0, -1))
        self._ref: Dict[int, int] = {}
        self._reserved = 0

    # -- accounting -----------------------------------------------------------
    def free_pages(self) -> int:
        return len(self._free)

    def used_pages(self) -> int:
        return self.num_pages - len(self._free)

    @property
    def reserved_pages(self) -> int:
        return self._reserved

    def utilization(self) -> float:
        return self.used_pages() / self.num_pages

    # -- reservation (admission) ----------------------------------------------
    def reserve(self, n: int) -> bool:
        """Reserve worst-case capacity for one request at admission.

        Returns False (refuse: QUEUE_SATURATED) when granting ``n`` more
        pages could over-commit the pool.  Reservations ignore prefix
        sharing, so actual usage never exceeds the reserved total — which
        is the invariant that makes mid-decode :meth:`alloc` infallible.
        """
        if n < 0:
            raise ValueError(f"cannot reserve {n} pages")
        if self._reserved + n > self.num_pages:
            return False
        self._reserved += n
        return True

    def unreserve(self, n: int) -> None:
        if n > self._reserved:
            raise AssertionError(
                f"unreserve({n}) exceeds outstanding reservation "
                f"{self._reserved}")
        self._reserved -= n

    # -- allocation / refcounts -----------------------------------------------
    def alloc(self, n: int) -> List[int]:
        """Take ``n`` pages off the free list (each born with refcount 1)."""
        if n > len(self._free):
            raise PoolExhausted(
                f"need {n} pages, {len(self._free)} free "
                f"({self.used_pages()}/{self.num_pages} used, "
                f"{self._reserved} reserved)")
        pages = [self._free.pop() for _ in range(n)]
        for pid in pages:
            self._ref[pid] = 1
        return pages

    def incref(self, pid: int) -> int:
        if pid not in self._ref:
            raise AssertionError(f"incref of unallocated page {pid}")
        self._ref[pid] += 1
        return self._ref[pid]

    def decref(self, pid: int) -> int:
        """Drop one reference; a page at zero returns to the free list."""
        if pid not in self._ref:
            raise AssertionError(f"double free of page {pid}")
        c = self._ref[pid] - 1
        if c == 0:
            del self._ref[pid]
            self._free.append(pid)
        else:
            self._ref[pid] = c
        return c

    def refcount(self, pid: int) -> int:
        return self._ref.get(pid, 0)

    # -- audit ----------------------------------------------------------------
    def audit(self) -> Dict[str, int]:
        """Leak/consistency audit: free + used must cover the pool exactly,
        every allocated page must hold a positive refcount, and the free
        list must never contain duplicates or allocated ids."""
        free_set = set(self._free)
        if len(free_set) != len(self._free):
            raise AssertionError("free list contains duplicate pages")
        if free_set & set(self._ref):
            raise AssertionError("page simultaneously free and allocated")
        if len(self._free) + len(self._ref) != self.num_pages:
            raise AssertionError(
                f"page leak: {len(self._free)} free + {len(self._ref)} "
                f"allocated != {self.num_pages} pool pages")
        if any(c < 1 for c in self._ref.values()):
            raise AssertionError("allocated page with non-positive refcount")
        return {"pool_pages": self.num_pages, "used": self.used_pages(),
                "free": self.free_pages(), "reserved": self._reserved}


def _block_keys(prompt: np.ndarray, page_size: int, n_blocks: int
                ) -> List[bytes]:
    """Chain digests of the first ``n_blocks`` full token blocks.

    Each key commits to the whole prefix up to its block (``h_i =
    H(h_{i-1} || tokens_i)``), so equal keys imply token-identical
    prefixes — divergent suffixes can never alias a shared page.
    """
    keys: List[bytes] = []
    h = b"kv-prefix-v1"
    tokens = np.ascontiguousarray(np.asarray(prompt, np.int32))
    for i in range(n_blocks):
        block = tokens[i * page_size:(i + 1) * page_size]
        h = hashlib.blake2b(h + block.tobytes(), digest_size=16).digest()
        keys.append(h)
    return keys


class PrefixCache:
    """LRU map of prompt-prefix block hashes → shared, refcounted pages.

    The cache holds one reference on every registered page; live requests
    that hit hold their own.  Evicting an entry drops only the cache's
    reference, so pages shared with in-flight requests survive until those
    requests finish.  Evicting a mid-chain entry leaves later blocks of
    the same prefix unreachable for future lookups (the chain walk stops
    at the first miss); they age out of the LRU in turn.
    """

    def __init__(self, pool: PagePool):
        self.pool = pool
        self._entries: "collections.OrderedDict[bytes, int]" = \
            collections.OrderedDict()
        self.hits = 0            # lookups that matched >= 1 block
        self.misses = 0
        self.hit_tokens = 0      # prompt tokens served from shared pages
        self.lookup_tokens = 0   # prompt tokens presented to lookup

    def __len__(self) -> int:
        return len(self._entries)

    def hit_rate(self) -> float:
        """Fraction of presented prompt tokens served from shared pages."""
        if self.lookup_tokens == 0:
            return 0.0
        return self.hit_tokens / self.lookup_tokens

    # -- lookup / insert ------------------------------------------------------
    def lookup(self, prompt: np.ndarray, page_size: int
               ) -> Tuple[int, List[int]]:
        """Longest cached prefix of ``prompt`` in whole blocks.

        Returns ``(n_blocks, page_ids)`` with one reference taken on each
        returned page for the caller (released via ``PagePool.decref`` at
        request finish).  At least one suffix token is always left
        un-cached so the suffix prefill has a token to predict from.
        """
        limit = max(len(prompt) - 1, 0) // page_size
        self.lookup_tokens += len(prompt)
        pages: List[int] = []
        for key in _block_keys(prompt, page_size, limit):
            pid = self._entries.get(key)
            if pid is None:
                break
            self._entries.move_to_end(key)
            pages.append(pid)
        for pid in pages:
            self.pool.incref(pid)
        if pages:
            self.hits += 1
            self.hit_tokens += len(pages) * page_size
        else:
            self.misses += 1
        return len(pages), pages

    def probe(self, prompt: np.ndarray, page_size: int) -> int:
        """Tokens a lookup would serve from cache — no refs, no LRU touch
        (admission pricing must not mutate cache state)."""
        limit = max(len(prompt) - 1, 0) // page_size
        n = 0
        for key in _block_keys(prompt, page_size, limit):
            if key not in self._entries:
                break
            n += 1
        return n * page_size

    def insert(self, prompt: np.ndarray, pages: List[int], page_size: int
               ) -> int:
        """Register every full block of a just-prefilled prompt.

        ``pages`` is the request's page list in block order (shared prefix
        + freshly written pages).  Each newly registered page gains the
        cache's reference.  Partial trailing pages are never registered —
        that is what keeps every shared page immutable.  Returns the
        number of blocks newly registered.
        """
        n_full = len(prompt) // page_size
        added = 0
        for i, key in enumerate(_block_keys(prompt, page_size, n_full)):
            if key in self._entries:
                self._entries.move_to_end(key)
                continue
            pid = pages[i]
            self.pool.incref(pid)
            self._entries[key] = pid
            added += 1
        return added

    # -- eviction -------------------------------------------------------------
    def evict_one(self) -> bool:
        """Drop the least-recently-used entry (cache reference only).

        Returns False when the cache is empty.  The freed page only
        returns to the pool if no live request still shares it.
        """
        if not self._entries:
            return False
        _, pid = self._entries.popitem(last=False)
        self.pool.decref(pid)
        return True

    def flush(self) -> None:
        while self.evict_one():
            pass
