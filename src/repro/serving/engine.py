"""Batched serving engine: prefill + decode over the KV cache substrate.

A minimal-but-real continuous-batching loop: requests join a waiting queue,
are prefilled in groups, and decode advances all live sequences one token a
step.  Built on the same ``build_prefill_step`` / ``build_decode_step``
functions the dry-run lowers for the 512-chip mesh, so what serves on one
CPU device here is exactly what compiles for the pod.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import (build_decode_step, build_prefill_step, decode_cache,
                          model_specs)
from repro.models.common import init_params


@dataclasses.dataclass
class Request:
    request_id: str
    prompt: np.ndarray                  # (S,) int32
    max_new_tokens: int = 8
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    """Fixed-batch engine over a reduced config (CPU) or pod mesh (TPU)."""

    def __init__(self, cfg, params=None, *, batch_size: int = 2,
                 max_seq: int = 128, seed: int = 0):
        self.cfg = cfg
        self.batch_size = batch_size
        self.max_seq = max_seq
        self.params = params if params is not None else init_params(
            model_specs(cfg), seed)
        self._prefill = jax.jit(build_prefill_step(cfg))
        self._decode = jax.jit(build_decode_step(cfg), donate_argnums=1)
        self.metrics: Dict[str, float] = {"prefill_ms": 0.0, "decode_ms": 0.0,
                                          "tokens": 0}

    def _batch_extras(self, B):
        extras = {}
        if self.cfg.family == "encdec":
            extras["frames"] = jnp.zeros(
                (B, self.cfg.encoder_frames, self.cfg.d_model),
                jnp.dtype(self.cfg.param_dtype))
        if self.cfg.family == "vision":
            extras["image_embeds"] = jnp.zeros(
                (B, self.cfg.num_image_tokens, self.cfg.d_model),
                jnp.dtype(self.cfg.param_dtype))
        return extras

    def generate(self, requests: List[Request]) -> List[Request]:
        """Serve a group of requests to completion (greedy decoding)."""
        assert len(requests) <= self.batch_size
        B = self.batch_size
        S = max(len(r.prompt) for r in requests)
        prompts = np.zeros((B, S), np.int32)
        for i, r in enumerate(requests):
            prompts[i, S - len(r.prompt):] = r.prompt     # left-pad
        batch = {"tokens": jnp.asarray(prompts), **self._batch_extras(B)}

        t0 = time.perf_counter()
        prefill_cache, logits = self._prefill(self.params, batch)
        self.metrics["prefill_ms"] += (time.perf_counter() - t0) * 1e3

        # decode continues in a max_seq cache primed from the prefill cache
        from repro.serving.cache_utils import extend_cache
        cache = decode_cache(self.cfg, B, self.max_seq)
        cache = extend_cache(cache, prefill_cache, S)
        token = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        max_new = max(r.max_new_tokens for r in requests)
        for step in range(max_new):
            pos = jnp.int32(S + step)
            t0 = time.perf_counter()
            cache, logits = self._decode(self.params, cache, token, pos)
            self.metrics["decode_ms"] += (time.perf_counter() - t0) * 1e3
            self.metrics["tokens"] += len(requests)
            token = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            tok_np = np.asarray(token[:, 0])
            for i, r in enumerate(requests):
                if len(r.generated) < r.max_new_tokens:
                    r.generated.append(int(tok_np[i]))
                else:
                    r.done = True
        for r in requests:
            r.done = True
        return requests
