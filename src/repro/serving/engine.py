"""LM serving engine: prefill + decode over the KV cache substrate.

Two serving modes share the same jitted ``build_prefill_step`` /
``build_decode_step`` functions the dry-run lowers for the 512-chip mesh,
so what serves on one CPU device here is exactly what compiles for the pod:

- :meth:`ServingEngine.generate` — fixed-batch run-to-completion: one group
  is left-padded to a common length, prefilled together, and decoded until
  every member is done.  This is the measurable baseline continuous
  batching is judged against.
- continuous batching — :meth:`submit` puts a request on the waiting queue;
  :meth:`step` advances the shared decode batch one token.  Each batch slot
  owns an independent timeline: a freed slot is re-primed from a fresh B=1
  prefill and the per-row position vector keeps every other sequence exact.
  Requests join and leave the batch every step, which is what turns
  mixed-length traffic from head-of-line blocking into goodput.

KV storage comes in two layouts:

- **slot-granular** (default) — every batch slot owns a contiguous
  ``max_seq`` row of the decode cache, whether the request uses 9 tokens or
  all of them.  Capacity = ``batch_size`` requests of ``max_seq`` tokens.
- **paged** (``paged=True``) — global-attention K/V and MLA latents live in
  a shared pool of fixed-size token pages (``serving/kv_pages.py``)
  addressed through per-row page tables; pages are allocated on demand as
  sequences grow and refcounted so requests sharing a prompt prefix share
  its pages (prefix cache: suffix-only prefill).  Capacity is priced in
  *pages actually used*: admission reserves a request's worst-case page
  need and refuses with a structured ``QUEUE_SATURATED`` (+
  ``retry_after_s``) when the pool cannot hold it — the reservation is
  what guarantees mid-decode page allocation never fails.  Bounded
  per-row state (ring-buffer windows, recurrent/rwkv carries, cross K/V)
  stays slot-granular; archs with no pageable leaves degrade gracefully to
  the slot-granular path.

Per-request serving telemetry (TTFT, decode tokens/s) is stamped on the
:class:`Request` via the engine's injected :class:`~repro.core.simclock.Clock`
(``clock=`` ctor arg — the PR 8 simulator can drive serving on virtual
time); the control-plane adapter (``repro.substrates.lm_serving``) forwards
it to the ``TelemetryBus``.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Callable, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.errors import AdmissionRefused, ErrorCode
from repro.core.simclock import SYSTEM_CLOCK, Clock
from repro.models import (build_decode_step, build_decode_step_paged,
                          build_prefill_past_step, build_prefill_step,
                          decode_cache, decode_cache_paged, model_specs,
                          paged_cache_flags, paged_support)
from repro.models.common import init_params
from repro.serving.cache_utils import (extend_cache, gather_pages,
                                       write_prefill_paged, write_slots)
from repro.serving.kv_pages import PagePool, PrefixCache


@dataclasses.dataclass
class Request:
    request_id: str
    prompt: np.ndarray                  # (S,) int32
    max_new_tokens: int = 8
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    #: optional absolute deadline (engine-clock monotonic seconds); admission
    #: may refuse a request predicted to finish past it
    deadline_s: Optional[float] = None
    #: serving telemetry (engine-clock monotonic stamps, engine-filled)
    arrived_s: Optional[float] = None
    first_token_s: Optional[float] = None
    finished_s: Optional[float] = None
    #: True when the request finished after its deadline (admitted requests
    #: should never see this if admission predicts correctly)
    expired: bool = False
    #: pages reserved against the kv pool at admission (paged mode only;
    #: engine bookkeeping, not wire state)
    reserved_pages: int = 0

    @property
    def ttft_ms(self) -> Optional[float]:
        """Time to first token (arrival → first emitted token)."""
        if self.arrived_s is None or self.first_token_s is None:
            return None
        return (self.first_token_s - self.arrived_s) * 1e3

    @property
    def tokens_per_s(self) -> Optional[float]:
        """Decode throughput over the request's full residency."""
        if (self.arrived_s is None or self.finished_s is None
                or not self.generated):
            return None
        dur = self.finished_s - self.arrived_s
        return len(self.generated) / dur if dur > 0 else None


@dataclasses.dataclass
class _Slot:
    """One row of the shared decode batch."""

    index: int
    request: Optional[Request] = None
    pos: int = 0                        # next cache position this row writes
    token: int = 0                      # last emitted token (next decode input)
    #: page ids owned by this row, in block order (paged mode; includes
    #: shared prefix pages — every page holds one of the request's refs)
    pages: List[int] = dataclasses.field(default_factory=list)


class ServingEngine:
    """Serving engine over a reduced config (CPU) or pod mesh (TPU).

    ``generate`` (fixed-batch) and the continuous path (``submit`` /
    ``step`` / ``drain``) may be used on the same engine, but not
    concurrently with each other — they share the jitted steps and metrics.
    Continuous-path entry points are thread-safe; ``submit`` may be called
    from many threads while a driver thread runs ``step``.

    In paged mode ``max_seq`` is the per-request token cap (the page-table
    width); aggregate capacity is the page pool, not
    ``batch_size × max_seq`` — so a paged engine admits more concurrent
    short requests than it has contiguous rows for, and a single request
    may exceed what one slot-granular row could ever hold.
    """

    def __init__(self, cfg, params=None, *, batch_size: int = 2,
                 max_seq: int = 128, seed: int = 0, paged: bool = False,
                 page_size: int = 16, pool_pages: Optional[int] = None,
                 prefix_sharing: bool = True, clock: Optional[Clock] = None):
        self.cfg = cfg
        self.batch_size = batch_size
        self.max_seq = max_seq
        self.clock = clock if clock is not None else SYSTEM_CLOCK
        self.params = params if params is not None else init_params(
            model_specs(cfg), seed)
        self._prefill = jax.jit(build_prefill_step(cfg))
        self.paged = bool(paged)
        self.page_size = int(page_size)
        self.pool_pages = 0
        self._pool: Optional[PagePool] = None
        self._prefix: Optional[PrefixCache] = None
        self._tables: Optional[np.ndarray] = None
        if self.paged:
            any_paged, prefix_ok = paged_support(cfg)
            if any_paged:
                self.max_pages = -(-max_seq // self.page_size)
                self.pool_pages = (pool_pages if pool_pages is not None
                                   else batch_size * self.max_pages)
                self._flags = paged_cache_flags(cfg)
                self._pool = PagePool(self.pool_pages, self.page_size)
                self._tables = np.zeros((batch_size, self.max_pages),
                                        np.int32)
                self._tables_dev: Dict[int, object] = {}
                self._decode = jax.jit(
                    build_decode_step_paged(cfg, self.page_size),
                    donate_argnums=1)
                self._prime = jax.jit(self._prime_paged_fn, donate_argnums=2)
                if prefix_sharing and prefix_ok:
                    self._prefix = PrefixCache(self._pool)
                    self._prefill_past = build_prefill_past_step(cfg)
                    self._prime_past = jax.jit(self._prime_past_fn,
                                               donate_argnums=2)
            # archs with no pageable leaves (pure recurrent/ring stacks)
            # fall through to the slot-granular path below
        if self._pool is None:
            self._decode = jax.jit(build_decode_step(cfg), donate_argnums=1)
            self._prime = jax.jit(self._prime_fn, donate_argnums=2)
        # fixed-batch ``generate`` always decodes contiguously (it owns a
        # private cache and is the baseline the paged path is judged against)
        self._decode_dense = (self._decode if self._pool is None else
                              jax.jit(build_decode_step(cfg), donate_argnums=1))
        self.metrics: Dict[str, float] = {
            "prefill_ms": 0.0, "decode_ms": 0.0, "decode_steps": 0,
            "tokens": 0, "requests": 0, "deadline_expired": 0}
        # continuous-batching state
        self._slots = [_Slot(i) for i in range(batch_size)]
        self._waiting: Deque[Request] = collections.deque()
        self._cb_cache = None           # shared decode cache, built lazily
        self._lock = threading.RLock()
        self._work = threading.Condition(self._lock)
        #: called with each finished Request (adapter → telemetry/waiters)
        self.on_complete: Optional[Callable[[Request], None]] = None
        #: admission hook: called with (request, engine) before enqueue;
        #: raises AdmissionRefused to refuse (e.g. roofline deadline check)
        self.admission: Optional[Callable[[Request, "ServingEngine"], None]] = None
        #: observers feeding a cost model (ms per decode step / per prefill)
        self.on_step_ms: Optional[Callable[[float], None]] = None
        self.on_prefill_ms: Optional[Callable[[int, float], None]] = None

    def _batch_extras(self, B):
        extras = {}
        if self.cfg.family == "encdec":
            extras["frames"] = jnp.zeros(
                (B, self.cfg.encoder_frames, self.cfg.d_model),
                jnp.dtype(self.cfg.param_dtype))
        if self.cfg.family == "vision":
            extras["image_embeds"] = jnp.zeros(
                (B, self.cfg.num_image_tokens, self.cfg.d_model),
                jnp.dtype(self.cfg.param_dtype))
        return extras

    # -- validation -----------------------------------------------------------
    def _validate(self, r: Request) -> None:
        """Structured refusal instead of silent cache truncation."""
        n = len(r.prompt)
        if n == 0:
            raise AdmissionRefused(ErrorCode.BAD_REQUEST,
                                   f"{r.request_id}: empty prompt")
        if n > self.max_seq:
            raise AdmissionRefused(
                ErrorCode.BAD_REQUEST,
                f"{r.request_id}: prompt length {n} exceeds max_seq "
                f"{self.max_seq}")
        if r.max_new_tokens < 1:
            raise AdmissionRefused(
                ErrorCode.BAD_REQUEST,
                f"{r.request_id}: bad request: max_new_tokens "
                f"{r.max_new_tokens} < 1")
        if n + r.max_new_tokens > self.max_seq:
            raise AdmissionRefused(
                ErrorCode.BAD_REQUEST,
                f"{r.request_id}: kv cache overflow: prompt {n} + "
                f"max_new_tokens {r.max_new_tokens} exceeds max_seq "
                f"{self.max_seq}")

    def _emit(self, r: Request, tok: int) -> None:
        """Append one generated token; done flips at exactly max_new_tokens
        so the continuous loop can free the KV slot immediately."""
        r.generated.append(int(tok))
        if r.first_token_s is None:
            r.first_token_s = self.clock.monotonic()
        if len(r.generated) >= r.max_new_tokens:
            r.done = True
            r.finished_s = self.clock.monotonic()
            if r.deadline_s is not None and r.finished_s > r.deadline_s:
                r.expired = True
                self.metrics["deadline_expired"] += 1

    # -- fixed-batch baseline -------------------------------------------------
    def generate(self, requests: List[Request]) -> List[Request]:
        """Serve one group to completion (greedy decoding) — the fixed-batch
        run-to-completion baseline.  Prompts are left-padded to the group's
        longest; the batch decodes in lockstep until every member is done."""
        if not requests:
            return []
        if len(requests) > self.batch_size:
            raise AdmissionRefused(
                ErrorCode.BAD_REQUEST,
                f"bad request: group of {len(requests)} exceeds batch_size "
                f"{self.batch_size}")
        for r in requests:
            self._validate(r)
        B = self.batch_size
        S = max(len(r.prompt) for r in requests)
        max_new = max(r.max_new_tokens for r in requests)
        if S + max_new > self.max_seq:
            # padded group timeline: every member decodes from position S
            raise AdmissionRefused(
                ErrorCode.BAD_REQUEST,
                f"kv cache overflow: padded prompt {S} + max_new_tokens "
                f"{max_new} exceeds max_seq {self.max_seq}")
        now = self.clock.monotonic()
        for r in requests:
            if r.arrived_s is None:
                r.arrived_s = now
        prompts = np.zeros((B, S), np.int32)
        for i, r in enumerate(requests):
            prompts[i, S - len(r.prompt):] = r.prompt     # left-pad
        batch = {"tokens": jnp.asarray(prompts), **self._batch_extras(B)}

        t0 = time.perf_counter()
        prefill_cache, logits = self._prefill(self.params, batch)
        logits = jax.block_until_ready(logits)
        self.metrics["prefill_ms"] += (time.perf_counter() - t0) * 1e3

        # decode continues in a max_seq cache primed from the prefill cache
        cache = decode_cache(self.cfg, B, self.max_seq)
        cache = extend_cache(cache, prefill_cache, S)
        token = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        # the prefill already predicts each sequence's next token: emit it
        tok_np = np.asarray(token[:, 0])
        for i, r in enumerate(requests):
            self._emit(r, tok_np[i])
        self.metrics["tokens"] += len(requests)
        step = 0
        while any(not r.done for r in requests):
            pos = jnp.int32(S + step)
            t0 = time.perf_counter()
            cache, logits = self._decode_dense(self.params, cache, token, pos)
            logits = jax.block_until_ready(logits)
            self.metrics["decode_ms"] += (time.perf_counter() - t0) * 1e3
            self.metrics["decode_steps"] += 1
            token = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            tok_np = np.asarray(token[:, 0])
            emitted = 0
            for i, r in enumerate(requests):
                if not r.done:
                    self._emit(r, tok_np[i])
                    emitted += 1
            # only still-generating rows are billable work
            self.metrics["tokens"] += emitted
            step += 1
        self.metrics["requests"] += len(requests)
        return requests

    # -- continuous batching --------------------------------------------------
    def submit(self, r: Request) -> Request:
        """Validate, run admission, reserve kv pages, and enqueue.

        Raises :class:`AdmissionRefused`: ``BAD_REQUEST`` for malformed
        work, ``QUEUE_SATURATED`` (with ``retry_after_s``) when the page
        pool cannot hold the request's worst-case need, or whatever the
        admission hook raises (e.g. a roofline-predicted ``DEADLINE``) —
        all without touching engine state."""
        self._validate(r)
        if r.arrived_s is None:
            r.arrived_s = self.clock.monotonic()
        if self.admission is not None:
            self.admission(r, self)
        with self._work:
            if self._pool is not None:
                need = self._pages_needed(len(r.prompt) + r.max_new_tokens)
                if not self._pool.reserve(need):
                    raise AdmissionRefused(
                        ErrorCode.QUEUE_SATURATED,
                        f"{r.request_id}: queue saturated: kv page pool "
                        f"cannot hold {need} more pages "
                        f"({self._pool.reserved_pages}/{self._pool.num_pages}"
                        f" reserved)",
                        detail={"retry_after_s": self._retry_after_s(),
                                "needed_pages": need,
                                "pool_pages": self._pool.num_pages,
                                "pool_pages_used": self._pool.used_pages(),
                                "reserved_pages": self._pool.reserved_pages})
                r.reserved_pages = need
            self._waiting.append(r)
            self._work.notify_all()
        return r

    def _pages_needed(self, tokens: int) -> int:
        return -(-tokens // self.page_size)

    def _retry_after_s(self) -> float:
        """Back-off hint for a saturated pool: roughly one batch drain of
        the decode tokens currently owed, at the observed step rate."""
        steps = self.metrics["decode_steps"]
        step_s = (self.metrics["decode_ms"] / steps / 1e3) if steps else 0.05
        b = self.backlog()
        drain_steps = max(1.0, b["decode_tokens"] / max(1, self.batch_size))
        return round(max(0.05, drain_steps * step_s), 3)

    def backlog(self) -> Dict[str, int]:
        """Work owed to queued + in-flight requests, split by phase:
        ``decode_tokens`` (tokens still to generate) and ``prefill_tokens``
        (un-prefilled prompt tokens of waiting requests) — the admission
        model prices the two at different rates."""
        with self._lock:
            decode = sum(r.max_new_tokens for r in self._waiting)
            decode += sum(s.request.max_new_tokens - len(s.request.generated)
                          for s in self._slots if s.request is not None)
            prefill = sum(len(r.prompt) for r in self._waiting)
            return {"decode_tokens": decode, "prefill_tokens": prefill}

    def backlog_tokens(self) -> int:
        """Total tokens of owed work (decode + un-prefilled prompt)."""
        b = self.backlog()
        return b["decode_tokens"] + b["prefill_tokens"]

    def live_slots(self) -> int:
        with self._lock:
            return sum(1 for s in self._slots if s.request is not None)

    def cached_prefix_tokens(self, prompt) -> int:
        """Prompt tokens a submit would serve from the prefix cache (pure
        probe: no refs taken, no LRU touch — safe for admission pricing)."""
        if self._prefix is None:
            return 0
        with self._lock:
            return self._prefix.probe(np.asarray(prompt, np.int32),
                                      self.page_size)

    def pool_stats(self) -> Dict[str, float]:
        """Paged-capacity telemetry for the descriptor/snapshot (empty dict
        on slot-granular engines)."""
        if self._pool is None:
            return {}
        with self._lock:
            stats: Dict[str, float] = {
                "page_size": self.page_size,
                "pool_pages": self._pool.num_pages,
                "pool_pages_used": self._pool.used_pages(),
                "pool_pages_free": self._pool.free_pages(),
                "pool_utilization": round(self._pool.utilization(), 4),
            }
            if self._prefix is not None:
                stats["prefix_hit_rate"] = round(self._prefix.hit_rate(), 4)
                stats["prefix_cached_tokens"] = self._prefix.hit_tokens
            return stats

    def audit_pages(self) -> Dict[str, int]:
        """Leak audit of the page pool (consistency asserted inside)."""
        if self._pool is None:
            return {}
        with self._lock:
            return self._pool.audit()

    # -- slot-granular prime --------------------------------------------------
    def _prime_fn(self, params, batch, cb_cache, slot):
        """Fused admission kernel (jitted once per prompt length): B=1
        prefill → fit into a max_seq row → scatter into the shared decode
        cache at ``slot`` → argmax first token.  One dispatch per admission
        instead of a python-level tree walk per cache leaf (which costs
        more than several decode steps and would cap continuous-batching
        goodput on short-request traffic)."""
        S = batch["tokens"].shape[1]
        pcache, logits = self._prefill(params, batch)
        row = extend_cache(decode_cache(self.cfg, 1, self.max_seq),
                           pcache, S)
        cb = write_slots(cb_cache, row, slot)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return cb, tok

    # -- paged prime ----------------------------------------------------------
    def _prime_paged_fn(self, params, batch, cb_cache, pages, slot):
        """Fused paged admission kernel (jitted per prompt length): B=1
        prefill → scatter token blocks into pool pages (resident leaves
        into the batch row) → argmax first token."""
        S = batch["tokens"].shape[1]
        pcache, logits = self._prefill(params, batch)
        cb = write_prefill_paged(self._flags, cb_cache, pcache, pages, slot,
                                 S, self.page_size)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return cb, tok

    def _prime_past_fn(self, params, batch, cb_cache, pages, shared, slot):
        """Prefix-hit admission kernel (jitted per (suffix, prefix) length
        pair): gather the shared prefix pages into contiguous past K/V →
        suffix-only prefill against it → scatter the suffix blocks into the
        request's private pages."""
        S = batch["tokens"].shape[1]
        past = gather_pages(self._flags, cb_cache, shared)
        pcache, logits = self._prefill_past(params, batch, past)
        cb = write_prefill_paged(self._flags, cb_cache, pcache, pages, slot,
                                 S, self.page_size)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return cb, tok

    def _alloc_pages(self, n: int) -> List[int]:
        """Allocate for already-reserved work, evicting cache-only prefix
        pages as needed.  Conservative reservations guarantee success: live
        usage never exceeds the reserved total, and everything else in the
        pool is an evictable cache reference."""
        if n == 0:
            return []
        while (self._pool.free_pages() < n and self._prefix is not None
               and self._prefix.evict_one()):
            pass
        return self._pool.alloc(n)

    def _prime_slot(self, slot: _Slot, r: Request) -> None:
        """B=1 prefill at the prompt's natural length, scattered into the
        slot's row (slot-granular) or the request's pages (paged)."""
        S = len(r.prompt)
        prompt = np.asarray(r.prompt, np.int32)
        if self._cb_cache is None:
            self._cb_cache = (
                decode_cache_paged(self.cfg, self.batch_size, self.max_seq,
                                   self.pool_pages, self.page_size)
                if self._pool is not None
                else decode_cache(self.cfg, self.batch_size, self.max_seq))
        slot_arr = jnp.asarray([slot.index], jnp.int32)
        t0 = time.perf_counter()
        if self._pool is not None:
            shared: List[int] = []
            if self._prefix is not None:
                _, shared = self._prefix.lookup(prompt, self.page_size)
            prefix_tokens = len(shared) * self.page_size
            fresh = self._alloc_pages(self._pages_needed(S) - len(shared))
            slot.pages = list(shared) + fresh
            self._tables[slot.index, :] = 0
            self._tables[slot.index, :len(slot.pages)] = slot.pages
            self._tables_dev.clear()
            suffix = prompt[prefix_tokens:]
            batch = {"tokens": jnp.asarray(suffix[None, :]),
                     **self._batch_extras(1)}
            if shared:
                self._cb_cache, tok = self._prime_past(
                    self.params, batch, self._cb_cache,
                    jnp.asarray(fresh, jnp.int32),
                    jnp.asarray(shared, jnp.int32), slot_arr)
            else:
                self._cb_cache, tok = self._prime(
                    self.params, batch, self._cb_cache,
                    jnp.asarray(fresh, jnp.int32), slot_arr)
            if self._prefix is not None:
                # register this prompt's full blocks for future sharers
                self._prefix.insert(prompt, slot.pages, self.page_size)
            pf_tokens = len(suffix)
        else:
            batch = {"tokens": jnp.asarray(prompt[None, :]),
                     **self._batch_extras(1)}
            self._cb_cache, tok = self._prime(
                self.params, batch, self._cb_cache, slot_arr)
            pf_tokens = S
        tok = int(np.asarray(jax.block_until_ready(tok))[0])
        ms = (time.perf_counter() - t0) * 1e3
        self.metrics["prefill_ms"] += ms
        if self.on_prefill_ms is not None:
            self.on_prefill_ms(pf_tokens, ms)
        slot.request, slot.pos, slot.token = r, S, tok
        self._emit(r, tok)
        self.metrics["tokens"] += 1
        if r.done:                       # max_new_tokens == 1
            self._finish(slot)

    def _finish(self, slot: _Slot) -> None:
        r = slot.request
        if self._pool is not None:
            for pid in slot.pages:
                self._pool.decref(pid)
            slot.pages = []
            self._pool.unreserve(r.reserved_pages)
            r.reserved_pages = 0
            self._tables[slot.index, :] = 0
            self._tables_dev.clear()
        slot.request, slot.pos, slot.token = None, 0, 0
        self.metrics["requests"] += 1
        if self.on_complete is not None:
            self.on_complete(r)

    def _admit_locked(self) -> None:
        for slot in self._slots:
            if slot.request is None and self._waiting:
                self._prime_slot(slot, self._waiting.popleft())

    def step(self) -> int:
        """Advance the shared decode batch one token.  Freed slots are
        re-primed from the waiting queue first, so sequences join and leave
        the batch every step.  Returns the number of live tokens emitted
        (0 = engine idle)."""
        with self._lock:
            self._admit_locked()
            live = [s for s in self._slots if s.request is not None]
            if not live:
                return 0
            tokens = np.zeros((self.batch_size, 1), np.int32)
            posv = np.zeros((self.batch_size,), np.int32)
            for s in self._slots:
                tokens[s.index, 0] = s.token
                posv[s.index] = s.pos
            width = 0
            if self._pool is not None:
                for s in live:
                    blk = s.pos // self.page_size
                    if blk >= len(s.pages):
                        # on-demand growth: this step's write position
                        # crossed into a new block; the admission-time
                        # reservation guarantees the allocation succeeds
                        s.pages.extend(self._alloc_pages(1))
                        self._tables[s.index, blk] = s.pages[-1]
                        self._tables_dev.clear()
                    width = max(width, len(s.pages))
                # attend only over live pages: the table passed to the
                # kernel is cropped to the widest live row, so short
                # requests read 1-2 pages instead of a full max_seq-shaped
                # row — the paged layout's bandwidth win.  Exact widths
                # compile at most max_pages decode variants; wide tables
                # bucket to powers of two to bound compile count.
                if self.max_pages > 16:
                    width = 1 << (width - 1).bit_length()
                width = min(width, self.max_pages)
            if self._pool is not None:
                # tables change only on admission/growth/finish; steps in
                # between reuse the uploaded device copy per width
                tables = self._tables_dev.get(width)
                if tables is None:
                    tables = jnp.asarray(self._tables[:, :width])
                    self._tables_dev[width] = tables
            t0 = time.perf_counter()
            if self._pool is not None:
                self._cb_cache, logits = self._decode(
                    self.params, self._cb_cache, jnp.asarray(tokens),
                    jnp.asarray(posv), tables)
            else:
                self._cb_cache, logits = self._decode(
                    self.params, self._cb_cache, jnp.asarray(tokens),
                    jnp.asarray(posv))
            logits = jax.block_until_ready(logits)
            ms = (time.perf_counter() - t0) * 1e3
            self.metrics["decode_ms"] += ms
            self.metrics["decode_steps"] += 1
            if self.on_step_ms is not None:
                self.on_step_ms(ms)
            tok = np.asarray(jnp.argmax(logits, axis=-1).astype(jnp.int32))
            for s in live:
                self._emit(s.request, int(tok[s.index]))
                s.token = int(tok[s.index])
                s.pos += 1
                if s.request.done:
                    self._finish(s)
            self.metrics["tokens"] += len(live)
            return len(live)

    def drain(self) -> None:
        """Run ``step`` until the queue and every slot are empty."""
        while True:
            with self._lock:
                busy = bool(self._waiting) or any(
                    s.request is not None for s in self._slots)
            if not busy:
                return
            self.step()

    def flush(self) -> None:
        """Drop all queued and in-flight work: release every reservation
        and page, clear the prefix cache, reset the decode cache.  For the
        lifecycle manager's ``flush_queue`` reset — callers guarantee no
        invoker is waiting on the flushed requests."""
        with self._work:
            if self._pool is not None:
                for r in self._waiting:
                    self._pool.unreserve(r.reserved_pages)
                    r.reserved_pages = 0
            self._waiting.clear()
            for s in self._slots:
                if s.request is not None and self._pool is not None:
                    for pid in s.pages:
                        self._pool.decref(pid)
                    self._pool.unreserve(s.request.reserved_pages)
                    s.request.reserved_pages = 0
                s.request, s.pos, s.token = None, 0, 0
                s.pages = []
            if self._prefix is not None:
                self._prefix.flush()
            if self._tables is not None:
                self._tables[:] = 0
                self._tables_dev.clear()
            self._cb_cache = None
            self._work.notify_all()

    def wake(self) -> None:
        """Nudge a parked ``serve_forever`` driver (call after setting its
        stop event — the idle park is unbounded, not a poll)."""
        with self._work:
            self._work.notify_all()

    def serve_forever(self, stop: threading.Event,
                      idle_wait_s: Optional[float] = None) -> None:
        """Driver loop for a serving thread: step while there is work, park
        on the condition variable while idle (``submit`` wakes it; pair
        ``stop.set()`` with :meth:`wake` so the parked driver observes the
        stop immediately instead of after a poll interval)."""
        def has_work() -> bool:
            return (stop.is_set() or bool(self._waiting)
                    or any(s.request is not None for s in self._slots))

        while not stop.is_set():
            if self.step() == 0:
                with self._work:
                    self.clock.wait_for(self._work, has_work,
                                        timeout=idle_wait_s)
