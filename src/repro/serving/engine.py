"""LM serving engine: prefill + decode over the KV cache substrate.

Two serving modes share the same jitted ``build_prefill_step`` /
``build_decode_step`` functions the dry-run lowers for the 512-chip mesh,
so what serves on one CPU device here is exactly what compiles for the pod:

- :meth:`ServingEngine.generate` — fixed-batch run-to-completion: one group
  is left-padded to a common length, prefilled together, and decoded until
  every member is done.  This is the measurable baseline continuous
  batching is judged against.
- continuous batching — :meth:`submit` puts a request on the waiting queue;
  :meth:`step` advances the shared decode batch one token.  Each batch slot
  owns an independent timeline: a freed slot is re-primed from a fresh B=1
  prefill (``cache_utils.write_slots`` scatters the prefilled rows into the
  shared decode cache) and the per-row position vector keeps every other
  sequence exact.  Requests join and leave the batch every step, which is
  what turns mixed-length traffic from head-of-line blocking into goodput.

Per-request serving telemetry (TTFT, decode tokens/s) is stamped on the
:class:`Request`; the control-plane adapter
(``repro.substrates.lm_serving``) forwards it to the ``TelemetryBus``.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Callable, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.errors import AdmissionRefused, ErrorCode
from repro.models import (build_decode_step, build_prefill_step, decode_cache,
                          model_specs)
from repro.models.common import init_params
from repro.serving.cache_utils import extend_cache, write_slots


@dataclasses.dataclass
class Request:
    request_id: str
    prompt: np.ndarray                  # (S,) int32
    max_new_tokens: int = 8
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    #: optional absolute deadline (``time.monotonic`` seconds); admission may
    #: refuse a request predicted to finish past it
    deadline_s: Optional[float] = None
    #: serving telemetry (``time.monotonic`` stamps, engine-filled)
    arrived_s: Optional[float] = None
    first_token_s: Optional[float] = None
    finished_s: Optional[float] = None
    #: True when the request finished after its deadline (admitted requests
    #: should never see this if admission predicts correctly)
    expired: bool = False

    @property
    def ttft_ms(self) -> Optional[float]:
        """Time to first token (arrival → first emitted token)."""
        if self.arrived_s is None or self.first_token_s is None:
            return None
        return (self.first_token_s - self.arrived_s) * 1e3

    @property
    def tokens_per_s(self) -> Optional[float]:
        """Decode throughput over the request's full residency."""
        if (self.arrived_s is None or self.finished_s is None
                or not self.generated):
            return None
        dur = self.finished_s - self.arrived_s
        return len(self.generated) / dur if dur > 0 else None


@dataclasses.dataclass
class _Slot:
    """One row of the shared decode batch."""

    index: int
    request: Optional[Request] = None
    pos: int = 0                        # next cache position this row writes
    token: int = 0                      # last emitted token (next decode input)


class ServingEngine:
    """Serving engine over a reduced config (CPU) or pod mesh (TPU).

    ``generate`` (fixed-batch) and the continuous path (``submit`` /
    ``step`` / ``drain``) may be used on the same engine, but not
    concurrently with each other — they share the jitted steps and metrics.
    Continuous-path entry points are thread-safe; ``submit`` may be called
    from many threads while a driver thread runs ``step``.
    """

    def __init__(self, cfg, params=None, *, batch_size: int = 2,
                 max_seq: int = 128, seed: int = 0):
        self.cfg = cfg
        self.batch_size = batch_size
        self.max_seq = max_seq
        self.params = params if params is not None else init_params(
            model_specs(cfg), seed)
        self._prefill = jax.jit(build_prefill_step(cfg))
        self._decode = jax.jit(build_decode_step(cfg), donate_argnums=1)
        self._prime = jax.jit(self._prime_fn, donate_argnums=2)
        self.metrics: Dict[str, float] = {
            "prefill_ms": 0.0, "decode_ms": 0.0, "decode_steps": 0,
            "tokens": 0, "requests": 0, "deadline_expired": 0}
        # continuous-batching state
        self._slots = [_Slot(i) for i in range(batch_size)]
        self._waiting: Deque[Request] = collections.deque()
        self._cb_cache = None           # shared decode cache, built lazily
        self._lock = threading.RLock()
        self._work = threading.Condition(self._lock)
        #: called with each finished Request (adapter → telemetry/waiters)
        self.on_complete: Optional[Callable[[Request], None]] = None
        #: admission hook: called with (request, engine) before enqueue;
        #: raises AdmissionRefused to refuse (e.g. roofline deadline check)
        self.admission: Optional[Callable[[Request, "ServingEngine"], None]] = None
        #: observers feeding a cost model (ms per decode step / per prefill)
        self.on_step_ms: Optional[Callable[[float], None]] = None
        self.on_prefill_ms: Optional[Callable[[int, float], None]] = None

    def _batch_extras(self, B):
        extras = {}
        if self.cfg.family == "encdec":
            extras["frames"] = jnp.zeros(
                (B, self.cfg.encoder_frames, self.cfg.d_model),
                jnp.dtype(self.cfg.param_dtype))
        if self.cfg.family == "vision":
            extras["image_embeds"] = jnp.zeros(
                (B, self.cfg.num_image_tokens, self.cfg.d_model),
                jnp.dtype(self.cfg.param_dtype))
        return extras

    # -- validation -----------------------------------------------------------
    def _validate(self, r: Request) -> None:
        """Structured refusal instead of silent cache truncation."""
        n = len(r.prompt)
        if n == 0:
            raise AdmissionRefused(ErrorCode.BAD_REQUEST,
                                   f"{r.request_id}: empty prompt")
        if n > self.max_seq:
            raise AdmissionRefused(
                ErrorCode.BAD_REQUEST,
                f"{r.request_id}: prompt length {n} exceeds max_seq "
                f"{self.max_seq}")
        if r.max_new_tokens < 1:
            raise AdmissionRefused(
                ErrorCode.BAD_REQUEST,
                f"{r.request_id}: bad request: max_new_tokens "
                f"{r.max_new_tokens} < 1")
        if n + r.max_new_tokens > self.max_seq:
            raise AdmissionRefused(
                ErrorCode.BAD_REQUEST,
                f"{r.request_id}: kv cache overflow: prompt {n} + "
                f"max_new_tokens {r.max_new_tokens} exceeds max_seq "
                f"{self.max_seq}")

    def _emit(self, r: Request, tok: int) -> None:
        """Append one generated token; done flips at exactly max_new_tokens
        so the continuous loop can free the KV slot immediately."""
        r.generated.append(int(tok))
        if r.first_token_s is None:
            r.first_token_s = time.monotonic()  # planelint: allow(clock-seam) — serving-engine timebase (ROADMAP: virtualize)
        if len(r.generated) >= r.max_new_tokens:
            r.done = True
            r.finished_s = time.monotonic()  # planelint: allow(clock-seam) — serving-engine timebase (ROADMAP: virtualize)
            if r.deadline_s is not None and r.finished_s > r.deadline_s:
                r.expired = True
                self.metrics["deadline_expired"] += 1

    # -- fixed-batch baseline -------------------------------------------------
    def generate(self, requests: List[Request]) -> List[Request]:
        """Serve one group to completion (greedy decoding) — the fixed-batch
        run-to-completion baseline.  Prompts are left-padded to the group's
        longest; the batch decodes in lockstep until every member is done."""
        if not requests:
            return []
        if len(requests) > self.batch_size:
            raise AdmissionRefused(
                ErrorCode.BAD_REQUEST,
                f"bad request: group of {len(requests)} exceeds batch_size "
                f"{self.batch_size}")
        for r in requests:
            self._validate(r)
        B = self.batch_size
        S = max(len(r.prompt) for r in requests)
        max_new = max(r.max_new_tokens for r in requests)
        if S + max_new > self.max_seq:
            # padded group timeline: every member decodes from position S
            raise AdmissionRefused(
                ErrorCode.BAD_REQUEST,
                f"kv cache overflow: padded prompt {S} + max_new_tokens "
                f"{max_new} exceeds max_seq {self.max_seq}")
        now = time.monotonic()  # planelint: allow(clock-seam) — serving-engine timebase (ROADMAP: virtualize)
        for r in requests:
            if r.arrived_s is None:
                r.arrived_s = now
        prompts = np.zeros((B, S), np.int32)
        for i, r in enumerate(requests):
            prompts[i, S - len(r.prompt):] = r.prompt     # left-pad
        batch = {"tokens": jnp.asarray(prompts), **self._batch_extras(B)}

        t0 = time.perf_counter()
        prefill_cache, logits = self._prefill(self.params, batch)
        logits = jax.block_until_ready(logits)
        self.metrics["prefill_ms"] += (time.perf_counter() - t0) * 1e3

        # decode continues in a max_seq cache primed from the prefill cache
        cache = decode_cache(self.cfg, B, self.max_seq)
        cache = extend_cache(cache, prefill_cache, S)
        token = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        # the prefill already predicts each sequence's next token: emit it
        tok_np = np.asarray(token[:, 0])
        for i, r in enumerate(requests):
            self._emit(r, tok_np[i])
        self.metrics["tokens"] += len(requests)
        step = 0
        while any(not r.done for r in requests):
            pos = jnp.int32(S + step)
            t0 = time.perf_counter()
            cache, logits = self._decode(self.params, cache, token, pos)
            logits = jax.block_until_ready(logits)
            self.metrics["decode_ms"] += (time.perf_counter() - t0) * 1e3
            self.metrics["decode_steps"] += 1
            token = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            tok_np = np.asarray(token[:, 0])
            emitted = 0
            for i, r in enumerate(requests):
                if not r.done:
                    self._emit(r, tok_np[i])
                    emitted += 1
            # only still-generating rows are billable work
            self.metrics["tokens"] += emitted
            step += 1
        self.metrics["requests"] += len(requests)
        return requests

    # -- continuous batching --------------------------------------------------
    def submit(self, r: Request) -> Request:
        """Validate, run admission, and enqueue one request.

        Raises :class:`AdmissionRefused` (BAD_REQUEST for malformed work,
        or whatever the admission hook raises — e.g. a roofline-predicted
        DEADLINE) without touching engine state."""
        self._validate(r)
        if r.arrived_s is None:
            r.arrived_s = time.monotonic()  # planelint: allow(clock-seam) — serving-engine timebase (ROADMAP: virtualize)
        if self.admission is not None:
            self.admission(r, self)
        with self._work:
            self._waiting.append(r)
            self._work.notify_all()
        return r

    def backlog_tokens(self) -> int:
        """Tokens still owed to queued + in-flight requests (the quantity a
        predictive admission model prices a new arrival against)."""
        with self._lock:
            owed = sum(r.max_new_tokens for r in self._waiting)
            owed += sum(s.request.max_new_tokens - len(s.request.generated)
                        for s in self._slots if s.request is not None)
            return owed

    def live_slots(self) -> int:
        with self._lock:
            return sum(1 for s in self._slots if s.request is not None)

    def _prime_fn(self, params, batch, cb_cache, slot):
        """Fused admission kernel (jitted once per prompt length): B=1
        prefill → fit into a max_seq row → scatter into the shared decode
        cache at ``slot`` → argmax first token.  One dispatch per admission
        instead of a python-level tree walk per cache leaf (which costs
        more than several decode steps and would cap continuous-batching
        goodput on short-request traffic)."""
        S = batch["tokens"].shape[1]
        pcache, logits = self._prefill(params, batch)
        row = extend_cache(decode_cache(self.cfg, 1, self.max_seq),
                           pcache, S)
        cb = write_slots(cb_cache, row, slot)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return cb, tok

    def _prime_slot(self, slot: _Slot, r: Request) -> None:
        """B=1 prefill at the prompt's natural length, scattered into the
        slot's row of the shared decode cache."""
        S = len(r.prompt)
        tokens = jnp.asarray(np.asarray(r.prompt, np.int32)[None, :])
        batch = {"tokens": tokens, **self._batch_extras(1)}
        if self._cb_cache is None:
            self._cb_cache = decode_cache(self.cfg, self.batch_size,
                                          self.max_seq)
        t0 = time.perf_counter()
        self._cb_cache, tok = self._prime(
            self.params, batch, self._cb_cache,
            jnp.asarray([slot.index], jnp.int32))
        tok = int(np.asarray(jax.block_until_ready(tok))[0])
        ms = (time.perf_counter() - t0) * 1e3
        self.metrics["prefill_ms"] += ms
        if self.on_prefill_ms is not None:
            self.on_prefill_ms(S, ms)
        slot.request, slot.pos, slot.token = r, S, tok
        self._emit(r, tok)
        self.metrics["tokens"] += 1
        if r.done:                       # max_new_tokens == 1
            self._finish(slot)

    def _finish(self, slot: _Slot) -> None:
        r = slot.request
        slot.request, slot.pos, slot.token = None, 0, 0
        self.metrics["requests"] += 1
        if self.on_complete is not None:
            self.on_complete(r)

    def _admit_locked(self) -> None:
        for slot in self._slots:
            if slot.request is None and self._waiting:
                self._prime_slot(slot, self._waiting.popleft())

    def step(self) -> int:
        """Advance the shared decode batch one token.  Freed slots are
        re-primed from the waiting queue first, so sequences join and leave
        the batch every step.  Returns the number of live tokens emitted
        (0 = engine idle)."""
        with self._lock:
            self._admit_locked()
            live = [s for s in self._slots if s.request is not None]
            if not live:
                return 0
            tokens = np.zeros((self.batch_size, 1), np.int32)
            posv = np.zeros((self.batch_size,), np.int32)
            for s in self._slots:
                tokens[s.index, 0] = s.token
                posv[s.index] = s.pos
            t0 = time.perf_counter()
            self._cb_cache, logits = self._decode(
                self.params, self._cb_cache, jnp.asarray(tokens),
                jnp.asarray(posv))
            logits = jax.block_until_ready(logits)
            ms = (time.perf_counter() - t0) * 1e3
            self.metrics["decode_ms"] += ms
            self.metrics["decode_steps"] += 1
            if self.on_step_ms is not None:
                self.on_step_ms(ms)
            tok = np.asarray(jnp.argmax(logits, axis=-1).astype(jnp.int32))
            for s in live:
                self._emit(s.request, int(tok[s.index]))
                s.token = int(tok[s.index])
                s.pos += 1
                if s.request.done:
                    self._finish(s)
            self.metrics["tokens"] += len(live)
            return len(live)

    def drain(self) -> None:
        """Run ``step`` until the queue and every slot are empty."""
        while True:
            with self._lock:
                busy = bool(self._waiting) or any(
                    s.request is not None for s in self._slots)
            if not busy:
                return
            self.step()

    def serve_forever(self, stop: threading.Event,
                      idle_wait_s: float = 0.05) -> None:
        """Driver loop for a serving thread: step while there is work, park
        on the condition variable while idle (``submit`` wakes it)."""
        while not stop.is_set():
            if self.step() == 0:
                with self._work:
                    if not self._waiting and not any(
                            s.request is not None for s in self._slots):
                        self._work.wait(timeout=idle_wait_s)
