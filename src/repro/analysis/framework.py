"""planelint framework — pluggable AST checkers for control-plane invariants.

The control plane encodes several correctness conventions that nothing in
the type system enforces: the injected-``Clock`` seam (PR 8), lock ordering
across ~30 locks, the structured ``ErrorCode`` taxonomy (PR 4), and the
append-only binary intern table (PR 6). ``planelint`` turns those
conventions into machine-checked rules.

Suppression pragmas (checked per rule name):

* ``# planelint: allow(rule[, rule2])`` — trailing a line suppresses that
  line; on a comment-only line it suppresses the next line.
* ``# planelint: allow-file(rule)`` — anywhere in a file suppresses the
  rule for the whole file.
* ``# planelint: holds(_lock)`` — trailing a ``def`` line, declares a
  caller-holds-lock contract trusted by the guarded-by checker.

Field-guard annotations use ``# guarded_by: _lock`` trailing the
assignment that introduces the field (see the guarded-by checker).
"""

from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set

_ALLOW_RE = re.compile(r"#\s*planelint:\s*allow\(([^)]*)\)")
_ALLOW_FILE_RE = re.compile(r"#\s*planelint:\s*allow-file\(([^)]*)\)")
_HOLDS_RE = re.compile(r"#\s*planelint:\s*holds\(([^)]*)\)")
_GUARDED_RE = re.compile(r"#\s*guarded_by:\s*([A-Za-z_][A-Za-z0-9_]*)")

SEVERITY_ERROR = "error"
SEVERITY_WARN = "warn"


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation, anchored to a file and line."""

    rule: str
    path: str  # repo-relative, e.g. "src/repro/core/telemetry.py"
    line: int
    message: str
    hint: str = ""
    severity: str = SEVERITY_ERROR

    def format(self) -> str:
        txt = f"{self.path}:{self.line}: [{self.rule}] {self.message}"
        if self.hint:
            txt += f"\n    hint: {self.hint}"
        return txt

    def to_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


def _split_rules(raw: str) -> Set[str]:
    return {part.strip() for part in raw.split(",") if part.strip()}


class SourceFile:
    """A parsed source file plus its planelint pragma tables."""

    def __init__(self, root: Path, path: Path) -> None:
        self.path = path
        self.rel = path.relative_to(root).as_posix()
        # Module path relative to the package root, used for checker scoping
        # ("core/telemetry.py" rather than "src/repro/core/telemetry.py").
        parts = Path(self.rel).parts
        if parts[:2] == ("src", "repro"):
            self.mod = "/".join(parts[2:])
        else:
            self.mod = self.rel
        self.text = path.read_text(encoding="utf-8")
        self.lines = self.text.splitlines()
        self.tree = ast.parse(self.text, filename=str(path))
        self.allow: Dict[int, Set[str]] = {}
        self.allow_file: Set[str] = set()
        self.holds: Dict[int, Set[str]] = {}
        self.guarded: Dict[int, str] = {}
        self._scan_pragmas()

    def _scan_pragmas(self) -> None:
        for lineno, line in enumerate(self.lines, start=1):
            m = _ALLOW_FILE_RE.search(line)
            if m:
                self.allow_file |= _split_rules(m.group(1))
            m = _ALLOW_RE.search(line)
            if m:
                target = lineno + 1 if line.lstrip().startswith("#") else lineno
                self.allow.setdefault(target, set()).update(_split_rules(m.group(1)))
            m = _HOLDS_RE.search(line)
            if m:
                self.holds.setdefault(lineno, set()).update(_split_rules(m.group(1)))
            m = _GUARDED_RE.search(line)
            if m:
                self.guarded[lineno] = m.group(1)

    def allows(self, rule: str, line: int) -> bool:
        return rule in self.allow_file or rule in self.allow.get(line, ())

    def holds_locks(self, def_line: int) -> Set[str]:
        return self.holds.get(def_line, set())


class Project:
    """All analyzed source files, keyed by repo-relative path."""

    def __init__(self, root: Path, files: Sequence[SourceFile]) -> None:
        self.root = root
        self.files: Dict[str, SourceFile] = {sf.rel: sf for sf in files}
        self.by_mod: Dict[str, SourceFile] = {sf.mod: sf for sf in files}

    def iter_files(self, prefixes: Optional[Sequence[str]] = None) -> Iterable[SourceFile]:
        for sf in sorted(self.files.values(), key=lambda s: s.rel):
            if prefixes is None or any(sf.mod.startswith(p) for p in prefixes):
                yield sf

    def file_by_mod(self, mod: str) -> Optional[SourceFile]:
        return self.by_mod.get(mod)


def load_project(root: Path, rel_paths: Optional[Sequence[str]] = None) -> Project:
    """Load ``src/repro`` (or an explicit file list) into a ``Project``."""

    root = root.resolve()
    if rel_paths is None:
        paths = sorted((root / "src" / "repro").rglob("*.py"))
    else:
        paths = [root / rel for rel in rel_paths]
    files = []
    for path in paths:
        if "__pycache__" in path.parts:
            continue
        files.append(SourceFile(root, path))
    return Project(root, files)


class Checker:
    """Base class: one named rule producing findings over a project."""

    name: str = ""
    description: str = ""

    def check(self, project: Project) -> List[Finding]:
        raise NotImplementedError

    def update_goldens(self, project: Project) -> Optional[str]:
        """Rewrite any golden file this checker owns; return its path."""

        return None


def apply_pragmas(project: Project, findings: Sequence[Finding]) -> tuple[List[Finding], int]:
    """Drop findings suppressed by allow pragmas; return (kept, n_suppressed)."""

    kept: List[Finding] = []
    suppressed = 0
    for f in findings:
        sf = project.files.get(f.path)
        if sf is not None and sf.allows(f.rule, f.line):
            suppressed += 1
        else:
            kept.append(f)
    return kept, suppressed


def run_checkers(
    project: Project,
    checkers: Sequence[Checker],
) -> tuple[List[Finding], int]:
    all_findings: List[Finding] = []
    suppressed_total = 0
    for checker in checkers:
        found = checker.check(project)
        kept, suppressed = apply_pragmas(project, found)
        all_findings.extend(kept)
        suppressed_total += suppressed
    all_findings.sort(key=lambda f: (f.path, f.line, f.rule, f.message))
    return all_findings, suppressed_total
