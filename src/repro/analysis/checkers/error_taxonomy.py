"""error-taxonomy checker: every rejection carries a structured ErrorCode.

PR 4 introduced ``core/errors.py``: a closed ``ErrorCode`` enum, typed
``ControlPlaneError``/``AdmissionRefused`` exceptions, and
``classify_rejection`` — a needle table (``_CLASSIFIERS``) that maps legacy
free-text reasons onto codes so old reason strings keep classifying.  This
checker keeps the funnel tight in the modules a client can actually reach
(orchestrator, scheduler, invocation, gateway, remote/serving substrates):

* R1: typed error constructors (``ControlPlaneError``, ``AdmissionRefused``,
  ``WireError``) must get an ``ErrorCode``, not a bare string, as the code;
* R2: ``InvocationResult(status="rejected", ...)`` may only be built inside
  ``core/invocation.py`` — everyone else goes through
  ``InvocationManager.rejected`` so telemetry always carries ``error_code``;
* R3: a ``rejected(...)``/``_reject_or_twin(...)`` call with a fully
  literal reason must either pass ``code=`` or use a reason that one of the
  ``_CLASSIFIERS`` needles can classify (otherwise it lands on the
  catch-all INTERNAL and the breaker/taxonomy telemetry goes blind).
  Non-literal reasons are skipped — the classifier handles them at runtime.
"""

from __future__ import annotations

import ast
from typing import List, Optional, Set

from ..framework import Checker, Finding, Project, SourceFile

SCOPE_MODULES = (
    "core/orchestrator.py",
    "core/scheduler.py",
    "core/invocation.py",
    "substrates/remote_plane.py",
    "substrates/lm_serving.py",
)
SCOPE_PREFIXES = ("gateway/",)

TYPED_ERROR_CTORS = {"ControlPlaneError", "AdmissionRefused", "WireError"}
REJECT_FUNNELS = {"rejected": 1, "_reject_or_twin": 2}  # name → reason arg index


def _in_scope(sf: SourceFile) -> bool:
    return sf.mod in SCOPE_MODULES or any(
        sf.mod.startswith(p) for p in SCOPE_PREFIXES
    )


def load_needles(project: Project) -> Set[str]:
    """Extract the _CLASSIFIERS needle strings from core/errors.py."""

    sf = project.file_by_mod("core/errors.py")
    needles: Set[str] = set()
    if sf is None:
        return needles
    for node in sf.tree.body:
        if (
            isinstance(node, ast.Assign)
            and len(node.targets) == 1
            and isinstance(node.targets[0], ast.Name)
            and node.targets[0].id == "_CLASSIFIERS"
        ):
            for sub in ast.walk(node.value):
                if isinstance(sub, ast.Constant) and isinstance(sub.value, str):
                    needles.add(sub.value.lower())
    return needles


def _literal_str(node: ast.expr) -> Optional[str]:
    """The compile-time value of a fully literal string expression, else None."""

    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    if isinstance(node, ast.JoinedStr):
        parts = []
        for v in node.values:
            if isinstance(v, ast.Constant) and isinstance(v.value, str):
                parts.append(v.value)
            elif isinstance(v, ast.FormattedValue):
                return None  # runtime content could add a needle; skip
        return "".join(parts)
    if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Add):
        left = _literal_str(node.left)
        right = _literal_str(node.right)
        if left is not None and right is not None:
            return left + right
    return None


def _passes_code(node: ast.Call) -> bool:
    for kw in node.keywords:
        if kw.arg == "code" and not (
            isinstance(kw.value, ast.Constant) and kw.value.value is None
        ):
            return True
    return False


class ErrorTaxonomyChecker(Checker):
    name = "error-taxonomy"
    description = "rejections reachable from orchestrator/scheduler/gateway carry ErrorCodes"

    def check(self, project: Project) -> List[Finding]:
        needles = load_needles(project)
        findings: List[Finding] = []
        for sf in project.iter_files():
            if not _in_scope(sf):
                continue
            findings.extend(self._check_file(sf, needles))
        return findings

    def _check_file(self, sf: SourceFile, needles: Set[str]) -> List[Finding]:
        findings: List[Finding] = []
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            fname = None
            if isinstance(node.func, ast.Name):
                fname = node.func.id
            elif isinstance(node.func, ast.Attribute):
                fname = node.func.attr

            # R1: typed error constructors want an ErrorCode first.
            if fname in TYPED_ERROR_CTORS:
                code_arg: Optional[ast.expr] = node.args[0] if node.args else None
                for kw in node.keywords:
                    if kw.arg == "code":
                        code_arg = kw.value
                if isinstance(code_arg, ast.Constant) and isinstance(
                    code_arg.value, str
                ):
                    findings.append(
                        Finding(
                            rule=self.name,
                            path=sf.rel,
                            line=node.lineno,
                            message=(
                                f"{fname}(...) built with a bare string code "
                                f"{code_arg.value!r} instead of an ErrorCode"
                            ),
                            hint="pass ErrorCode.<MEMBER> (core/errors.py)",
                        )
                    )

            # R2: rejected results are minted only by InvocationManager.
            if (
                fname == "InvocationResult"
                and sf.mod != "core/invocation.py"
            ):
                for kw in node.keywords:
                    if (
                        kw.arg == "status"
                        and isinstance(kw.value, ast.Constant)
                        and kw.value.value == "rejected"
                    ):
                        findings.append(
                            Finding(
                                rule=self.name,
                                path=sf.rel,
                                line=node.lineno,
                                message=(
                                    "InvocationResult(status='rejected') built outside "
                                    "core/invocation.py bypasses the error_code funnel"
                                ),
                                hint="use InvocationManager.rejected(task, reason, code=...)",
                            )
                        )

            # R3: literal reasons through the funnels must classify.
            if fname in REJECT_FUNNELS and isinstance(node.func, ast.Attribute):
                if _passes_code(node):
                    continue
                idx = REJECT_FUNNELS[fname]
                reason_arg: Optional[ast.expr] = None
                if len(node.args) > idx:
                    reason_arg = node.args[idx]
                for kw in node.keywords:
                    if kw.arg == "reason":
                        reason_arg = kw.value
                if reason_arg is None:
                    continue
                literal = _literal_str(reason_arg)
                if literal is None:
                    continue
                low = literal.lower()
                if needles and not any(n in low for n in needles):
                    findings.append(
                        Finding(
                            rule=self.name,
                            path=sf.rel,
                            line=node.lineno,
                            message=(
                                f"bare-string rejection {literal!r} matches no "
                                "classifier needle and no code= was passed "
                                "(lands on ErrorCode.INTERNAL)"
                            ),
                            hint=(
                                "pass code=ErrorCode.<MEMBER>, or extend "
                                "_CLASSIFIERS in core/errors.py"
                            ),
                        )
                    )
        return findings
