"""codec-drift checker: the binary intern table is append-only and complete.

The PR 6 binary codec (``gateway/protocol.py``) compresses dict keys via
``INTERNED_FIELDS`` — both ends index into the tuple *by position*, so the
table is append-only: reordering or removing an entry silently corrupts
every frame exchanged with an older peer (a MAJOR protocol break).  This
checker pins the contract to a committed golden
(``analysis/codec_fields.golden``) and cross-checks the table against the
JSON wire field set:

* duplicates in ``INTERNED_FIELDS`` — error (later entry is unreachable);
* committed golden is no longer a *prefix* of the live table — error
  (entries were reordered or removed);
* live table has entries appended beyond the golden — warn until the
  golden is reviewed and regenerated (``--update-goldens``);
* a wire dataclass field (``TaskRequest``/``InvocationResult``/
  ``OrchestrationTrace``/``RuntimeSnapshot``) or a ``protocol.py`` envelope
  key that is not interned — error (it rides the hot path as a raw string);
* an interned entry that appears nowhere in the statically visible wire
  universe — warn (dead weight that can never be removed; document it in
  the golden's ``[exempt]`` section if it is produced dynamically).

The golden's ``[exempt]`` section lists reviewed names excluded from the
last two checks.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Set, Tuple

from ..framework import Checker, Finding, Project, SourceFile

PROTOCOL_MOD = "gateway/protocol.py"
GOLDEN = "src/repro/analysis/codec_fields.golden"

#: dataclasses whose to_wire()/to_dict() forms cross the process boundary
WIRE_DATACLASSES = {
    "TaskRequest": "core/tasks.py",
    "InvocationResult": "core/invocation.py",
    "OrchestrationTrace": "core/orchestrator.py",
    "RuntimeSnapshot": "core/telemetry.py",
}

#: modules scanned for the wire-key universe (dict displays, .get("k"),
#: d["k"] with literal keys)
UNIVERSE_PREFIXES = ("gateway/", "core/", "substrates/", "serving/")


def load_interned(project: Project) -> Tuple[List[str], int]:
    """(INTERNED_FIELDS entries in order, assignment line)."""

    sf = project.file_by_mod(PROTOCOL_MOD)
    if sf is None:
        return [], 0
    for node in sf.tree.body:
        if (
            isinstance(node, ast.Assign)
            and len(node.targets) == 1
            and isinstance(node.targets[0], ast.Name)
            and node.targets[0].id == "INTERNED_FIELDS"
        ):
            entries = [
                c.value
                for c in ast.walk(node.value)
                if isinstance(c, ast.Constant) and isinstance(c.value, str)
            ]
            return entries, node.lineno
    return [], 0


def _literal_keys(sf: SourceFile) -> Set[str]:
    """String keys visible in dict displays, subscripts, and .get() calls."""

    keys: Set[str] = set()
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.Dict):
            for k in node.keys:
                if isinstance(k, ast.Constant) and isinstance(k.value, str):
                    keys.add(k.value)
        elif isinstance(node, ast.Subscript):
            s = node.slice
            if isinstance(s, ast.Constant) and isinstance(s.value, str):
                keys.add(s.value)
        elif (
            isinstance(node, ast.Call)
            and isinstance(node.func, ast.Attribute)
            and node.func.attr in ("get", "setdefault", "pop")
            and node.args
        ):
            a0 = node.args[0]
            if isinstance(a0, ast.Constant) and isinstance(a0.value, str):
                keys.add(a0.value)
    return keys


def dataclass_fields(project: Project) -> Dict[str, Set[str]]:
    """Wire dataclass name → declared field names (AnnAssign, public)."""

    out: Dict[str, Set[str]] = {}
    for cls, mod in WIRE_DATACLASSES.items():
        sf = project.file_by_mod(mod)
        if sf is None:
            continue
        for node in sf.tree.body:
            if isinstance(node, ast.ClassDef) and node.name == cls:
                fields = set()
                for stmt in node.body:
                    if isinstance(stmt, ast.AnnAssign) and isinstance(
                        stmt.target, ast.Name
                    ):
                        ann = ast.unparse(stmt.annotation) if stmt.annotation else ""
                        if "ClassVar" in ann:
                            continue
                        if not stmt.target.id.startswith("_"):
                            fields.add(stmt.target.id)
                out[cls] = fields
    return out


def _parse_golden(text: str) -> Dict[str, List[str]]:
    sections: Dict[str, List[str]] = {"interned": [], "exempt": []}
    current = "interned"
    for raw in text.splitlines():
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        if line.startswith("[") and line.endswith("]"):
            current = line[1:-1]
            sections.setdefault(current, [])
            continue
        sections[current].append(line)
    return sections


class CodecDriftChecker(Checker):
    name = "codec-drift"
    description = "INTERNED_FIELDS is append-only vs the golden and covers the wire field set"

    def check(self, project: Project) -> List[Finding]:
        findings: List[Finding] = []
        interned, table_line = load_interned(project)
        sf = project.file_by_mod(PROTOCOL_MOD)
        if sf is None or not interned:
            return [
                Finding(
                    rule=self.name,
                    path=PROTOCOL_MOD,
                    line=1,
                    message="could not locate INTERNED_FIELDS in gateway/protocol.py",
                    hint="the codec contract moved; update the codec-drift checker",
                )
            ]

        seen: Set[str] = set()
        for i, entry in enumerate(interned):
            if entry in seen:
                findings.append(
                    Finding(
                        rule=self.name,
                        path=sf.rel,
                        line=table_line,
                        message=f"duplicate interned field {entry!r} (index {i} is unreachable)",
                        hint="remove the duplicate before any peer ships it",
                    )
                )
            seen.add(entry)

        golden_path = project.root / GOLDEN
        exempt: Set[str] = set()
        if not golden_path.exists():
            findings.append(
                Finding(
                    rule=self.name,
                    path=GOLDEN,
                    line=1,
                    message="no committed codec golden",
                    hint="run 'python -m repro.analysis --update-goldens' and commit",
                    severity="warn",
                )
            )
        else:
            sections = _parse_golden(golden_path.read_text(encoding="utf-8"))
            golden_interned = sections.get("interned", [])
            exempt = set(sections.get("exempt", []))
            if interned[: len(golden_interned)] != golden_interned:
                findings.append(
                    Finding(
                        rule=self.name,
                        path=sf.rel,
                        line=table_line,
                        message=(
                            "INTERNED_FIELDS is no longer a prefix-extension of the "
                            "committed golden — entries were reordered or removed "
                            "(MAJOR protocol break: peers index by position)"
                        ),
                        hint="restore the original prefix; only append new entries",
                    )
                )
            elif len(interned) > len(golden_interned):
                appended = interned[len(golden_interned):]
                findings.append(
                    Finding(
                        rule=self.name,
                        path=sf.rel,
                        line=table_line,
                        message=(
                            f"{len(appended)} interned field(s) appended beyond the "
                            f"golden: {', '.join(appended)}"
                        ),
                        hint="review, then 'python -m repro.analysis --update-goldens'",
                        severity="warn",
                    )
                )

        # coverage: wire dataclass fields + protocol.py keys must be interned
        must: Dict[str, str] = {}
        for cls, fields in dataclass_fields(project).items():
            for f in fields:
                must.setdefault(f, f"{cls} field")
        for key in sorted(_literal_keys(sf)):
            must.setdefault(key, "protocol.py envelope key")
        for name in sorted(must):
            if name not in seen and name not in exempt:
                findings.append(
                    Finding(
                        rule=self.name,
                        path=sf.rel,
                        line=table_line,
                        message=(
                            f"wire field {name!r} ({must[name]}) is not interned — "
                            "it rides the binary hot path as a raw string"
                        ),
                        hint=(
                            "append it to INTERNED_FIELDS (append-only!) or list it "
                            "under [exempt] in the codec golden with a review note"
                        ),
                    )
                )

        # dead entries: interned but nowhere in the visible wire universe
        universe: Set[str] = set()
        for usf in project.iter_files(UNIVERSE_PREFIXES):
            universe |= _literal_keys(usf)
        for fields in dataclass_fields(project).values():
            universe |= fields
        for entry in interned:
            if entry not in universe and entry not in exempt:
                findings.append(
                    Finding(
                        rule=self.name,
                        path=sf.rel,
                        line=table_line,
                        message=(
                            f"interned field {entry!r} not found in the wire universe "
                            "— dead table weight (and append-only means it can never "
                            "be removed)"
                        ),
                        hint="if produced dynamically, list it under [exempt] in the golden",
                        severity="warn",
                    )
                )
        return findings

    def update_goldens(self, project: Project) -> str:
        interned, _ = load_interned(project)
        golden_path = project.root / GOLDEN
        exempt: List[str] = []
        if golden_path.exists():
            exempt = _parse_golden(
                golden_path.read_text(encoding="utf-8")
            ).get("exempt", [])
        lines = [
            "# planelint codec golden — committed snapshot of INTERNED_FIELDS",
            "# (gateway/protocol.py). The live table must remain a prefix-",
            "# extension of [interned]: reordering or removing entries is a",
            "# MAJOR protocol break. [exempt] lists reviewed names excluded",
            "# from coverage/dead-entry checks (dynamic or endpoint-local).",
            "[interned]",
            *interned,
            "[exempt]",
            *exempt,
        ]
        golden_path.write_text("\n".join(lines) + "\n", encoding="utf-8")
        return GOLDEN
