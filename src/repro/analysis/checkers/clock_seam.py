"""clock-seam checker: all control-plane time flows through the injected Clock.

PR 8 made the plane runnable on virtual time (``core/simclock.py``): every
timestamp, timeout, and sleep must route through the injected ``Clock`` so
the 1000-plane simulator and seeded chaos campaigns stay deterministic and
wall-free. A single raw ``time.time()`` behind the seam silently mixes wall
epochs into virtual runs (the VirtualClock epoch is 1.7e9, real wall is
past it — wall-stamped twins look *fresher than now* and never go stale).

Flagged in scoped modules (outside ``core/simclock.py`` and pragmas):

* calls to ``time.time`` / ``time.monotonic`` / ``time.sleep`` (and the
  ``_ns`` variants), however the module or function was imported;
* ``datetime.now`` / ``utcnow`` / ``today`` calls;
* argless timestamp default-factories: ``field(default_factory=time.time)``;
* raw-time parameter defaults: ``def __init__(self, clock=time.monotonic)``
  bakes the wall clock into the signature instead of resolving an injected
  default at call time.

``time.perf_counter`` is deliberately allowed: it measures *durations* for
control-overhead accounting and never feeds a timebase decision.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Set

from ..framework import Checker, Finding, Project, SourceFile

SCOPES = ("core/", "gateway/", "substrates/", "serving/", "roofline/", "analysis/")
ALLOWED_MODULES = {"core/simclock.py"}
BANNED_TIME_FUNCS = {"time", "monotonic", "sleep", "time_ns", "monotonic_ns"}
BANNED_DATETIME_FUNCS = {"now", "utcnow", "today"}

_HINT = (
    "route through the injected Clock (core/simclock.py) or suppress with "
    "'# planelint: allow(clock-seam)' plus a rationale if wall time is intended"
)


class _TimeImports(ast.NodeVisitor):
    """Track names bound to the time/datetime modules and their functions."""

    def __init__(self) -> None:
        self.time_modules: Set[str] = set()
        self.datetime_modules: Set[str] = set()
        # local name → banned function name
        self.direct_time: Dict[str, str] = {}

    def visit_Import(self, node: ast.Import) -> None:
        for alias in node.names:
            local = alias.asname or alias.name
            if alias.name == "time":
                self.time_modules.add(local)
            elif alias.name == "datetime":
                self.datetime_modules.add(local)

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        if node.module == "time":
            for alias in node.names:
                if alias.name in BANNED_TIME_FUNCS:
                    self.direct_time[alias.asname or alias.name] = alias.name
        elif node.module == "datetime":
            for alias in node.names:
                if alias.name == "datetime":
                    self.datetime_modules.add(alias.asname or alias.name)


def _banned_timestamp_ref(node: ast.expr, imports: _TimeImports) -> str:
    """Name a banned timestamp function if ``node`` references one, else ''."""

    if isinstance(node, ast.Attribute) and isinstance(node.value, ast.Name):
        base, attr = node.value.id, node.attr
        if base in imports.time_modules and attr in BANNED_TIME_FUNCS:
            return f"time.{attr}"
        if base in imports.datetime_modules and attr in BANNED_DATETIME_FUNCS:
            return f"datetime.{attr}"
    if isinstance(node, ast.Attribute) and isinstance(node.value, ast.Attribute):
        # datetime.datetime.now
        inner = node.value
        if (
            isinstance(inner.value, ast.Name)
            and inner.value.id in imports.datetime_modules
            and node.attr in BANNED_DATETIME_FUNCS
        ):
            return f"datetime.{node.attr}"
    if isinstance(node, ast.Name) and node.id in imports.direct_time:
        return f"time.{imports.direct_time[node.id]}"
    return ""


class ClockSeamChecker(Checker):
    name = "clock-seam"
    description = "no raw wall-clock calls outside simclock.py; use the injected Clock"

    def check(self, project: Project) -> List[Finding]:
        findings: List[Finding] = []
        for sf in project.iter_files(SCOPES):
            if sf.mod in ALLOWED_MODULES:
                continue
            findings.extend(self._check_file(sf))
        return findings

    def _check_file(self, sf: SourceFile) -> List[Finding]:
        imports = _TimeImports()
        imports.visit(sf.tree)
        findings: List[Finding] = []
        for node in ast.walk(sf.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for default in list(node.args.defaults) + [
                    d for d in node.args.kw_defaults if d is not None
                ]:
                    ref = _banned_timestamp_ref(default, imports)
                    if ref:
                        findings.append(
                            Finding(
                                rule=self.name,
                                path=sf.rel,
                                line=default.lineno,
                                message=(
                                    f"raw-time parameter default ({ref}) bakes the "
                                    "wall clock into the signature"
                                ),
                                hint=(
                                    "default the parameter to None and resolve the "
                                    "injected clock in the body; " + _HINT
                                ),
                            )
                        )
                continue
            if not isinstance(node, ast.Call):
                continue
            ref = _banned_timestamp_ref(node.func, imports)
            if ref:
                findings.append(
                    Finding(
                        rule=self.name,
                        path=sf.rel,
                        line=node.lineno,
                        message=f"raw {ref}() call behind the virtual-time seam",
                        hint=_HINT,
                    )
                )
                continue
            # field(default_factory=time.time) — stamps wall time at
            # construction, before any clock can be injected.
            for kw in node.keywords:
                if kw.arg == "default_factory" and kw.value is not None:
                    ref = _banned_timestamp_ref(kw.value, imports)
                    if ref:
                        findings.append(
                            Finding(
                                rule=self.name,
                                path=sf.rel,
                                line=node.lineno,
                                message=(
                                    f"argless timestamp default-factory ({ref}) stamps "
                                    "wall time before a clock can be injected"
                                ),
                                hint=(
                                    "default to None and stamp from the owning "
                                    "component's injected clock; " + _HINT
                                ),
                            )
                        )
        return findings
