"""planelint checker registry."""

from __future__ import annotations

from typing import List

from ..framework import Checker
from .clock_seam import ClockSeamChecker
from .codec_drift import CodecDriftChecker
from .error_taxonomy import ErrorTaxonomyChecker
from .guarded_by import GuardedByChecker
from .lock_order import LockOrderChecker

__all__ = [
    "ClockSeamChecker",
    "CodecDriftChecker",
    "ErrorTaxonomyChecker",
    "GuardedByChecker",
    "LockOrderChecker",
    "all_checkers",
]


def all_checkers() -> List[Checker]:
    return [
        ClockSeamChecker(),
        LockOrderChecker(),
        GuardedByChecker(),
        ErrorTaxonomyChecker(),
        CodecDriftChecker(),
    ]
