"""lock-order checker: the cross-module lock-acquisition graph stays acyclic.

Builds a directed graph over canonical lock ids (``Class._attr`` for lock
attributes, ``Class.method()`` for lock-factory methods such as
``LifecycleManager.lock``).  An edge ``A -> B`` means some code path
acquires ``B`` while holding ``A`` — either a nested ``with`` directly, or
a call made under ``A`` whose transitive callees acquire ``B`` (resolved
through attribute types, local aliases, and subclass expansion; see
``analysis/model.py``).  Conditions alias their backing lock, so
``scheduler._idle``/``_space`` are the same node as ``scheduler._lock``.

Findings:

* any cycle in the graph — a potential deadlock under the PR 8 fault
  storms (error);
* a non-reentrant ``threading.Lock`` transitively re-acquired while held —
  certain self-deadlock (error);
* drift from the committed ``analysis/lock_order.golden`` — new edges are
  fine but must be reviewed and re-committed via ``--update-goldens``
  (warn; fails under ``--strict``).

Known blind spot: opaque callables (``self.clock()`` where ``clock`` is a
bare ``Callable``) contribute no edges.  The runtime witness
(``repro.analysis.witness``) covers those paths under the simulator.
"""

from __future__ import annotations

from pathlib import Path
from typing import Dict, List, Set, Tuple

from ..framework import Checker, Finding, Project
from ..model import (
    REENTRANT_KINDS,
    DISPATCHER_NAMES,
    MethodInfo,
    ProjectModel,
    analyze_all,
    build_model,
)

SCOPES = ("core/", "gateway/", "substrates/", "serving/")
GOLDEN = "src/repro/analysis/lock_order.golden"

_CYCLE_HINT = (
    "break the cycle by releasing the first lock before acquiring the second "
    "(copy state out, then call), or impose a single global order"
)


def build_lock_graph(
    project: Project,
) -> Tuple[ProjectModel, Dict[str, Set[str]], Dict[Tuple[str, str], Tuple[str, int]]]:
    """Return (model, adjacency, edge witness sites)."""

    model = build_model(project, SCOPES)
    infos = analyze_all(model)

    # Dynamic pub/sub dispatch: emit/_notify-style methods call every
    # registered handler at the held-set of their unresolved local calls
    # (``fn(event)`` inside the dispatch loop).
    for (cls, mname), info in infos.items():
        if mname in DISPATCHER_NAMES and info.unresolved_held:
            for held, line in info.unresolved_held:
                for hcls, hmethod in model.handlers:
                    info.calls.append(((hcls, hmethod), held, line))

    # Transitive lock acquisitions per method, to a fixpoint.
    trans: Dict[Tuple[str, str], Set[str]] = {
        key: {lock for lock, _, _ in info.acquisitions} for key, info in infos.items()
    }
    changed = True
    while changed:
        changed = False
        for key, info in infos.items():
            acc = trans[key]
            before = len(acc)
            for (tcls, tmethod), _held, _line in info.calls:
                for impl in model.resolve_method(tcls, tmethod):
                    acc |= trans.get(impl, set())
            if len(acc) != before:
                changed = True

    adj: Dict[str, Set[str]] = {}
    sites: Dict[Tuple[str, str], Tuple[str, int]] = {}

    def add_edge(a: str, b: str, info: MethodInfo, line: int) -> None:
        if a == b:
            return
        adj.setdefault(a, set()).add(b)
        sites.setdefault((a, b), (_site_path(model, info), line))

    for key, info in infos.items():
        for lock, line, held in info.acquisitions:
            for h in held:
                add_edge(h, lock, info, line)
        for (tcls, tmethod), held, line in info.calls:
            if not held:
                continue
            callee_locks: Set[str] = set()
            for impl in model.resolve_method(tcls, tmethod):
                callee_locks |= trans.get(impl, set())
            for h in held:
                for lock in callee_locks:
                    add_edge(h, lock, info, line)
    return model, adj, sites


def _site_path(model: ProjectModel, info: MethodInfo) -> str:
    cm = model.classes.get(info.key[0])
    return cm.sf.rel if cm is not None else "?"


def _self_reacquire_findings(
    project: Project, model: ProjectModel
) -> List[Finding]:
    """A plain Lock acquired again (directly or via calls) while held."""

    infos = analyze_all(model)
    trans: Dict[Tuple[str, str], Set[Tuple[str, int, str]]] = {}
    for key, info in infos.items():
        trans[key] = {(lock, line, _site_path(model, info)) for lock, line, _ in info.acquisitions}
    changed = True
    while changed:
        changed = False
        for key, info in infos.items():
            acc = trans[key]
            before = len(acc)
            for (tcls, tmethod), _held, _line in info.calls:
                for impl in model.resolve_method(tcls, tmethod):
                    acc |= trans.get(impl, set())
            if len(acc) != before:
                changed = True

    findings: List[Finding] = []
    seen: Set[Tuple[str, int]] = set()
    for key, info in infos.items():
        for lock, line, held in info.acquisitions:
            if lock in held and model.lock_kinds.get(lock) not in REENTRANT_KINDS:
                site = (_site_path(model, info), line)
                if site not in seen:
                    seen.add(site)
                    findings.append(
                        Finding(
                            rule="lock-order",
                            path=site[0],
                            line=site[1],
                            message=(
                                f"non-reentrant lock {lock} re-acquired while "
                                "already held — self-deadlock"
                            ),
                            hint="use threading.RLock or restructure to release first",
                        )
                    )
        for (tcls, tmethod), held, line in info.calls:
            for impl in model.resolve_method(tcls, tmethod):
                for lock, alin, apath in trans.get(impl, set()):
                    if lock in held and model.lock_kinds.get(lock) not in REENTRANT_KINDS:
                        site = (_site_path(model, info), line)
                        if site not in seen:
                            seen.add(site)
                            findings.append(
                                Finding(
                                    rule="lock-order",
                                    path=site[0],
                                    line=site[1],
                                    message=(
                                        f"call under non-reentrant lock {lock} reaches "
                                        f"{impl[0]}.{impl[1]} which re-acquires it "
                                        f"({apath}:{alin}) — self-deadlock"
                                    ),
                                    hint="release before calling, or make the callee lock-free",
                                )
                            )
    return findings


def _find_cycles(adj: Dict[str, Set[str]]) -> List[List[str]]:
    """Simple cycles via DFS on each strongly-connected component."""

    index: Dict[str, int] = {}
    low: Dict[str, int] = {}
    on_stack: Set[str] = set()
    stack: List[str] = []
    sccs: List[List[str]] = []
    counter = [0]

    def strongconnect(v: str) -> None:
        index[v] = low[v] = counter[0]
        counter[0] += 1
        stack.append(v)
        on_stack.add(v)
        for w in sorted(adj.get(v, ())):
            if w not in index:
                strongconnect(w)
                low[v] = min(low[v], low[w])
            elif w in on_stack:
                low[v] = min(low[v], index[w])
        if low[v] == index[v]:
            comp = []
            while True:
                w = stack.pop()
                on_stack.discard(w)
                comp.append(w)
                if w == v:
                    break
            sccs.append(comp)

    nodes = sorted(set(adj) | {w for ws in adj.values() for w in ws})
    for v in nodes:
        if v not in index:
            strongconnect(v)

    cycles = []
    for comp in sccs:
        if len(comp) > 1:
            cycles.append(sorted(comp))
        elif comp and comp[0] in adj.get(comp[0], ()):
            cycles.append(comp)
    return cycles


def render_graph(adj: Dict[str, Set[str]]) -> List[str]:
    return [f"{a} -> {b}" for a in sorted(adj) for b in sorted(adj[a])]


class LockOrderChecker(Checker):
    name = "lock-order"
    description = "inter-module lock-acquisition graph has no cycles and matches the golden"

    def check(self, project: Project) -> List[Finding]:
        model, adj, sites = build_lock_graph(project)
        findings = _self_reacquire_findings(project, model)
        for cycle in _find_cycles(adj):
            edges = []
            for i, a in enumerate(cycle):
                b = cycle[(i + 1) % len(cycle)]
                site = sites.get((a, b))
                if site:
                    edges.append(f"{a} -> {b} ({site[0]}:{site[1]})")
            first_site = sites.get((cycle[0], cycle[1 % len(cycle)]), ("?", 0))
            findings.append(
                Finding(
                    rule=self.name,
                    path=first_site[0],
                    line=first_site[1],
                    message=(
                        "lock-order cycle (potential deadlock): "
                        + "; ".join(edges or cycle)
                    ),
                    hint=_CYCLE_HINT,
                )
            )
        findings.extend(self._golden_findings(project, adj, sites))
        return findings

    def _golden_findings(
        self,
        project: Project,
        adj: Dict[str, Set[str]],
        sites: Dict[Tuple[str, str], Tuple[str, int]],
    ) -> List[Finding]:
        golden_path = project.root / GOLDEN
        current = render_graph(adj)
        if not golden_path.exists():
            return [
                Finding(
                    rule=self.name,
                    path=GOLDEN,
                    line=1,
                    message="no committed lock-order golden",
                    hint="run 'python -m repro.analysis --update-goldens' and commit",
                    severity="warn",
                )
            ]
        golden = [
            ln.strip()
            for ln in golden_path.read_text(encoding="utf-8").splitlines()
            if ln.strip() and not ln.lstrip().startswith("#")
        ]
        findings: List[Finding] = []
        for edge in sorted(set(current) - set(golden)):
            a, _, b = edge.partition(" -> ")
            site = sites.get((a, b), (GOLDEN, 1))
            findings.append(
                Finding(
                    rule=self.name,
                    path=site[0],
                    line=site[1],
                    message=f"new lock-order edge not in golden: {edge}",
                    hint=(
                        "review the new acquisition order, then "
                        "'python -m repro.analysis --update-goldens'"
                    ),
                    severity="warn",
                )
            )
        for edge in sorted(set(golden) - set(current)):
            findings.append(
                Finding(
                    rule=self.name,
                    path=GOLDEN,
                    line=1,
                    message=f"stale golden edge no longer in code: {edge}",
                    hint="'python -m repro.analysis --update-goldens' to prune",
                    severity="warn",
                )
            )
        return findings

    def update_goldens(self, project: Project) -> str:
        _model, adj, _sites = build_lock_graph(project)
        golden_path = project.root / GOLDEN
        lines = [
            "# planelint lock-order golden — the discovered static lock-acquisition",
            "# graph. 'A -> B' means some path acquires B while holding A. Reviewed",
            "# edges only; regenerate with: python -m repro.analysis --update-goldens",
        ] + render_graph(adj)
        golden_path.write_text("\n".join(lines) + "\n", encoding="utf-8")
        return GOLDEN
