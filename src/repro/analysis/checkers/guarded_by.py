"""guarded-by checker: annotated fields are only touched under their lock.

A field whose introducing assignment carries a trailing
``# guarded_by: _lock`` comment must only be read or written while that
lock (or a condition backed by it) is held.  Lock scope is computed by the
same walk the lock-order checker uses, so local aliases
(``lk = self._lock``), condition aliasing (``with self._idle:`` holds
``_lock``), and class-level shared locks (``with Cls._shared_lock:``) all
count as holding the lock.

``__init__`` (and ``__post_init__``) are exempt: the object is not yet
shared.  A method whose ``def`` line carries ``# planelint: holds(_lock)``
declares a caller-holds contract and is trusted (the contract itself is a
convention callers must uphold — the runtime witness exercises it).
"""

from __future__ import annotations

import ast
from typing import Dict, List, Tuple

from ..framework import Checker, Finding, Project
from ..model import ClassModel, ProjectModel, analyze_method, build_model

SCOPES = ("core/", "gateway/", "substrates/", "serving/")
EXEMPT_METHODS = {"__init__", "__post_init__"}


def _guarded_fields(cm: ClassModel) -> Dict[str, Tuple[str, int]]:
    """field attr → (declared lock attr, decl line) from # guarded_by pragmas."""

    fields: Dict[str, Tuple[str, int]] = {}
    # class-level declarations: _shared_pool = None  # guarded_by: _shared_pool_lock
    for stmt in cm.node.body:
        target = None
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 and isinstance(
            stmt.targets[0], ast.Name
        ):
            target = stmt.targets[0].id
        elif isinstance(stmt, ast.AnnAssign) and isinstance(stmt.target, ast.Name):
            target = stmt.target.id
        if target and stmt.lineno in cm.sf.guarded:
            fields[target] = (cm.sf.guarded[stmt.lineno], stmt.lineno)
    # instance fields: self._x = ...  # guarded_by: _lock
    for func in cm.methods.values():
        for node in ast.walk(func):
            tgt = None
            if isinstance(node, ast.Assign) and len(node.targets) == 1:
                tgt = node.targets[0]
            elif isinstance(node, ast.AnnAssign):
                tgt = node.target
            if (
                isinstance(tgt, ast.Attribute)
                and isinstance(tgt.value, ast.Name)
                and tgt.value.id in ("self", "cls")
                and node.lineno in cm.sf.guarded
            ):
                fields.setdefault(tgt.attr, (cm.sf.guarded[node.lineno], node.lineno))
    return fields


class GuardedByChecker(Checker):
    name = "guarded-by"
    description = "fields annotated '# guarded_by: _lock' are only accessed under that lock"

    def check(self, project: Project) -> List[Finding]:
        model = build_model(project, SCOPES)
        findings: List[Finding] = []
        for cm in model.classes.values():
            fields = _guarded_fields(cm)
            if not fields:
                continue
            findings.extend(self._check_class(model, cm, fields))
        return findings

    def _check_class(
        self,
        model: ProjectModel,
        cm: ClassModel,
        fields: Dict[str, Tuple[str, int]],
    ) -> List[Finding]:
        findings: List[Finding] = []
        canon_of: Dict[str, str] = {}
        for attr, (lock_attr, decl_line) in fields.items():
            canon = cm.canonical_lock(lock_attr)
            if canon is None:
                findings.append(
                    Finding(
                        rule=self.name,
                        path=cm.sf.rel,
                        line=decl_line,
                        message=(
                            f"guarded_by names unknown lock '{lock_attr}' "
                            f"on {cm.name}.{attr}"
                        ),
                        hint="the lock must be a threading Lock/RLock/Condition attribute of the class",
                    )
                )
            else:
                canon_of[attr] = canon
        if not canon_of:
            return findings

        for mname, func in cm.methods.items():
            if mname in EXEMPT_METHODS:
                continue
            trusted = {
                cm.canonical_lock(attr)
                for attr in cm.sf.holds_locks(func.lineno)
            }
            trusted.discard(None)
            info = analyze_method(model, cm, func)
            for attr, ctx, line, held in info.accesses:
                canon = canon_of.get(attr)
                if canon is None:
                    continue
                if canon in held or canon in trusted:
                    continue
                verb = "written" if ctx == "store" else "read"
                findings.append(
                    Finding(
                        rule=self.name,
                        path=cm.sf.rel,
                        line=line,
                        message=(
                            f"{cm.name}.{attr} {verb} without holding "
                            f"{canon} (declared guarded_by)"
                        ),
                        hint=(
                            f"wrap in 'with self.{canon.rsplit('.', 1)[1]}:' or mark the "
                            "method '# planelint: holds(...)' if callers hold it"
                        ),
                    )
                )
        return findings
