"""planelint CLI: ``python -m repro.analysis [--strict] [--json] ...``.

Exit status: 1 if any error-severity finding survives pragmas (or, under
``--strict``, any finding at all); 0 otherwise.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from .checkers import all_checkers
from .framework import SEVERITY_ERROR, load_project, run_checkers


def repo_root() -> Path:
    # src/repro/analysis/__main__.py → repo root is three parents up from src/
    return Path(__file__).resolve().parents[3]


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="planelint — control-plane invariant checkers",
    )
    parser.add_argument(
        "--root", type=Path, default=None,
        help="repo root (default: auto-detected from the package location)")
    parser.add_argument(
        "--rule", action="append", default=None, metavar="NAME",
        help="run only this rule (repeatable); default: all")
    parser.add_argument(
        "--strict", action="store_true",
        help="exit 1 on warnings too (golden drift etc.)")
    parser.add_argument(
        "--json", action="store_true", dest="as_json",
        help="emit findings as a JSON array")
    parser.add_argument(
        "--update-goldens", action="store_true",
        help="regenerate lock_order.golden / codec_fields.golden, then re-check")
    parser.add_argument(
        "--list-rules", action="store_true", help="list rules and exit")
    args = parser.parse_args(argv)

    checkers = all_checkers()
    if args.list_rules:
        for c in checkers:
            print(f"{c.name:15s} {c.description}")
        return 0
    if args.rule:
        known = {c.name for c in checkers}
        unknown = set(args.rule) - known
        if unknown:
            parser.error(f"unknown rule(s): {', '.join(sorted(unknown))}")
        checkers = [c for c in checkers if c.name in set(args.rule)]

    root = (args.root or repo_root()).resolve()
    project = load_project(root)

    if args.update_goldens:
        for c in checkers:
            path = c.update_goldens(project)
            if path:
                print(f"updated {path}")

    findings, suppressed = run_checkers(project, checkers)
    errors = [f for f in findings if f.severity == SEVERITY_ERROR]
    warns = [f for f in findings if f.severity != SEVERITY_ERROR]

    if args.as_json:
        print(json.dumps([f.to_dict() for f in findings], indent=2))
    else:
        for f in findings:
            print(f.format())
        tail = (
            f"planelint: {len(errors)} error(s), {len(warns)} warning(s), "
            f"{suppressed} suppressed by pragmas "
            f"({len(project.files)} files, {len(checkers)} rule(s))"
        )
        print(tail if not findings else "\n" + tail)

    if errors or (args.strict and warns):
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
