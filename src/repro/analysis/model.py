"""Project concurrency model: classes, locks, attribute types, call edges.

Shared by the lock-order and guarded-by checkers.  The model is built in
two passes over the AST:

1. per class: declared locks (``self._x = threading.Lock()``, class-level
   locks, ``Condition(self._lock)`` aliasing its backing lock), attribute
   types (``self.x = ClassName(...)``, annotated assignments, annotated
   ``__init__`` parameters), lock-factory methods (return annotation is a
   threading lock type, e.g. ``LifecycleManager.lock``), and pub/sub
   handler registrations (``bus.subscribe(self._on_event)``);
2. per method: a single recursive walk records lock acquisitions (with
   the held-set at acquisition), resolved calls (with the held-set at the
   call site), and ``self.<attr>`` accesses (for guarded-by).

Resolution is deliberately conservative: a call we cannot resolve to a
``(class, method)`` pair contributes nothing.  Dynamic pub/sub dispatch is
modeled by convention — methods named ``emit``/``_notify``/``publish``/
``dispatch`` are assumed to call every registered handler at the held-set
of their unresolved local calls (so dispatching under a lock shows up as
edges into every subscriber).
"""

from __future__ import annotations

import ast
import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .framework import Project, SourceFile

LOCK_CTORS = {"Lock": "lock", "RLock": "rlock", "Condition": "condition"}
REENTRANT_KINDS = {"rlock", "condition", "factory-rlock"}
DISPATCHER_NAMES = {"emit", "_notify", "publish", "dispatch"}
SUBSCRIBE_NAMES = {"subscribe", "add_listener", "add_done_callback"}


@dataclasses.dataclass
class LockDecl:
    cls: str
    attr: str
    kind: str                 # lock | rlock | condition | factory-rlock | factory-lock
    backing: Optional[str]    # condition's backing lock attr (None = own)
    line: int
    mod: str


@dataclasses.dataclass
class ClassModel:
    name: str
    sf: SourceFile
    node: ast.ClassDef
    bases: List[str] = dataclasses.field(default_factory=list)
    locks: Dict[str, LockDecl] = dataclasses.field(default_factory=dict)
    attr_types: Dict[str, str] = dataclasses.field(default_factory=dict)
    methods: Dict[str, ast.FunctionDef] = dataclasses.field(default_factory=dict)
    lock_factories: Dict[str, str] = dataclasses.field(default_factory=dict)

    def canonical_lock(self, attr: str) -> Optional[str]:
        """Resolve a lock attribute to its canonical id, following condition
        aliases to the backing lock (``_idle``/``_space`` → ``_lock``)."""
        seen = set()
        while attr in self.locks and attr not in seen:
            seen.add(attr)
            decl = self.locks[attr]
            if decl.kind == "condition" and decl.backing:
                attr = decl.backing
                continue
            return f"{self.name}.{attr}"
        return None


@dataclasses.dataclass
class MethodInfo:
    key: Tuple[str, str]
    acquisitions: List[Tuple[str, int, Tuple[str, ...]]] = \
        dataclasses.field(default_factory=list)
    calls: List[Tuple[Tuple[str, str], Tuple[str, ...], int]] = \
        dataclasses.field(default_factory=list)
    accesses: List[Tuple[str, str, int, Tuple[str, ...]]] = \
        dataclasses.field(default_factory=list)
    # held-sets of calls we could NOT resolve (drives pub/sub dispatch edges)
    unresolved_held: List[Tuple[Tuple[str, ...], int]] = \
        dataclasses.field(default_factory=list)


class _ImportTable:
    """Names bound to the threading module / its lock constructors."""

    def __init__(self, tree: ast.Module) -> None:
        self.threading_modules: Set[str] = set()
        self.direct_ctors: Dict[str, str] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    if alias.name == "threading":
                        self.threading_modules.add(alias.asname or alias.name)
            elif isinstance(node, ast.ImportFrom) and node.module == "threading":
                for alias in node.names:
                    if alias.name in LOCK_CTORS:
                        self.direct_ctors[alias.asname or alias.name] = \
                            LOCK_CTORS[alias.name]


def _lock_ctor_kind(node: ast.expr, imports: _ImportTable) -> Optional[str]:
    """'lock'/'rlock'/'condition' when ``node`` is a threading lock call."""
    if not isinstance(node, ast.Call):
        return None
    f = node.func
    if isinstance(f, ast.Attribute) and isinstance(f.value, ast.Name) \
            and f.value.id in imports.threading_modules:
        return LOCK_CTORS.get(f.attr)
    if isinstance(f, ast.Name):
        return imports.direct_ctors.get(f.id)
    return None


def _annotation_class(node: Optional[ast.expr]) -> Optional[str]:
    """Extract a class name from an annotation: ``T``, ``"T"``,
    ``Optional[T]``, ``module.T``."""
    if node is None:
        return None
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value.strip()
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Subscript):
        base = node.value
        if isinstance(base, ast.Name) and base.id in ("Optional",):
            return _annotation_class(node.slice)
    return None


def _lock_factory_kind(func: ast.FunctionDef,
                       imports: _ImportTable) -> Optional[str]:
    """A method whose return annotation is a threading lock type hands out
    locks (e.g. ``LifecycleManager.lock(rid) -> threading.RLock``)."""
    ann = func.returns
    if ann is None:
        return None
    if isinstance(ann, ast.Attribute) and isinstance(ann.value, ast.Name) \
            and ann.value.id in imports.threading_modules:
        kind = LOCK_CTORS.get(ann.attr)
    elif isinstance(ann, ast.Name):
        kind = imports.direct_ctors.get(ann.id)
    else:
        kind = None
    return f"factory-{kind}" if kind else None


class ProjectModel:
    def __init__(self) -> None:
        self.classes: Dict[str, ClassModel] = {}
        self.subclasses: Dict[str, Set[str]] = {}
        self.handlers: List[Tuple[str, str]] = []
        self.lock_kinds: Dict[str, str] = {}     # canonical id → kind
        self.lock_sites: Dict[str, Tuple[str, int]] = {}

    def subtree(self, cls: str) -> List[str]:
        out, stack = [], [cls]
        seen: Set[str] = set()
        while stack:
            c = stack.pop()
            if c in seen:
                continue
            seen.add(c)
            out.append(c)
            stack.extend(self.subclasses.get(c, ()))
        return out

    def resolve_method(self, cls: str, name: str) -> List[Tuple[str, str]]:
        """All (class, method) implementations reachable from a call on a
        ``cls``-typed receiver: the class or any subclass defining it."""
        keys = []
        for c in self.subtree(cls):
            cm = self.classes.get(c)
            if cm is not None and name in cm.methods:
                keys.append((c, name))
        return keys


def build_model(project: Project,
                prefixes: Sequence[str]) -> ProjectModel:
    model = ProjectModel()
    per_file_imports: Dict[str, _ImportTable] = {}
    for sf in project.iter_files(prefixes):
        imports = _ImportTable(sf.tree)
        per_file_imports[sf.rel] = imports
        for node in sf.tree.body:
            if not isinstance(node, ast.ClassDef):
                continue
            cm = ClassModel(node.name, sf, node)
            cm.bases = [b.attr if isinstance(b, ast.Attribute) else
                        b.id if isinstance(b, ast.Name) else ""
                        for b in node.bases]
            _scan_class(cm, imports)
            # first definition wins on name collision (names are unique in
            # practice across the scoped control-plane modules)
            model.classes.setdefault(node.name, cm)
    for cm in model.classes.values():
        for base in cm.bases:
            if base in model.classes:
                model.subclasses.setdefault(base, set()).add(cm.name)
        for attr, decl in cm.locks.items():
            canon = cm.canonical_lock(attr)
            if canon == f"{cm.name}.{attr}":
                model.lock_kinds[canon] = decl.kind
                model.lock_sites[canon] = (decl.mod, decl.line)
        for mname, kind in cm.lock_factories.items():
            canon = f"{cm.name}.{mname}()"
            model.lock_kinds[canon] = kind
            model.lock_sites[canon] = (cm.sf.mod,
                                       cm.methods[mname].lineno)
    # pub/sub handler registrations (second pass: needs the class table)
    for cm in model.classes.values():
        for node in ast.walk(cm.node):
            if isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Attribute) \
                    and node.func.attr in SUBSCRIBE_NAMES:
                for arg in node.args:
                    if isinstance(arg, ast.Attribute) \
                            and isinstance(arg.value, ast.Name) \
                            and arg.value.id == "self" \
                            and arg.attr in cm.methods:
                        model.handlers.append((cm.name, arg.attr))
    return model


def _scan_class(cm: ClassModel, imports: _ImportTable) -> None:
    init_params: Dict[str, str] = {}
    for stmt in cm.node.body:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            cm.methods[stmt.name] = stmt
            kind = _lock_factory_kind(stmt, imports)
            if kind:
                cm.lock_factories[stmt.name] = kind
            if stmt.name == "__init__":
                for a in stmt.args.args + stmt.args.kwonlyargs:
                    t = _annotation_class(a.annotation)
                    if t:
                        init_params[a.arg] = t
        elif isinstance(stmt, ast.Assign):
            kind = _lock_ctor_kind(stmt.value, imports)
            for tgt in stmt.targets:
                if isinstance(tgt, ast.Name) and kind:
                    cm.locks[tgt.id] = LockDecl(
                        cm.name, tgt.id, kind, None, stmt.lineno, cm.sf.mod)
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            kind = _lock_ctor_kind(stmt.value, imports)
            if isinstance(stmt.target, ast.Name) and kind:
                cm.locks[stmt.target.id] = LockDecl(
                    cm.name, stmt.target.id, kind, None, stmt.lineno,
                    cm.sf.mod)

    for func in cm.methods.values():
        for node in ast.walk(func):
            tgt = None
            value = None
            annotation = None
            if isinstance(node, ast.Assign) and len(node.targets) == 1:
                tgt, value = node.targets[0], node.value
            elif isinstance(node, ast.AnnAssign) and node.target is not None:
                tgt, value, annotation = node.target, node.value, \
                    node.annotation
            if not (isinstance(tgt, ast.Attribute)
                    and isinstance(tgt.value, ast.Name)
                    and tgt.value.id in ("self", "cls")):
                continue
            attr = tgt.attr
            kind = _lock_ctor_kind(value, imports) if value is not None \
                else None
            if kind:
                backing = None
                if kind == "condition" and isinstance(value, ast.Call) \
                        and value.args:
                    a0 = value.args[0]
                    if isinstance(a0, ast.Attribute) \
                            and isinstance(a0.value, ast.Name) \
                            and a0.value.id == "self":
                        backing = a0.attr
                cm.locks.setdefault(attr, LockDecl(
                    cm.name, attr, kind, backing, node.lineno, cm.sf.mod))
                continue
            t = _annotation_class(annotation)
            if t is None and value is not None:
                t = _value_class(value, init_params)
            if t and attr not in cm.attr_types:
                cm.attr_types[attr] = t


def _value_class(value: ast.expr, params: Dict[str, str]) -> Optional[str]:
    """Class name for ``self.x = <value>``: constructor call, annotated
    parameter, or the first resolvable operand of ``a or b``."""
    if isinstance(value, ast.Call):
        f = value.func
        if isinstance(f, ast.Name):
            return f.id if f.id[:1].isupper() else None
        if isinstance(f, ast.Attribute):
            return f.attr if f.attr[:1].isupper() else None
    if isinstance(value, ast.Name):
        return params.get(value.id)
    if isinstance(value, ast.BoolOp):
        for v in value.values:
            t = _value_class(v, params)
            if t:
                return t
    return None


# ---------------------------------------------------------------------------
# per-method analysis


class _MethodAnalyzer:
    def __init__(self, model: ProjectModel, cm: ClassModel,
                 func: ast.FunctionDef) -> None:
        self.model = model
        self.cm = cm
        self.func = func
        self.info = MethodInfo(key=(cm.name, func.name))
        self.param_types: Dict[str, str] = {}
        for a in func.args.args + func.args.kwonlyargs:
            t = _annotation_class(a.annotation)
            if t:
                self.param_types[a.arg] = t
        # local var → chain of self attributes ("x = self.a.b" → ("a","b"))
        self.aliases: Dict[str, Tuple[str, ...]] = {}
        self.local_types: Dict[str, str] = {}
        # names bound inside the method (params, assignments, loop targets):
        # only calls to THESE count as unresolved dynamic dispatch — a bare
        # builtin like list() under a lock is not a callback invocation
        self.local_names: Set[str] = {
            a.arg for a in func.args.args + func.args.kwonlyargs
        }

    def run(self) -> MethodInfo:
        self._visit_body(self.func.body, ())
        return self.info

    # -- resolution helpers ---------------------------------------------------
    def _self_chain(self, node: ast.expr) -> Optional[Tuple[str, ...]]:
        """``self.a.b`` → ("a", "b"); follows local aliases one level."""
        parts: List[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if isinstance(node, ast.Name):
            if node.id in ("self", "cls"):
                return tuple(reversed(parts))
            if node.id in self.aliases:
                return self.aliases[node.id] + tuple(reversed(parts))
        return None

    def _chain_type(self, chain: Tuple[str, ...]) -> Optional[str]:
        """Type of ``self.<chain>`` walking attr_types across classes."""
        cur = self.cm.name
        for attr in chain:
            cm = self.model.classes.get(cur)
            if cm is None:
                return None
            cur = cm.attr_types.get(attr)
            if cur is None:
                return None
        return cur

    def _resolve_lock(self, node: ast.expr) -> Optional[Tuple[str, str, int]]:
        """Lock id for a with-item: ``(lock_id, kind, line)`` or None."""
        line = getattr(node, "lineno", self.func.lineno)
        # with self.lock(rid):  — lock-factory call
        if isinstance(node, ast.Call):
            callee = self._resolve_callee(node.func)
            if callee is not None:
                tcls, mname = callee
                for c in self.model.subtree(tcls):
                    cm = self.model.classes.get(c)
                    if cm is not None and mname in cm.lock_factories:
                        canon = f"{c}.{mname}()"
                        return canon, cm.lock_factories[mname], line
            return None
        chain = self._self_chain(node)
        if chain:
            if len(chain) == 1:
                canon = self.cm.canonical_lock(chain[0])
                if canon:
                    return canon, self.model.lock_kinds.get(canon, "lock"), \
                        line
            else:
                owner = self._chain_type(chain[:-1])
                if owner and owner in self.model.classes:
                    canon = self.model.classes[owner].canonical_lock(chain[-1])
                    if canon:
                        return canon, \
                            self.model.lock_kinds.get(canon, "lock"), line
        # with ClassName._shared_lock:
        if isinstance(node, ast.Attribute) and isinstance(node.value, ast.Name):
            owner = self.model.classes.get(node.value.id)
            if owner is not None:
                canon = owner.canonical_lock(node.attr)
                if canon:
                    return canon, self.model.lock_kinds.get(canon, "lock"), \
                        line
        # with lk:  — local alias of a lock attribute
        if isinstance(node, ast.Name) and node.id in self.aliases:
            return self._resolve_lock(ast.copy_location(
                ast.Attribute(value=ast.Name(id="self"),
                              attr=self.aliases[node.id][-1])
                if len(self.aliases[node.id]) == 1 else node, node)) \
                if len(self.aliases[node.id]) == 1 else None
        return None

    def _resolve_callee(self, f: ast.expr) -> Optional[Tuple[str, str]]:
        if isinstance(f, ast.Attribute):
            base = f.value
            if isinstance(base, ast.Name):
                if base.id in ("self", "cls"):
                    return (self.cm.name, f.attr)
                if base.id in self.model.classes:
                    return (base.id, f.attr)
                if base.id in self.local_types:
                    return (self.local_types[base.id], f.attr)
                if base.id in self.aliases:
                    t = self._chain_type(self.aliases[base.id])
                    if t:
                        return (t, f.attr)
                if base.id in self.param_types:
                    return (self.param_types[base.id], f.attr)
                return None
            chain = self._self_chain(base)
            if chain:
                t = self._chain_type(chain)
                if t:
                    return (t, f.attr)
            return None
        if isinstance(f, ast.Name) and f.id in self.model.classes:
            return (f.id, "__init__")
        return None

    # -- the walk -------------------------------------------------------------
    def _visit_body(self, body: List[ast.stmt],
                    held: Tuple[str, ...]) -> None:
        for stmt in body:
            self._visit(stmt, held)

    def _visit(self, node: ast.AST, held: Tuple[str, ...]) -> None:
        if isinstance(node, ast.With):
            inner = held
            for item in node.items:
                self._visit(item.context_expr, inner)
                resolved = self._resolve_lock(item.context_expr)
                if resolved is not None:
                    lock_id, _kind, line = resolved
                    self.info.acquisitions.append((lock_id, line, inner))
                    inner = inner + (lock_id,)
            self._visit_body(node.body, inner)
            return
        if isinstance(node, (ast.For, ast.AsyncFor)):
            for tname in ast.walk(node.target):
                if isinstance(tname, ast.Name):
                    self.local_names.add(tname.id)
            for child in ast.iter_child_nodes(node):
                self._visit(child, held)
            return
        if isinstance(node, ast.Assign):
            for t in node.targets:
                for tn in ast.walk(t):
                    if isinstance(tn, ast.Name):
                        self.local_names.add(tn.id)
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            name = node.targets[0].id
            chain = self._self_chain(node.value)
            if chain:
                self.aliases[name] = chain
            else:
                t = _value_class(node.value, self.param_types)
                if t and t in self.model.classes:
                    self.local_types[name] = t
                elif isinstance(node.value, ast.BoolOp):
                    for v in node.value.values:
                        c = self._self_chain(v)
                        if c:
                            self.aliases[name] = c
                            break
            self._visit(node.value, held)
            return
        if isinstance(node, ast.Call):
            callee = self._resolve_callee(node.func)
            line = node.lineno
            if callee is not None:
                self.info.calls.append((callee, held, line))
            elif isinstance(node.func, ast.Name) \
                    and node.func.id in self.local_names:
                self.info.unresolved_held.append((held, line))
            for child in ast.iter_child_nodes(node):
                self._visit(child, held)
            return
        if isinstance(node, ast.Attribute):
            if isinstance(node.value, ast.Name) and (
                    node.value.id in ("self", "cls")
                    or node.value.id == self.cm.name):
                ctx = "store" if isinstance(node.ctx, (ast.Store, ast.Del)) \
                    else "load"
                self.info.accesses.append((node.attr, ctx, node.lineno, held))
            self._visit(node.value, held)
            return
        if isinstance(node, ast.Lambda):
            # predicates passed to wait_for run with the condition re-held —
            # analyze the body at the current held-set
            self._visit(node.body, held)
            return
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # nested def: deferred execution (thread targets, callbacks) —
            # analyze with an empty held-set
            self._visit_body(node.body, ())
            return
        for child in ast.iter_child_nodes(node):
            self._visit(child, held)


def analyze_method(model: ProjectModel, cm: ClassModel,
                   func: ast.FunctionDef) -> MethodInfo:
    return _MethodAnalyzer(model, cm, func).run()


def analyze_all(model: ProjectModel) -> Dict[Tuple[str, str], MethodInfo]:
    infos: Dict[Tuple[str, str], MethodInfo] = {}
    for cm in model.classes.values():
        for func in cm.methods.values():
            infos[(cm.name, func.name)] = analyze_method(model, cm, func)
    return infos
