"""planelint: control-plane invariant analysis for the phys-MCP repro.

Static checkers (``python -m repro.analysis``) for the conventions nothing
else enforces — the injected-Clock seam, lock ordering, guarded-by field
discipline, the structured ErrorCode taxonomy, and the append-only binary
intern table — plus a runtime lock-order witness
(:mod:`repro.analysis.witness`) the chaos/sim fixtures activate so the PR 8
simulator doubles as a deadlock fuzzer.
"""

from .framework import (  # noqa: F401
    Checker,
    Finding,
    Project,
    SourceFile,
    apply_pragmas,
    load_project,
    run_checkers,
)
from .checkers import all_checkers  # noqa: F401
