"""Runtime lock-order witness: the dynamic half of the lock-order checker.

The static checker (``repro.analysis.checkers.lock_order``) derives the
lock graph from the AST, but opaque callables — ``self.clock()``, bus
subscribers, injected recoverers — contribute no edges there.  This module
closes that blind spot at runtime: :func:`witnessed_locks` monkeypatches
``threading.Lock``/``threading.RLock`` inside a ``with`` window so every
lock *constructed* in the window is wrapped in an :class:`OrderedLock`
that reports to a shared :class:`LockWitness`:

- **order edges** are recorded at acquire-*attempt* time (lockdep-style:
  the intent to nest is the fact, whether or not the acquire succeeds),
  from every lock the thread already holds to the one it is acquiring;
- **self-reacquire** of a non-reentrant ``Lock`` the thread already holds
  is reported immediately (the real program would deadlock there);
- **hold-while-blocking** is reported when a thread parks on a condition
  (``Condition.wait`` / ``wait_for``) while still holding *other*
  witnessed locks — the sleeping thread pins those locks, so any waker
  that needs one of them deadlocks.

Locks are aggregated by **allocation site** (``file:line`` of the
constructor call), mirroring the static checker's canonical
``Class._attr`` naming: a 1000-plane fleet contributes one node per lock
*field*, not one per instance.  The deliberate blind spot is ordering
between two instances born at the same site — same-site edges are
skipped rather than reported as self-cycles.

Nothing here records timestamps: the report is a pure function of the
witnessed acquisition sequence, so a deterministic run (virtual clock,
seeded RNG, sequenced threads) yields a byte-identical report.
"""

from __future__ import annotations

import contextlib
import sys
import threading
from _thread import get_ident
from typing import Dict, List, Optional, Set, Tuple

# captured before any patching: the witness's own state must never be
# guarded by a witnessed lock (the bookkeeping would recurse)
_REAL_LOCK = threading.Lock
_REAL_RLOCK = threading.RLock

_SKIP_FILES = (threading.__file__, __file__, contextlib.__file__)


class WitnessViolation(AssertionError):
    """A lock-order cycle or blocking violation observed at runtime."""


def _call_site() -> Tuple[str, bool]:
    """(file:line, is_plumbing) for the frame that constructed the lock,
    skipping stdlib threading internals and this module (so
    ``Condition()``'s implicit ``RLock()`` is attributed to the
    ``Condition(...)`` call site).

    ``is_plumbing`` is True when the lock was born inside
    ``Thread.__init__`` — the interpreter's own bootstrap Event, signalled
    by the runtime regardless of any user lock, so blocking on it (as
    ``Thread.start`` does) is not a user-level ordering fact."""

    frame = sys._getframe(1)
    plumbing = False
    while frame is not None and frame.f_code.co_filename in _SKIP_FILES:
        slf = frame.f_locals.get("self")
        if isinstance(slf, threading.Thread):
            plumbing = True
        frame = frame.f_back
    if frame is None:
        return "<unknown>", plumbing
    filename = frame.f_code.co_filename
    marker = "src/repro/"
    idx = filename.rfind(marker)
    if idx >= 0:
        filename = filename[idx + len(marker):]
    else:
        filename = filename.rsplit("/", 1)[-1]
    return f"{filename}:{frame.f_lineno}", plumbing


class OrderedLock:
    """Drop-in ``Lock``/``RLock`` wrapper reporting to a :class:`LockWitness`.

    Implements the full protocol ``threading.Condition`` probes for —
    ``acquire``/``release``/``_is_owned``/``_release_save``/
    ``_acquire_restore`` — so a witnessed lock can back a condition, and
    the ``_release_save`` call doubles as the wait-entry hook for
    hold-while-blocking detection."""

    __slots__ = ("_inner", "_witness", "site", "label", "reentrant",
                 "plumbing")

    def __init__(self, inner, witness: "LockWitness", site: str,
                 label: str, reentrant: bool, plumbing: bool = False) -> None:
        self._inner = inner
        self._witness = witness
        self.site = site
        self.label = label
        self.reentrant = reentrant
        self.plumbing = plumbing

    # -- core lock protocol ---------------------------------------------------
    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        self._witness._before_acquire(self)
        got = self._inner.acquire(blocking, timeout)
        if got:
            self._witness._push(self, 1)
        return got

    def release(self) -> None:
        self._inner.release()
        self._witness._pop(self)

    def __enter__(self) -> "OrderedLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def locked(self) -> bool:
        locked = getattr(self._inner, "locked", None)
        if locked is not None:
            return locked()
        return self._witness._held_anywhere(self)

    # -- condition-variable protocol -----------------------------------------
    def _is_owned(self) -> bool:
        is_owned = getattr(self._inner, "_is_owned", None)
        if is_owned is not None:
            return is_owned()
        return self._witness._thread_holds(self)

    def _release_save(self):
        # Condition.wait enters here with the lock held: the thread is
        # about to block, so any OTHER held lock is a blocking hazard
        self._witness._on_wait(self)
        release_save = getattr(self._inner, "_release_save", None)
        if release_save is not None:
            inner_state = release_save()
        else:
            self._inner.release()
            inner_state = None
        count = self._witness._pop_all(self)
        return (inner_state, count)

    def _acquire_restore(self, state) -> None:
        inner_state, count = state
        acquire_restore = getattr(self._inner, "_acquire_restore", None)
        if acquire_restore is not None:
            acquire_restore(inner_state)
        else:
            self._inner.acquire()
        # the post-wait reacquire restores a hold the thread already
        # ordered before waiting — no new edge is recorded
        self._witness._push(self, count)

    def __repr__(self) -> str:
        return f"<OrderedLock {self.label} reentrant={self.reentrant}>"


class LockWitness:
    """Accumulates acquisition orders and violations across all
    :class:`OrderedLock` instances wrapped for it."""

    def __init__(self) -> None:
        self._mu = _REAL_LOCK()
        # thread ident -> acquisition stack of [lock, recursion_count]
        self._held: Dict[int, List[List]] = {}
        # allocation-site order graph: site -> set of sites acquired under it
        self._edges: Dict[str, Set[str]] = {}
        self._violations: List[str] = []
        self._site_counts: Dict[str, int] = {}
        self._locks_created = 0

    # -- lock construction ----------------------------------------------------
    def wrap(self, inner, reentrant: bool, site: Optional[str] = None
             ) -> OrderedLock:
        if site is None:
            site, plumbing = _call_site()
        else:
            plumbing = False
        with self._mu:
            n = self._site_counts.get(site, 0)
            self._site_counts[site] = n + 1
            self._locks_created += 1
        return OrderedLock(inner, self, site, f"{site}#{n}", reentrant,
                           plumbing)

    # -- bookkeeping hooks (called from OrderedLock) ---------------------------
    def _before_acquire(self, lock: OrderedLock) -> None:
        tid = get_ident()
        with self._mu:
            stack = self._held.get(tid, ())
            for entry in stack:
                if entry[0] is lock:
                    if not lock.reentrant:
                        self._violations.append(
                            "self-reacquire of non-reentrant lock "
                            f"{lock.label} (thread would deadlock)")
                    return          # reentrant reacquire: no new ordering
            if lock.plumbing:
                return              # thread-bootstrap locks: no user edges
            for entry in stack:
                held = entry[0]
                a, b = held.site, lock.site
                if a != b and not held.plumbing:
                    # same-site instance pairs stay unchecked
                    self._edges.setdefault(a, set()).add(b)

    def _push(self, lock: OrderedLock, count: int) -> None:
        tid = get_ident()
        with self._mu:
            stack = self._held.setdefault(tid, [])
            for entry in stack:
                if entry[0] is lock:
                    entry[1] += count
                    return
            stack.append([lock, count])

    def _pop(self, lock: OrderedLock) -> None:
        tid = get_ident()
        with self._mu:
            stack = self._held.get(tid, [])
            for i in range(len(stack) - 1, -1, -1):
                if stack[i][0] is lock:
                    stack[i][1] -= 1
                    if stack[i][1] <= 0:
                        del stack[i]
                    return

    def _pop_all(self, lock: OrderedLock) -> int:
        """Remove every recursion level of ``lock`` for this thread
        (Condition.wait fully releases); returns the count to restore."""

        tid = get_ident()
        with self._mu:
            stack = self._held.get(tid, [])
            for i in range(len(stack) - 1, -1, -1):
                if stack[i][0] is lock:
                    count = stack[i][1]
                    del stack[i]
                    return count
        return 1

    def _on_wait(self, lock: OrderedLock) -> None:
        if lock.plumbing:
            return      # Thread.start joining its bootstrap Event: benign
        tid = get_ident()
        with self._mu:
            others = sorted(entry[0].label
                            for entry in self._held.get(tid, ())
                            if entry[0] is not lock
                            and not entry[0].plumbing)
            if others:
                self._violations.append(
                    f"hold-while-blocking: waiting on condition backed by "
                    f"{lock.label} while holding {', '.join(others)}")

    def _thread_holds(self, lock: OrderedLock) -> bool:
        tid = get_ident()
        with self._mu:
            return any(entry[0] is lock
                       for entry in self._held.get(tid, ()))

    def _held_anywhere(self, lock: OrderedLock) -> bool:
        with self._mu:
            return any(entry[0] is lock
                       for stack in self._held.values()
                       for entry in stack)

    # -- reporting -------------------------------------------------------------
    def edges(self) -> List[str]:
        with self._mu:
            return sorted(f"{a} -> {b}"
                          for a, succ in self._edges.items() for b in succ)

    def cycles(self) -> List[List[str]]:
        """Deterministic elementary-cycle scan of the site graph (DFS from
        each node in sorted order; cycles canonicalized by rotation)."""

        with self._mu:
            adj = {a: sorted(succ) for a, succ in self._edges.items()}
        seen: Set[Tuple[str, ...]] = set()
        cycles: List[List[str]] = []

        def dfs(node: str, path: List[str], on_path: Set[str]) -> None:
            for nxt in adj.get(node, ()):
                if nxt in on_path:
                    cyc = path[path.index(nxt):]
                    pivot = cyc.index(min(cyc))
                    canon = tuple(cyc[pivot:] + cyc[:pivot])
                    if canon not in seen:
                        seen.add(canon)
                        cycles.append(list(canon))
                elif len(path) < 32:        # bounded: graphs here are tiny
                    path.append(nxt)
                    on_path.add(nxt)
                    dfs(nxt, path, on_path)
                    on_path.discard(nxt)
                    path.pop()

        for start in sorted(adj):
            dfs(start, [start], {start})
        return sorted(cycles)

    def violations(self) -> List[str]:
        with self._mu:
            return sorted(set(self._violations))

    def report(self) -> Dict:
        return {
            "locks": self._locks_created,
            "edges": self.edges(),
            "cycles": self.cycles(),
            "violations": self.violations(),
        }

    def assert_clean(self) -> None:
        """Raise :class:`WitnessViolation` on any cycle or violation."""

        problems: List[str] = []
        for cyc in self.cycles():
            problems.append("lock-order cycle: " + " -> ".join(cyc + [cyc[0]]))
        problems.extend(self.violations())
        if problems:
            raise WitnessViolation(
                "lock witness observed {} problem(s):\n  {}".format(
                    len(problems), "\n  ".join(problems)))


@contextlib.contextmanager
def witnessed_locks(witness: Optional[LockWitness] = None):
    """Patch ``threading.Lock``/``threading.RLock`` so every lock created
    inside the window is witnessed.  Yields the :class:`LockWitness`.

    Locks created *before* the window stay unwrapped (and invisible);
    build the system under test inside the window.  ``Condition()``,
    ``Event()`` and ``concurrent.futures`` plumbing constructed in the
    window pick up witnessed locks automatically because they call the
    patched module-level constructors."""

    w = witness if witness is not None else LockWitness()

    def make_lock():
        return w.wrap(_REAL_LOCK(), reentrant=False)

    def make_rlock():
        return w.wrap(_REAL_RLOCK(), reentrant=True)

    threading.Lock = make_lock          # type: ignore[assignment]
    threading.RLock = make_rlock        # type: ignore[assignment]
    try:
        yield w
    finally:
        threading.Lock = _REAL_LOCK     # type: ignore[assignment]
        threading.RLock = _REAL_RLOCK   # type: ignore[assignment]
