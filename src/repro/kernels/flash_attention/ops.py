"""Jitted public wrapper: model-layout (B,S,H,hd) → kernel layout and back.

``use_pallas`` on an ArchConfig routes ``repro.models.attention`` through
this op on TPU; the pure-JAX chunked path remains the CPU/dry-run default.
"""
from __future__ import annotations

import jax

import functools

from repro.kernels.autodiff import kernel_with_ref_vjp
from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


@functools.lru_cache(maxsize=32)
def _diff_op(causal, block_q, block_k, interpret):
    return kernel_with_ref_vjp(
        functools.partial(flash_attention, causal=causal, block_q=block_q,
                          block_k=block_k, interpret=interpret),
        functools.partial(attention_ref, causal=causal))


def mha(q, k, v, *, causal: bool = True, block_q: int = 128,
        block_k: int = 128, interpret: bool = True):
    """q: (B, S, H, hd); k, v: (B, T, K, hd). Returns (B, S, H, hd).

    Differentiable: Pallas kernel forward, oracle-recompute backward."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    o = _diff_op(causal, block_q, block_k, interpret)(qt, kt, vt)
    return o.transpose(0, 2, 1, 3)


def mha_ref(q, k, v, *, causal: bool = True):
    o = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                      v.transpose(0, 2, 1, 3), causal=causal)
    return o.transpose(0, 2, 1, 3)
