"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def attention_ref(q, k, v, *, causal: bool = True):
    """q: (B, H, S, hd); k, v: (B, K, T, hd), H = K·G. fp32 math."""
    B, H, S, hd = q.shape
    K, T = k.shape[1], k.shape[2]
    G = H // K
    qf = q.astype(jnp.float32).reshape(B, K, G, S, hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bkgsh,bkth->bkgst", qf, kf) / np.sqrt(hd)
    if causal:
        mask = jnp.tril(jnp.ones((S, T), bool), k=T - S)
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,bkth->bkgsh", p, vf)
    return o.reshape(B, H, S, hd).astype(q.dtype)
