"""Blocked causal GQA flash attention — Pallas TPU kernel.

TPU adaptation (not a CUDA port): the kernel is shaped around the MXU and
the sequential-innermost-grid-dimension property of TPU Pallas —

- grid = (batch, q_heads, q_blocks, kv_blocks); the kv dimension is
  innermost and therefore *sequential per core*, so the online-softmax
  running state (m, l, acc) lives in VMEM scratch that persists across kv
  iterations (no atomics / shared-memory reductions as on GPU),
- q/k/v blocks are staged HBM→VMEM by BlockSpec index maps; the GQA
  mapping (kv head = q head // group) happens in the index map, so grouped
  heads share kv traffic,
- block shapes default to (128, head_dim) — MXU-aligned (multiples of 8
  sublanes × 128 lanes for f32).

Causality is exploited at block granularity: kv blocks strictly above the
diagonal are skipped via ``pl.when`` (no compute, no VMEM writes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  sm_scale: float, block_q: int, block_k: int, causal: bool,
                  seq_len: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # block-level causal skip: kv block strictly above the diagonal
    run = (not causal) or (ki * block_k <= qi * block_q + block_q - 1)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * sm_scale                               # (bq, bk)
        rows = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                       (block_q, block_k), 0)
        cols = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                       (block_q, block_k), 1)
        mask = cols < seq_len
        if causal:
            mask &= cols <= rows
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                            # (bq,)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        p = jnp.where(mask, p, 0.0)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_cur

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True):
    """q: (B, H, S, hd); k, v: (B, K, T, hd) with H = K·G. Returns (B,H,S,hd).

    ``interpret=True`` executes on CPU for validation; on TPU pass False.
    """
    B, H, S, hd = q.shape
    K, T = k.shape[1], k.shape[2]
    G = H // K
    sm_scale = 1.0 / np.sqrt(hd)

    bq = min(block_q, S)
    bk = min(block_k, T)
    pad_q = (-S) % bq
    pad_k = (-T) % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    nq = (S + pad_q) // bq
    nk = (T + pad_k) // bk

    grid = (B, H, nq, nk)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, sm_scale=sm_scale, block_q=bq,
                          block_k=bk, causal=causal, seq_len=T),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j, g=G: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j, g=G: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S + pad_q, hd), q.dtype),
        scratch_shapes=[
            _vmem((bq,), jnp.float32),       # m: running row max
            _vmem((bq,), jnp.float32),       # l: running denominator
            _vmem((bq, hd), jnp.float32),    # acc: unnormalized output
        ],
        interpret=interpret,
    )(q, k, v)
    if pad_q:
        out = out[:, :, :S]
    return out


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)
