"""RWKV-6 chunked recurrence — Pallas TPU kernel.

TPU adaptation of the flash-linear-attention chunked algorithm:

- grid = (batch, heads, chunks); the chunk dimension is innermost and
  sequential, so the per-head matrix state S ∈ R^{hd×hd} (fp32) lives in
  VMEM scratch across chunk iterations — the cross-chunk recurrence costs
  zero HBM traffic,
- within a chunk the pairwise decay ``exp(cum_{t-1} − cum_j)`` (always ≤ 0
  in the exponent → no overflow) is materialized in VMEM only:
  (C, C, hd) fp32 at C=32, hd=64 is 256 KiB, far under the ~16 MiB budget,
- the intra-chunk contraction and state update are MXU matmuls.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rwkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, y_ref, state_scr, *,
                 chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    r = r_ref[0, 0].astype(jnp.float32)          # (C, hd)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    lw = lw_ref[0, 0].astype(jnp.float32)        # log-decay ≤ 0
    u = u_ref[0].astype(jnp.float32)             # (hd,)
    S = state_scr[...]                           # (hd, hd)

    C = chunk
    cum = jnp.cumsum(lw, axis=0)                 # inclusive
    # pairwise exponent cum_{t-1} - cum_j for t > j  (≤ 0 always)
    expn = (cum - lw)[:, None, :] - cum[None, :, :]          # (C, C, hd)
    tri = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0) > \
        jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    pair = jnp.where(tri[:, :, None], jnp.exp(expn), 0.0)
    A = jnp.sum(pair * r[:, None, :] * k[None, :, :], axis=-1)   # (C, C)
    diag = jnp.sum(r * u[None, :] * k, axis=-1)                  # (C,)
    eye = (jax.lax.broadcasted_iota(jnp.int32, (C, C), 0) ==
           jax.lax.broadcasted_iota(jnp.int32, (C, C), 1))
    A = A + jnp.where(eye, diag[:, None], 0.0)

    y = jax.lax.dot_general(A, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # cross-chunk read: r_t decayed back to chunk start
    y = y + jax.lax.dot_general(r * jnp.exp(cum - lw), S,
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    # state update
    dec_k = jnp.exp(cum[-1][None, :] - cum)                      # (C, hd) ≤ 1
    S_new = S * jnp.exp(cum[-1])[:, None] + jax.lax.dot_general(
        (k * dec_k), v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    state_scr[...] = S_new
    y_ref[0, 0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan(r, k, v, lw, u, *, chunk: int = 32, interpret: bool = True):
    """r,k,v: (B, H, S, hd); lw: (B, H, S, hd) fp32 log-decay; u: (H, hd).

    Returns y: (B, H, S, hd).  S must be divisible by ``chunk``.
    """
    B, H, S, hd = r.shape
    assert S % chunk == 0, (S, chunk)
    n = S // chunk
    grid = (B, H, n)
    spec = pl.BlockSpec((1, 1, chunk, hd), lambda b, h, c: (b, h, c, 0))
    return pl.pallas_call(
        functools.partial(_rwkv_kernel, chunk=chunk),
        grid=grid,
        in_specs=[spec, spec, spec, spec,
                  pl.BlockSpec((1, hd), lambda b, h, c: (h, 0))],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), r.dtype),
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, lw, u)
