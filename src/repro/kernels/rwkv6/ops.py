"""Jitted wrapper: model layout (B,S,H,hd) ↔ kernel layout (B,H,S,hd)."""
from __future__ import annotations

import functools

from repro.kernels.autodiff import kernel_with_ref_vjp
from repro.kernels.rwkv6.ref import rwkv6_ref
from repro.kernels.rwkv6.rwkv6_scan import rwkv6_scan


@functools.lru_cache(maxsize=16)
def _diff_op(chunk, interpret):
    return kernel_with_ref_vjp(
        functools.partial(rwkv6_scan, chunk=chunk, interpret=interpret),
        rwkv6_ref)


def time_mix_scan(r, k, v, lw, u, *, chunk: int = 32, interpret: bool = True):
    """Model-layout entry point. r,k,v,lw: (B,S,H,hd); u: (H,hd).

    Differentiable: Pallas kernel forward, oracle-recompute backward."""
    tr = lambda t: t.transpose(0, 2, 1, 3)
    y = _diff_op(chunk, interpret)(tr(r), tr(k), tr(v), tr(lw), u)
    return y.transpose(0, 2, 1, 3)


def time_mix_ref(r, k, v, lw, u):
    tr = lambda t: t.transpose(0, 2, 1, 3)
    return rwkv6_ref(tr(r), tr(k), tr(v), tr(lw), u).transpose(0, 2, 1, 3)
