"""Sequential-recurrence oracle for the RWKV-6 kernel.

    S_t = diag(w_t)·S_{t-1} + k_tᵀ v_t
    y_t = r_t · (S_{t-1} + diag(u)·k_tᵀ v_t)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rwkv6_ref(r, k, v, lw, u):
    """r,k,v,lw: (B, H, S, hd); u: (H, hd). Sequential scan over S."""
    B, H, S, hd = r.shape
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    w = jnp.exp(lw.astype(jnp.float32))

    def step(S_c, xs):
        rt, kt, vt, wt = xs                      # (B, H, hd)
        kv = jnp.einsum("bhd,bhe->bhde", kt, vt)
        y = jnp.einsum("bhd,bhde->bhe", rt,
                       S_c + u[None, :, :, None] * kv)
        S_n = S_c * wt[..., None] + kv
        return S_n, y

    xs = tuple(t.transpose(2, 0, 1, 3) for t in (rf, kf, vf, w))
    S0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    _, ys = jax.lax.scan(step, S0, xs)
    return ys.transpose(1, 2, 0, 3).astype(r.dtype)
