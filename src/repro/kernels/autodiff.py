"""custom_vjp wrappers making the Pallas kernels trainable.

Forward runs the Pallas kernel (MXU/VPU-shaped, VMEM-resident); backward
recomputes through the pure-jnp oracle under ``jax.vjp`` — the
flash-attention-style recompute pattern. A fused backward kernel is the
natural next step on hardware; the oracle backward is numerically identical
and keeps the forward win.
"""
from __future__ import annotations

import functools

import jax


def kernel_with_ref_vjp(kernel_fn, ref_fn):
    """Differentiable op: ``kernel_fn`` forward, grads through ``ref_fn``.

    Both must share the same positional-arg signature; keyword args must be
    passed by the caller via functools.partial before wrapping.
    """

    @jax.custom_vjp
    def op(*args):
        return kernel_fn(*args)

    def fwd(*args):
        return kernel_fn(*args), args

    def bwd(args, g):
        _, vjp = jax.vjp(lambda *a: ref_fn(*a), *args)
        return vjp(g)

    op.defvjp(fwd, bwd)
    return op


def differentiable(kernel_fn, ref_fn, **kernel_kwargs):
    k = functools.partial(kernel_fn, **kernel_kwargs)
    return kernel_with_ref_vjp(k, ref_fn)
