"""Jitted wrapper for the RG-LRU scan kernel."""
from __future__ import annotations

import functools

from repro.kernels.autodiff import kernel_with_ref_vjp
from repro.kernels.rglru.ref import rglru_ref
from repro.kernels.rglru.rglru_scan import rglru_scan


@functools.lru_cache(maxsize=16)
def _diff_op(chunk, block_w, interpret):
    return kernel_with_ref_vjp(
        functools.partial(rglru_scan, chunk=chunk, block_w=block_w,
                          interpret=interpret),
        rglru_ref)


def linear_recurrence(a, b, *, chunk: int = 64, block_w: int = 128,
                      interpret: bool = True):
    """Differentiable: Pallas kernel forward, oracle backward."""
    return _diff_op(chunk, block_w, interpret)(a, b)


def linear_recurrence_ref(a, b):
    return rglru_ref(a, b)
