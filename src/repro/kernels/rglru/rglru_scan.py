"""RG-LRU diagonal linear recurrence — Pallas TPU kernel.

h_t = a_t ⊙ h_{t-1} + b_t over the time axis, channel-blocked:

- grid = (batch, width_blocks, chunks); chunks innermost/sequential with the
  carried state h ∈ R^{wb} (fp32) in VMEM scratch,
- the channel dimension is blocked to the 128-lane VPU width (this is a
  VPU kernel, not an MXU one — elementwise FMA over lanes),
- within a chunk the recurrence is an in-register ``fori_loop`` over C
  timesteps with a dynamic row store per step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, b_ref, h_ref, state_scr, *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    a = a_ref[0].astype(jnp.float32)         # (C, wb)
    b = b_ref[0].astype(jnp.float32)

    def step(t, h):
        h = a[t] * h + b[t]
        pl.store(h_ref, (0, pl.dslice(t, 1), slice(None)),
                 h[None].astype(h_ref.dtype))
        return h

    h_final = jax.lax.fori_loop(0, chunk, step, state_scr[...])
    state_scr[...] = h_final


@functools.partial(jax.jit, static_argnames=("chunk", "block_w", "interpret"))
def rglru_scan(a, b, *, chunk: int = 64, block_w: int = 128,
               interpret: bool = True):
    """a, b: (B, S, W) — returns h: (B, S, W) with h_t = a_t·h_{t-1} + b_t."""
    B, S, W = a.shape
    assert S % chunk == 0, (S, chunk)
    wb = min(block_w, W)
    assert W % wb == 0, (W, wb)
    grid = (B, W // wb, S // chunk)
    spec = pl.BlockSpec((1, chunk, wb), lambda bi, wi, ci: (bi, ci, wi))
    return pl.pallas_call(
        functools.partial(_rglru_kernel, chunk=chunk),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((B, S, W), a.dtype),
        scratch_shapes=[pltpu.VMEM((wb,), jnp.float32)],
        interpret=interpret,
    )(a, b)
