"""Streaming telemetry: server-push subscriptions over chunked HTTP.

The long-poll cursor (``GET /v1/telemetry``) is correct but chatty: a
parent plane following N children burns N polling cursors, each costing a
request per poll round even when nothing happened.  ``GET /v1/stream``
replaces that with ONE long-lived chunked-HTTP response per subscription:
the gateway pushes newline-delimited JSON events (ndjson) as they happen,
each carrying the same monotonically-increasing ``seq`` as the cursor log —
so delivery is loss-auditable (gapless seq = zero lost events) and a broken
stream resumes exactly where it stopped by passing the last seq back as
``cursor``.

Per-subscription filters select what crosses the wire:

==============  =============================================================
query param     semantics
==============  =============================================================
resources       comma-separated resource ids (default: all)
kinds           comma-separated event kinds — result, health, lifecycle,
                breaker, registry, drift, twin_shadow, twin_serve,
                twin_speculation (default: all)
min_severity    debug | info | warning | error (default: debug = everything)
cursor          seq to resume after (default: now — only new events)
heartbeat_s     idle heartbeat interval (bounded 0.2–30 s, default 10)
==============  =============================================================

Severity is derived per event (:func:`event_severity`): breaker openings
and failed health snapshots are ``error``, degradations / drift / rejected
results are ``warning``, routine results and registry changes are ``info``,
lifecycle chatter is ``debug`` — so a cloud plane can follow a whole child
fleet at ``min_severity=warning`` and receive almost nothing until
something is actually wrong.

Control lines (``{"stream": "hello" | "heartbeat" | "end", ...}``) frame
the event flow: ``hello`` carries the plane identity and starting cursor,
heartbeats prove liveness through idle stretches, ``end`` announces an
orderly close (a vanished connection with no ``end`` means the plane died).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Callable, Dict, FrozenSet, Optional

from repro.gateway.protocol import dumps as wire_dumps

#: severity ladder, least to most severe
SEVERITIES = ("debug", "info", "warning", "error")
_RANK = {s: i for i, s in enumerate(SEVERITIES)}


def severity_rank(severity: str) -> int:
    """Rank of a severity label (unknown labels rank as ``info``)."""
    return _RANK.get(severity, _RANK["info"])


def event_severity(kind: str, fields: Dict) -> str:
    """Derive one event's severity from its kind + payload.  Keep in sync
    with the module-docstring table (it is the wire contract)."""
    if kind == "lifecycle":
        return "debug"
    if kind == "breaker":
        to = fields.get("to")
        if to == "open":
            return "error"
        if to in ("degraded", "probation"):
            return "warning"
        return "info"
    if kind == "health":
        if (fields.get("health_status") == "failed"
                or fields.get("readiness") == "down"):
            return "error"
        if fields.get("health_status") == "degraded":
            return "warning"
        return "info"
    if kind == "drift":
        return "warning"
    if kind == "result":
        return "info" if fields.get("status") == "completed" else "warning"
    if kind == "twin_serve":
        # a twin serving means real hardware was NOT: worth noticing
        return "warning"
    return "info"


@dataclasses.dataclass(frozen=True)
class StreamFilter:
    """Per-subscription event filter: resource ids, kinds, min severity.
    ``None`` fields pass everything; an empty set would pass nothing and is
    normalized to None at parse time."""

    resources: Optional[FrozenSet[str]] = None
    kinds: Optional[FrozenSet[str]] = None
    min_severity: str = "debug"

    def matches(self, entry: Dict) -> bool:
        if self.resources is not None \
                and entry.get("resource_id") not in self.resources:
            return False
        if self.kinds is not None and entry.get("kind") not in self.kinds:
            return False
        return severity_rank(entry.get("severity", "info")) \
            >= _RANK[self.min_severity]

    # -- wire forms -----------------------------------------------------------
    @staticmethod
    def _split(raw: Optional[str]) -> Optional[FrozenSet[str]]:
        if not raw:
            return None
        vals = frozenset(v.strip() for v in raw.split(",") if v.strip())
        return vals or None

    @classmethod
    def from_query(cls, q: Dict[str, str]) -> "StreamFilter":
        sev = (q.get("min_severity") or "debug").strip().lower()
        if sev not in _RANK:
            raise ValueError(
                f"min_severity must be one of {SEVERITIES}, got {sev!r}")
        return cls(resources=cls._split(q.get("resources")),
                   kinds=cls._split(q.get("kinds")),
                   min_severity=sev)

    def to_query(self) -> Dict[str, str]:
        q: Dict[str, str] = {}
        if self.resources is not None:
            q["resources"] = ",".join(sorted(self.resources))
        if self.kinds is not None:
            q["kinds"] = ",".join(sorted(self.kinds))
        if self.min_severity != "debug":
            q["min_severity"] = self.min_severity
        return q


# ---------------------------------------------------------------------------
# chunked-HTTP framing (server side)


def write_chunk(wfile, payload: bytes) -> None:
    """One HTTP/1.1 chunk, flushed immediately — a subscriber must see an
    event the moment it is written, not when a buffer fills."""
    wfile.write(f"{len(payload):X}\r\n".encode("ascii"))
    wfile.write(payload)
    wfile.write(b"\r\n")
    wfile.flush()


def end_chunks(wfile) -> None:
    wfile.write(b"0\r\n\r\n")
    wfile.flush()


def control_line(kind: str, **fields) -> bytes:
    return wire_dumps({"stream": kind, **fields}) + b"\n"


def event_line(entry: Dict) -> bytes:
    # protocol.dumps, not bare json.dumps: event fields may carry numpy
    # scalars/arrays (result telemetry) that the wire encoder normalizes
    return wire_dumps(entry) + b"\n"


# ---------------------------------------------------------------------------
# subscription reader (client side)


class StreamClosed(Exception):
    """The stream ended — orderly (``end`` control line seen) or not."""

    def __init__(self, message: str, orderly: bool):
        super().__init__(message)
        self.orderly = orderly


class TelemetryStream:
    """Iterator over one ``/v1/stream`` subscription.

    Yields event dicts (each carrying ``seq``, ``kind``, ``resource_id``,
    ``fields``, ``severity``); heartbeats are consumed silently (they
    update :attr:`cursor` so a resume never replays) unless
    ``include_control=True``.  ``cursor`` always holds the resume point —
    pass it to a new subscription after a disconnect and no event is lost
    or duplicated (the gateway's ring is the only bound).

    Context-manager friendly; :meth:`close` severs the connection (the
    server handler notices on its next write).
    """

    def __init__(self, conn, response, include_control: bool = False):
        self._conn = conn
        self._resp = response
        self.include_control = include_control
        self.cursor: int = 0
        self.plane_id: Optional[str] = None
        self.closed = False
        self.orderly_end = False

    def __enter__(self) -> "TelemetryStream":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self) -> None:
        if not self.closed:
            self.closed = True
            try:
                self._conn.close()
            except OSError:
                pass

    def __iter__(self):
        return self

    def __next__(self) -> Dict:
        while True:
            if self.closed:
                raise StopIteration
            try:
                line = self._resp.readline()
            except Exception as e:                         # noqa: BLE001
                self.close()
                raise StreamClosed(f"stream broken: {e!r}", orderly=False)
            if not line:
                self.close()
                if self.orderly_end:
                    raise StopIteration
                raise StreamClosed("stream connection lost", orderly=False)
            line = line.strip()
            if not line:
                continue
            try:
                obj = json.loads(line)
            except json.JSONDecodeError:
                continue                  # torn line mid-close; skip
            ctl = obj.get("stream")
            if ctl is not None:
                if "cursor" in obj:
                    self.cursor = max(self.cursor, int(obj["cursor"]))
                if "plane_id" in obj:
                    self.plane_id = obj["plane_id"]
                if ctl == "end":
                    self.orderly_end = True
                    self.close()
                    raise StopIteration
                if self.include_control:
                    return obj
                continue
            self.cursor = max(self.cursor, int(obj.get("seq", 0)))
            return obj

    def events(self, limit: Optional[int] = None):
        """Bounded convenience iterator: up to ``limit`` events."""
        n = 0
        for ev in self:
            yield ev
            n += 1
            if limit is not None and n >= limit:
                return


#: type of the server-side per-entry filter hook the cursor log accepts
EntryPredicate = Callable[[Dict], bool]
