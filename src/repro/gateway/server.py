"""ControlPlaneGateway: the phys-MCP control plane behind a wire API.

Exposes an :class:`~repro.core.orchestrator.Orchestrator` (plus a
:class:`~repro.core.scheduler.ControlPlaneScheduler` worker pool for the
async paths) over loopback-style HTTP, using the same threaded
``ThreadingHTTPServer`` idiom as ``repro.substrates.http_fast.FastService``.
Every capability that was previously reachable only as an in-process Python
call — discover, describe, invoke, batched/async submission, telemetry,
health, twin state — becomes a versioned protocol-v1 endpoint:

========  ======================  =============================================
method    path                    semantics
========  ======================  =============================================
GET       /v1/health              plane health: snapshots, breakers, scheduler
GET       /v1/discover            capability discovery (query-param filters)
GET       /v1/describe/<rid>      one resource: descriptor + snapshot + twin
GET       /v1/twin/<rid>          twin-plane state for one resource
POST      /v1/invoke              synchronous submit → (result, trace)
POST      /v1/submit              async submit → ticket (scheduler future)
POST      /v1/submit_many         batched async submit → tickets
GET       /v1/poll/<ticket>       poll/await an async ticket
GET       /v1/telemetry           long-poll cursor over the TelemetryBus
GET       /v1/stream              server-push telemetry subscription
                                  (chunked ndjson, per-subscription filters
                                  — see ``repro.gateway.stream``)
GET       /v1/topology            plane identity + federation reachability
========  ======================  =============================================

Rejections travel as structured :class:`~repro.core.errors.WireError`
envelopes (taxonomy code + prose + full trace in ``detail``), never as bare
strings — see ``repro.gateway.protocol``.  ``QUEUE_SATURATED`` rejections
additionally carry a ``retry_after_s`` backoff hint derived from live
scheduler stats, so remote clients back off informed instead of hammering.

Wire auth (optional): constructing the gateway with ``api_keys={key:
tenant}`` requires every request to carry ``Authorization: Bearer <key>``;
unknown or missing credentials get a structured ``UNAUTHORIZED`` envelope,
and the authenticated tenant OVERRIDES the task's wire ``tenant`` field —
policy's ``authorized_tenants`` then constrains what each plane credential
may touch, instead of trusting whatever tenant the client typed.
"""
from __future__ import annotations

import itertools
import socket
import threading
import time
from collections import deque
from concurrent.futures import Future, TimeoutError as FutureTimeout
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Dict, List, Optional, Tuple
from urllib.parse import parse_qs, urlparse

from repro.core.errors import ControlPlaneError, ErrorCode, WireError
from repro.core.orchestrator import Orchestrator
from repro.core.scheduler import ControlPlaneScheduler, SchedulerClosed
from repro.core.telemetry import TelemetryEvent
from repro.gateway import protocol as wire
from repro.gateway import stream as streaming

_ticket_ids = itertools.count(1)


class TelemetryCursorLog:
    """Cursor-addressable view of the TelemetryBus for remote subscribers.

    The in-process bus pushes to callables; a wire client can't hold a
    callable across HTTP, so the gateway appends every event to a bounded
    sequence-numbered log and clients long-poll ``read(cursor)`` — each
    response carries ``next_cursor``, so a client resumes exactly where it
    left off (missed events are only possible after falling more than
    ``capacity`` events behind, which the response makes visible via
    ``dropped``).

    The ring bounds gateway memory whatever a poller does: a slow or dead
    subscriber costs at most ``capacity`` retained entries, never unbounded
    growth.  Lifetime evictions are counted (``dropped_events`` in every
    response), so a client can tell "nothing happened" apart from "events
    existed but aged out of the ring before anyone read them"."""

    def __init__(self, bus, capacity: int = 4096):
        self.capacity = capacity
        self._bus = bus
        # deque(maxlen): O(1) append+evict on the bus emit path (a full
        # list would re-copy capacity entries on every event once full)
        self._events: "deque[Tuple[int, Dict]]" = deque(maxlen=capacity)
        self._next_seq = 1
        self._dropped_events = 0        # lifetime ring evictions
        self._closed = False
        self._cond = threading.Condition()
        bus.subscribe(self._on_event)

    def close(self) -> None:
        """Detach from the bus and release blocked long-polls (the bus —
        and its plane — outlive this gateway's wire frontend)."""
        self._bus.unsubscribe(self._on_event)
        with self._cond:
            self._closed = True
            self._cond.notify_all()

    def _on_event(self, ev: TelemetryEvent) -> None:
        entry = {"resource_id": ev.resource_id, "kind": ev.kind,
                 "fields": dict(ev.fields), "timestamp": ev.timestamp,
                 "severity": streaming.event_severity(ev.kind, ev.fields)}
        with self._cond:
            if self._closed:
                return
            entry["seq"] = self._next_seq
            if len(self._events) == self.capacity:
                self._dropped_events += 1      # deque evicts on append
            self._events.append((self._next_seq, entry))
            self._next_seq += 1
            self._cond.notify_all()

    @property
    def closed(self) -> bool:
        with self._cond:
            return self._closed

    def tail(self) -> int:
        """Seq of the newest event (a subscription starting here sees only
        what happens next)."""
        with self._cond:
            return self._next_seq - 1

    def dropped_events(self) -> int:
        with self._cond:
            return self._dropped_events

    def read(self, cursor: int, timeout_s: float = 0.0, limit: int = 256,
             resource: Optional[str] = None,
             match: Optional["streaming.EntryPredicate"] = None) -> Dict:
        """Events with seq > cursor (optionally filtered by resource and/or
        an entry predicate — stream subscriptions pass their
        :class:`~repro.gateway.stream.StreamFilter` here); blocks up to
        ``timeout_s`` when none MATCH yet (long-poll).  Filtered-out events
        are consumed silently — they advance the returned cursor but never
        cut the wait short, so a filtered long-poll on a busy plane stays a
        long-poll instead of degenerating into a tight request loop."""
        deadline = time.monotonic() + max(0.0, timeout_s)
        with self._cond:
            while True:
                dropped = 0
                if self._events and self._events[0][0] > cursor + 1:
                    dropped = self._events[0][0] - cursor - 1
                newer = [e for seq, e in self._events if seq > cursor
                         and (resource is None
                              or e["resource_id"] == resource)
                         and (match is None or match(e))]
                if newer:
                    batch = newer[:limit]
                    tail = self._next_seq - 1
                    return {
                        "events": batch,
                        # consumed through the last returned match, plus any
                        # trailing filtered-out events when the batch is
                        # complete (so the next poll skips them)
                        "next_cursor": (batch[-1]["seq"] if len(batch)
                                        < len(newer) else max(batch[-1]["seq"],
                                                              tail)),
                        "dropped": dropped,
                        "dropped_events": self._dropped_events,
                        "closed": self._closed,
                    }
                # nothing matches: everything past the cursor (if anything)
                # was filtered out — consume it and keep waiting
                cursor = max(cursor, self._next_seq - 1)
                remaining = deadline - time.monotonic()
                if remaining <= 0 or self._closed:
                    return {"events": [], "next_cursor": cursor,
                            "dropped": dropped,
                            "dropped_events": self._dropped_events,
                            "closed": self._closed}
                self._cond.wait(timeout=remaining)


class _Handler(BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.1"
    # loopback latency hygiene: fully buffer the response (headers + body
    # leave in one segment) and disable Nagle so small control-plane
    # messages are not held hostage to delayed ACKs — together worth
    # several ms per call on the wire control path (bench_gateway)
    wbufsize = -1
    disable_nagle_algorithm = True

    # -- plumbing -------------------------------------------------------------
    @property
    def gateway(self) -> "ControlPlaneGateway":
        return self.server.gateway

    def _send(self, status: int, envelope: Dict) -> None:
        body = wire.dumps(envelope)
        self.send_response(status)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _send_ok(self, kind: str, body: Dict) -> None:
        self._send(200, wire.ok_envelope(kind, body,
                                         plane_id=self.gateway.plane_id))

    def _send_error(self, kind: str, err: WireError) -> None:
        self._send(wire.http_status(err.code),
                   wire.error_envelope(kind, err,
                                       plane_id=self.gateway.plane_id))

    def _read_body(self, expect_kind: str) -> Dict:
        length = int(self.headers.get("Content-Length", 0))
        envelope = wire.loads(self.rfile.read(length))
        return wire.parse_request(envelope, expect_kind=expect_kind)

    def _dispatch(self, kind: str, fn) -> None:
        try:
            # wire auth runs before ANY route logic; the mapped tenant (or
            # None on an open gateway) is what task submission trusts
            self.tenant = self.gateway.authenticate(self.headers)
            fn()
        except ControlPlaneError as e:
            self._send_error(kind, WireError(e.code, e.message, e.detail))
        except (BrokenPipeError, ConnectionResetError):
            pass                       # client went away mid-response
        except Exception as e:         # noqa: BLE001 — wire boundary
            self._send_error(kind, WireError(ErrorCode.INTERNAL, repr(e)))

    def log_message(self, *args):  # quiet
        pass

    def handle_one_request(self):
        # severed keep-alive/stream connections (gateway stop, subscriber
        # gone) must not traceback out of the handler thread on the
        # response flush
        try:
            super().handle_one_request()
        except (BrokenPipeError, ConnectionResetError):
            self.close_connection = True

    def finish(self):
        # ... nor on the final buffer close
        try:
            super().finish()
        except (BrokenPipeError, ConnectionResetError, OSError):
            pass

    # -- routing --------------------------------------------------------------
    def do_GET(self):
        parts = wire.split_path(self.path)
        q = {k: v[-1] for k, v in
             parse_qs(urlparse(self.path).query).items()}
        if parts[:1] != ("v1",):
            return self._send_error("error", WireError(
                ErrorCode.NOT_FOUND, f"unknown path {self.path!r} "
                                     "(protocol v1 lives under /v1/)"))
        route = parts[1] if len(parts) > 1 else ""
        arg = parts[2] if len(parts) > 2 else None
        gw = self.gateway
        if route == "health":
            self._dispatch("health", lambda: self._send_ok(
                "health", gw.health_body()))
        elif route == "discover":
            self._dispatch("discover", lambda: self._send_ok(
                "discover", gw.discover_body(q)))
        elif route == "describe" and arg:
            self._dispatch("describe", lambda: self._send_ok(
                "describe", gw.describe_body(arg)))
        elif route == "twin" and arg:
            self._dispatch("twin", lambda: self._send_ok(
                "twin", gw.twin_body(arg)))
        elif route == "poll" and arg:
            self._dispatch("poll", lambda: gw.poll_into(self, arg, q))
        elif route == "telemetry":
            self._dispatch("telemetry", lambda: self._send_ok(
                "telemetry", gw.telemetry_body(q)))
        elif route == "stream":
            self._dispatch("stream", lambda: gw.stream_into(self, q))
        elif route == "topology":
            self._dispatch("topology", lambda: self._send_ok(
                "topology", gw.topology_body()))
        else:
            self._send_error("error", WireError(
                ErrorCode.NOT_FOUND, f"unknown route {self.path!r}"))

    def do_POST(self):
        parts = wire.split_path(self.path)
        route = parts[1] if len(parts) > 1 and parts[0] == "v1" else ""
        gw = self.gateway
        if route == "invoke":
            self._dispatch("invoke", lambda: gw.invoke_into(
                self, self._read_body("invoke"), tenant=self.tenant))
        elif route == "submit":
            self._dispatch("submit", lambda: self._send_ok(
                "submit", gw.submit_body(self._read_body("submit"),
                                         tenant=self.tenant)))
        elif route == "submit_many":
            self._dispatch("submit_many", lambda: self._send_ok(
                "submit_many",
                gw.submit_many_body(self._read_body("submit_many"),
                                    tenant=self.tenant)))
        else:
            self._send_error("error", WireError(
                ErrorCode.NOT_FOUND, f"unknown route {self.path!r}"))


class _GatewayServer(ThreadingHTTPServer):
    """ThreadingHTTPServer that tracks accepted connections so ``stop()``
    can sever live keep-alive clients: ``shutdown()`` only stops the accept
    loop, and a handler thread parked on a persistent connection would keep
    answering a "dead" plane — breaking the federation failure semantics
    (a killed edge gateway must LOOK killed to its cloud parent)."""

    daemon_threads = True

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        self._conns: set = set()
        self._conns_lock = threading.Lock()

    def get_request(self):
        request, addr = super().get_request()
        with self._conns_lock:
            self._conns.add(request)
        return request, addr

    def shutdown_request(self, request):
        with self._conns_lock:
            self._conns.discard(request)
        super().shutdown_request(request)

    def close_all_connections(self) -> None:
        with self._conns_lock:
            conns = list(self._conns)
            self._conns.clear()
        for s in conns:
            try:
                s.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                s.close()
            except OSError:
                pass


class ControlPlaneGateway:
    """Threaded HTTP front-end over one control plane (one Orchestrator +
    one scheduler worker pool + one telemetry cursor log).

        gw = ControlPlaneGateway(orch, plane="edge").start()
        ... ControlPlaneClient(gw.url) ...
        gw.stop()

    A gateway OWNS its scheduler unless one is passed in; ``stop()`` shuts
    down what it owns and leaves the orchestrator itself alone (planes
    outlive their wire frontends)."""

    def __init__(self, orchestrator: Orchestrator, port: int = 0,
                 plane: str = "plane", workers: int = 8,
                 scheduler: Optional[ControlPlaneScheduler] = None,
                 api_keys: Optional[Dict[str, str]] = None,
                 telemetry_capacity: int = 4096):
        self.orchestrator = orchestrator
        self.plane = plane
        # the gateway names the plane; the orchestrator owns its identity
        self.topology = orchestrator.topology
        self.topology.set_name(plane)
        #: optional wire auth: api key -> tenant it authenticates as
        self.api_keys = dict(api_keys) if api_keys else None
        self._owns_scheduler = scheduler is None
        self.scheduler = scheduler or ControlPlaneScheduler(
            orchestrator, workers=workers)
        self.telemetry_log = TelemetryCursorLog(orchestrator.bus,
                                                capacity=telemetry_capacity)
        self._tickets: Dict[str, Future] = {}
        self._tickets_lock = threading.Lock()
        self._started_at = time.time()
        self.server = _GatewayServer(("127.0.0.1", port), _Handler)
        self.server.gateway = self
        self.port = self.server.server_address[1]
        self._thread = threading.Thread(target=self.server.serve_forever,
                                        daemon=True,
                                        name=f"phys-mcp-gateway-{self.plane}")

    # -- lifecycle ------------------------------------------------------------
    def start(self) -> "ControlPlaneGateway":
        self._thread.start()
        return self

    def stop(self) -> None:
        self.server.shutdown()
        self.server.close_all_connections()
        self.server.server_close()
        self.telemetry_log.close()
        if self._owns_scheduler:
            self.scheduler.shutdown(wait=False)

    @property
    def url(self) -> str:
        return f"http://127.0.0.1:{self.port}"

    @property
    def plane_id(self) -> str:
        return self.topology.plane_id

    # -- wire auth ------------------------------------------------------------
    def authenticate(self, headers) -> Optional[str]:
        """Map the request's Bearer credential onto its tenant.  Open
        gateway (no ``api_keys``): returns None, wire ``tenant`` field is
        trusted as before.  Keyed gateway: missing/unknown credentials are
        a structured ``UNAUTHORIZED`` refusal."""
        if not self.api_keys:
            return None
        auth = headers.get("Authorization", "") or ""
        if auth.startswith("Bearer "):
            tenant = self.api_keys.get(auth[len("Bearer "):].strip())
            if tenant is not None:
                return tenant
        raise ControlPlaneError(
            ErrorCode.UNAUTHORIZED,
            "missing or unknown plane credentials "
            "(this gateway requires 'Authorization: Bearer <api-key>')",
            {"plane": self.plane})

    # -- endpoint bodies ------------------------------------------------------
    def health_body(self) -> Dict:
        orch = self.orchestrator
        resources = {}
        for desc in orch.registry.all():
            snap = orch.bus.snapshot(desc.resource_id)
            resources[desc.resource_id] = (
                wire.snapshot_to_wire(snap) if snap is not None else None)
        breakers = None
        if orch.health is not None and hasattr(orch.health, "status"):
            try:
                breakers = orch.health.status()
            except Exception:                              # noqa: BLE001
                breakers = None
        return {
            "plane": self.plane,
            "uptime_s": round(time.time() - self._started_at, 3),
            "resources": resources,
            "breakers": breakers,
            "scheduler": {"pending": self.scheduler.pending},
        }

    def discover_body(self, q: Dict[str, str]) -> Dict:
        filters = {k: q[k] for k in ("function", "input_modality",
                                     "output_modality", "latency_regime",
                                     "substrate_class") if k in q}
        if "repeated" in q:
            filters["repeated"] = q["repeated"].lower() in ("1", "true")
        descs = self.orchestrator.discover(**filters)
        return {"descriptors": [wire.descriptor_to_wire(d) for d in descs]}

    def _descriptor_or_404(self, rid: str):
        desc = self.orchestrator.registry.get(rid)
        if desc is None:
            raise ControlPlaneError(ErrorCode.NOT_FOUND,
                                    f"no such resource {rid!r}")
        return desc

    def describe_body(self, rid: str) -> Dict:
        desc = self._descriptor_or_404(rid)
        snap = self.orchestrator.bus.snapshot(rid)
        twin = self.orchestrator.twins.get(rid)
        return {
            "descriptor": wire.descriptor_to_wire(desc),
            "snapshot": wire.snapshot_to_wire(snap) if snap else None,
            "twin": twin.to_dict() if twin is not None else None,
        }

    def twin_body(self, rid: str) -> Dict:
        self._descriptor_or_404(rid)
        twin = self.orchestrator.twins.get(rid)
        if twin is None:
            raise ControlPlaneError(ErrorCode.NOT_FOUND,
                                    f"resource {rid!r} has no twin binding")
        return {"twin": twin.to_dict()}

    @staticmethod
    def _q_num(q: Dict[str, str], key: str, default, cast):
        """Numeric query param or a structured BAD_REQUEST (a typo'd
        cursor must not surface as INTERNAL)."""
        try:
            return cast(q.get(key, default))
        except (TypeError, ValueError):
            raise wire.ProtocolError(
                f"query param {key!r} must be a number, got {q.get(key)!r}")

    def telemetry_body(self, q: Dict[str, str]) -> Dict:
        cursor = self._q_num(q, "cursor", 0, int)
        timeout_s = min(self._q_num(q, "timeout_s", 0.0, float), 30.0)
        limit = max(1, min(self._q_num(q, "limit", 256, int), 1024))
        try:
            filt = streaming.StreamFilter.from_query(q)
        except ValueError as e:
            raise wire.ProtocolError(str(e))
        body = self.telemetry_log.read(
            cursor, timeout_s=timeout_s, limit=limit,
            resource=q.get("resource"), match=filt.matches)
        body.pop("closed", None)      # stream-loop detail, not wire surface
        return body

    def topology_body(self) -> Dict:
        body = self.topology.to_dict()
        body["plane"] = self.plane
        body["registry_epoch"] = self.orchestrator.registry.epoch
        body["resources"] = len(self.orchestrator.registry.all())
        return body

    # -- streaming subscriptions ----------------------------------------------
    #: heartbeat interval bounds (s): floor keeps idle subscriptions cheap,
    #: ceiling bounds how long a silently-dead plane can look alive
    MIN_HEARTBEAT_S, MAX_HEARTBEAT_S = 0.2, 30.0

    def stream_into(self, handler: _Handler, q: Dict[str, str]) -> None:
        """One server-push subscription: chunked ndjson over the open
        response.  Events come from the same sequence-numbered ring the
        cursor endpoint reads, so seq-gaplessness (zero lost events) and
        resume-by-cursor hold across both transports.  The loop runs until
        the client disconnects, the gateway stops, or ``max_s`` lapses."""
        try:
            filt = streaming.StreamFilter.from_query(q)
        except ValueError as e:
            raise wire.ProtocolError(str(e))
        cursor = self._q_num(q, "cursor", self.telemetry_log.tail(), int)
        heartbeat_s = min(max(self._q_num(q, "heartbeat_s", 10.0, float),
                              self.MIN_HEARTBEAT_S), self.MAX_HEARTBEAT_S)
        max_s = self._q_num(q, "max_s", 0.0, float)
        deadline = (time.monotonic() + max_s) if max_s > 0 else None
        # a streamed connection never goes back into keep-alive rotation:
        # if the loop exits abnormally the framing state is undefined
        handler.close_connection = True
        handler.send_response(200)
        handler.send_header("Content-Type", "application/x-ndjson")
        handler.send_header("Cache-Control", "no-cache")
        handler.send_header("Transfer-Encoding", "chunked")
        handler.end_headers()
        w = handler.wfile
        try:
            streaming.write_chunk(w, streaming.control_line(
                "hello", plane_id=self.plane_id, plane=self.plane,
                cursor=cursor, protocol_version=wire.PROTOCOL_VERSION,
                registry_epoch=self.orchestrator.registry.epoch))
            if cursor == 0:
                # change-feed baseline: a from-the-beginning subscriber gets
                # the CURRENT fleet — synthetic register events plus each
                # member's stored health snapshot (seq 0 — they are state,
                # not history; the ring cannot serve this because resources
                # typically register before any gateway exists).  Baseline +
                # live updates = a consistent feed with no re-fetch.
                epoch = self.orchestrator.registry.epoch
                for desc in self.orchestrator.registry.all():
                    entry = {"resource_id": desc.resource_id,
                             "kind": "registry", "seq": 0,
                             "timestamp": time.time(), "severity": "info",
                             "fields": {"action": "register", "epoch": epoch,
                                        "plane_id": self.plane_id,
                                        "descriptor": desc.to_dict(),
                                        "baseline": True}}
                    if filt.matches(entry):
                        streaming.write_chunk(w, streaming.event_line(entry))
                    snap = self.orchestrator.bus.snapshot(desc.resource_id)
                    if snap is None:
                        continue
                    fields = dict(snap.to_dict(), baseline=True)
                    entry = {"resource_id": desc.resource_id,
                             "kind": "health", "seq": 0,
                             "timestamp": time.time(),
                             "severity": streaming.event_severity("health",
                                                                  fields),
                             "fields": fields}
                    if filt.matches(entry):
                        streaming.write_chunk(w, streaming.event_line(entry))
            while True:
                timeout = heartbeat_s
                if deadline is not None:
                    timeout = min(timeout, max(0.0,
                                               deadline - time.monotonic()))
                out = self.telemetry_log.read(
                    cursor, timeout_s=timeout, limit=256, match=filt.matches)
                cursor = out["next_cursor"]
                for entry in out["events"]:
                    streaming.write_chunk(w, streaming.event_line(entry))
                if out["closed"] or (deadline is not None
                                     and time.monotonic() >= deadline):
                    streaming.write_chunk(w, streaming.control_line(
                        "end", cursor=cursor,
                        dropped_events=out["dropped_events"]))
                    streaming.end_chunks(w)
                    return
                if not out["events"]:
                    streaming.write_chunk(w, streaming.control_line(
                        "heartbeat", cursor=cursor,
                        dropped_events=out["dropped_events"]))
        except (BrokenPipeError, ConnectionResetError, OSError):
            pass                       # subscriber went away; nothing to do

    # -- execution ------------------------------------------------------------
    #: resolved tickets retained for polling before eviction (FIFO)
    MAX_TICKETS = 1024

    def _submit(self, body: Dict, tenant: Optional[str] = None) -> Future:
        try:
            task = wire.task_from_wire(body.get("task") or {})
        except (TypeError, ValueError, KeyError) as e:
            # a task body the dataclass refuses is the CLIENT's error, not a
            # retryable server fault
            raise wire.ProtocolError(f"malformed task body: {e!r}")
        if tenant is not None and task.tenant != tenant:
            # authenticated identity beats whatever tenant the wire claimed
            task = task.clone(tenant=tenant)
        deadline_s = body.get("deadline_s")
        try:
            return self.scheduler.submit_async(task, deadline_s=deadline_s)
        except SchedulerClosed as e:
            raise ControlPlaneError(ErrorCode.PLANE_UNAVAILABLE, str(e))

    def _respond_outcome(self, handler: _Handler, kind: str,
                         result, trace) -> None:
        """Completed results ride an ok envelope; anything else becomes the
        structured error envelope carrying code + trace (saturation errors
        additionally carry the live ``retry_after_s`` backoff hint)."""
        if result.status == "completed":
            handler._send_ok(kind, {
                "result": wire.result_to_wire(result),
                "trace": wire.trace_to_wire(trace),
            })
        else:
            err = wire.rejection_to_error(result, trace)
            if err.code is ErrorCode.QUEUE_SATURATED:
                err.detail["retry_after_s"] = self.scheduler.retry_after_s()
            handler._send_error(kind, err)

    def invoke_into(self, handler: _Handler, body: Dict,
                    tenant: Optional[str] = None) -> None:
        result, trace = self._submit(body, tenant=tenant).result()
        self._respond_outcome(handler, "invoke", result, trace)

    def _store_ticket(self, fut: Future) -> str:
        ticket = f"ticket-{next(_ticket_ids):06d}"
        with self._tickets_lock:
            self._tickets[ticket] = fut
            # bound the store: evict the OLDEST RESOLVED tickets first (a
            # never-polled resolved future would otherwise retain its full
            # result forever); pending futures are only evicted when the
            # store is flooded with them
            while len(self._tickets) > self.MAX_TICKETS:
                victim = next((t for t, f in self._tickets.items()
                               if f.done()), None)
                if victim is None:
                    victim = next(iter(self._tickets))
                del self._tickets[victim]
        return ticket

    def submit_body(self, body: Dict, tenant: Optional[str] = None) -> Dict:
        return {"ticket": self._store_ticket(self._submit(body,
                                                          tenant=tenant))}

    def submit_many_body(self, body: Dict,
                         tenant: Optional[str] = None) -> Dict:
        tasks = body.get("tasks")
        if not isinstance(tasks, list):
            raise wire.ProtocolError("submit_many body needs a tasks list")
        deadline_s = body.get("deadline_s")
        # validate the WHOLE batch before queueing any of it: a malformed
        # task mid-list must not leave earlier tasks running on hardware
        # with their tickets never returned to the client
        parsed = []
        for i, t in enumerate(tasks):
            try:
                parsed.append(wire.task_from_wire(t or {}))
            except (TypeError, ValueError, KeyError) as e:
                raise wire.ProtocolError(
                    f"malformed task at index {i}: {e!r}")
        if tenant is not None:
            parsed = [t if t.tenant == tenant else t.clone(tenant=tenant)
                      for t in parsed]
        tickets = []
        for task in parsed:
            try:
                fut = self.scheduler.submit_async(task,
                                                  deadline_s=deadline_s)
            except SchedulerClosed as e:
                raise ControlPlaneError(ErrorCode.PLANE_UNAVAILABLE, str(e))
            tickets.append(self._store_ticket(fut))
        return {"tickets": tickets}

    def poll_into(self, handler: _Handler, ticket: str,
                  q: Dict[str, str]) -> None:
        with self._tickets_lock:
            fut = self._tickets.get(ticket)
        if fut is None:
            raise ControlPlaneError(ErrorCode.NOT_FOUND,
                                    f"unknown ticket {ticket!r}")
        wait_s = min(self._q_num(q, "wait_s", 0.0, float), 30.0)
        try:
            result, trace = fut.result(timeout=wait_s if wait_s > 0 else 0.001)
        except FutureTimeout:
            handler._send_ok("poll", {"state": "pending", "ticket": ticket})
            return
        except BaseException:
            # exception-resolved future: release the ticket (every re-poll
            # would re-raise forever) and surface the error once
            with self._tickets_lock:
                self._tickets.pop(ticket, None)
            raise
        # deliver-once, but only release AFTER the response bytes went out:
        # a client that disconnects mid-response can re-poll and still get
        # its result (a popped-early ticket would lose a completed task)
        self._respond_outcome(handler, "poll", result, trace)
        with self._tickets_lock:
            self._tickets.pop(ticket, None)
