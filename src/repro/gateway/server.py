"""ControlPlaneGateway: the phys-MCP control plane behind a wire API.

Exposes an :class:`~repro.core.orchestrator.Orchestrator` (plus a
:class:`~repro.core.scheduler.ControlPlaneScheduler` worker pool for the
async paths) over loopback-style HTTP.  Every capability that was
previously reachable only as an in-process Python call — discover,
describe, invoke, batched/async submission, telemetry, health, twin state
— becomes a versioned protocol-v1 endpoint:

========  ======================  =============================================
method    path                    semantics
========  ======================  =============================================
GET       /v1/health              plane health: snapshots, breakers, scheduler
GET       /v1/discover            capability discovery (query-param filters)
GET       /v1/describe/<rid>      one resource: descriptor + snapshot + twin
GET       /v1/twin/<rid>          twin-plane state for one resource
POST      /v1/invoke              synchronous submit → (result, trace)
POST      /v1/submit              async submit → ticket (scheduler future)
POST      /v1/submit_many         batched async submit → tickets (atomic)
POST      /v1/submit_coalesced    batched submit, per-entry outcomes (v1.2)
POST      /v1/poll_coalesced      batched ticket poll, one round-trip (v1.2)
GET       /v1/poll/<ticket>       poll/await an async ticket
GET       /v1/telemetry           long-poll cursor over the TelemetryBus
GET       /v1/stream              server-push telemetry subscription
                                  (chunked ndjson, per-subscription filters
                                  — see ``repro.gateway.stream``)
GET       /v1/topology            plane identity + federation reachability
========  ======================  =============================================

**Wire path (v1.2):** the server is a single-threaded ``selectors`` event
loop — non-blocking accept/read/write, connection multiplexing, and
per-connection write buffers — so one process sustains thousands of
concurrent keep-alive clients instead of one OS thread each.  The loop
thread only ever parses requests and moves bytes; endpoint handlers either
answer inline (the read surface) or register completion callbacks
(invoke/poll ride scheduler futures, telemetry long-polls ride cursor-log
listeners, ``/v1/stream`` gets a dedicated writer thread that enqueues
chunks through the loop).  Each request's responder is claim-once, so a
future callback and its timeout timer can race without double-sending.

Envelopes are content-negotiated per request: ``Content-Type`` selects the
request codec, ``Accept`` the response codec — JSON (protocol v1.1
unchanged on the wire) or the compact binary framing from
``repro.gateway.protocol`` (``application/x-physmcp``).

Rejections travel as structured :class:`~repro.core.errors.WireError`
envelopes (taxonomy code + prose + full trace in ``detail``), never as bare
strings — see ``repro.gateway.protocol``.  ``QUEUE_SATURATED`` rejections
additionally carry a ``retry_after_s`` backoff hint derived from live
scheduler stats, so remote clients back off informed instead of hammering.

Wire auth (optional): constructing the gateway with ``api_keys={key:
tenant}`` requires every request to carry ``Authorization: Bearer <key>``;
unknown or missing credentials get a structured ``UNAUTHORIZED`` envelope,
and the authenticated tenant OVERRIDES the task's wire ``tenant`` field —
policy's ``authorized_tenants`` then constrains what each plane credential
may touch, instead of trusting whatever tenant the client typed.
"""
from __future__ import annotations

import heapq
import itertools
import selectors
import socket
import threading
import time
from collections import deque
from concurrent.futures import Future
from http.client import responses as _REASONS
from typing import Callable, Dict, List, Optional, Tuple
from urllib.parse import parse_qs, urlparse

from repro.core.errors import ControlPlaneError, ErrorCode, WireError
from repro.core.orchestrator import Orchestrator
from repro.core.scheduler import ControlPlaneScheduler, SchedulerClosed
from repro.core.telemetry import TelemetryEvent
from repro.gateway import protocol as wire
from repro.gateway import stream as streaming

_ticket_ids = itertools.count(1)


class TelemetryCursorLog:
    """Cursor-addressable view of the TelemetryBus for remote subscribers.

    The in-process bus pushes to callables; a wire client can't hold a
    callable across HTTP, so the gateway appends every event to a bounded
    sequence-numbered log and clients long-poll ``read(cursor)`` — each
    response carries ``next_cursor``, so a client resumes exactly where it
    left off (missed events are only possible after falling more than
    ``capacity`` events behind, which the response makes visible via
    ``dropped``).

    The ring bounds gateway memory whatever a poller does: a slow or dead
    subscriber costs at most ``capacity`` retained entries, never unbounded
    growth.  Lifetime evictions are counted (``dropped_events`` in every
    response), so a client can tell "nothing happened" apart from "events
    existed but aged out of the ring before anyone read them".

    Two wait styles: blocking ``read(cursor, timeout_s=...)`` for caller
    threads (stream subscriptions), and ``add_listener`` for the event-loop
    server's parked long-polls — listeners are poked once per append (and
    once on close) WITHOUT anyone holding a thread on the wait."""

    def __init__(self, bus, capacity: int = 4096):
        self.capacity = capacity
        self._bus = bus
        # deque(maxlen): O(1) append+evict on the bus emit path (a full
        # list would re-copy capacity entries on every event once full)
        self._events: "deque[Tuple[int, Dict]]" = deque(maxlen=capacity)
        self._next_seq = 1
        self._dropped_events = 0        # lifetime ring evictions
        self._closed = False
        self._cond = threading.Condition()
        self._listeners: List[Callable[[], None]] = []
        bus.subscribe(self._on_event)

    def close(self) -> None:
        """Detach from the bus and release blocked long-polls (the bus —
        and its plane — outlive this gateway's wire frontend)."""
        self._bus.unsubscribe(self._on_event)
        with self._cond:
            self._closed = True
            self._cond.notify_all()
            listeners = list(self._listeners)
        for cb in listeners:            # outside the lock: they re-enter read
            try:
                cb()
            except Exception:                              # noqa: BLE001
                pass

    def add_listener(self, cb: Callable[[], None]) -> None:
        """Register a no-argument callable poked after every append (and on
        close).  Callbacks run on the EMITTING thread, outside the log lock
        — they may call ``read`` but must not block."""
        with self._cond:
            self._listeners.append(cb)

    def remove_listener(self, cb: Callable[[], None]) -> None:
        with self._cond:
            try:
                self._listeners.remove(cb)
            except ValueError:
                pass

    def _on_event(self, ev: TelemetryEvent) -> None:
        entry = {"resource_id": ev.resource_id, "kind": ev.kind,
                 "fields": dict(ev.fields), "timestamp": ev.timestamp,
                 "severity": streaming.event_severity(ev.kind, ev.fields)}
        with self._cond:
            if self._closed:
                return
            entry["seq"] = self._next_seq
            if len(self._events) == self.capacity:
                self._dropped_events += 1      # deque evicts on append
            self._events.append((self._next_seq, entry))
            self._next_seq += 1
            self._cond.notify_all()
            listeners = list(self._listeners)
        for cb in listeners:
            try:
                cb()
            except Exception:                              # noqa: BLE001
                pass

    @property
    def closed(self) -> bool:
        with self._cond:
            return self._closed

    def tail(self) -> int:
        """Seq of the newest event (a subscription starting here sees only
        what happens next)."""
        with self._cond:
            return self._next_seq - 1

    def dropped_events(self) -> int:
        with self._cond:
            return self._dropped_events

    def read(self, cursor: int, timeout_s: float = 0.0, limit: int = 256,
             resource: Optional[str] = None,
             match: Optional["streaming.EntryPredicate"] = None) -> Dict:
        """Events with seq > cursor (optionally filtered by resource and/or
        an entry predicate — stream subscriptions pass their
        :class:`~repro.gateway.stream.StreamFilter` here); blocks up to
        ``timeout_s`` when none MATCH yet (long-poll).  Filtered-out events
        are consumed silently — they advance the returned cursor but never
        cut the wait short, so a filtered long-poll on a busy plane stays a
        long-poll instead of degenerating into a tight request loop."""
        deadline = time.monotonic() + max(0.0, timeout_s)  # planelint: allow(clock-seam) — long-polls block real client sockets
        with self._cond:
            while True:
                dropped = 0
                if self._events and self._events[0][0] > cursor + 1:
                    dropped = self._events[0][0] - cursor - 1
                newer = [e for seq, e in self._events if seq > cursor
                         and (resource is None
                              or e["resource_id"] == resource)
                         and (match is None or match(e))]
                if newer:
                    batch = newer[:limit]
                    tail = self._next_seq - 1
                    return {
                        "events": batch,
                        # consumed through the last returned match, plus any
                        # trailing filtered-out events when the batch is
                        # complete (so the next poll skips them)
                        "next_cursor": (batch[-1]["seq"] if len(batch)
                                        < len(newer) else max(batch[-1]["seq"],
                                                              tail)),
                        "dropped": dropped,
                        "dropped_events": self._dropped_events,
                        "closed": self._closed,
                    }
                # nothing matches: everything past the cursor (if anything)
                # was filtered out — consume it and keep waiting
                cursor = max(cursor, self._next_seq - 1)
                remaining = deadline - time.monotonic()  # planelint: allow(clock-seam) — wire transport
                if remaining <= 0 or self._closed:
                    return {"events": [], "next_cursor": cursor,
                            "dropped": dropped,
                            "dropped_events": self._dropped_events,
                            "closed": self._closed}
                self._cond.wait(timeout=remaining)


# ---------------------------------------------------------------------------
# event-loop wire server


class _Headers(dict):
    """Header map with case-insensitive get (stored lower-cased)."""

    def get(self, key, default=None):                      # noqa: D102
        return dict.get(self, key.lower(), default)


def _parse_head(raw: bytes) -> Tuple[str, str, str, _Headers]:
    """``(method, path, version, headers)`` from a raw request head, or
    ``ValueError`` on anything that isn't a plain HTTP/1.x request."""
    lines = raw.split(b"\r\n")
    try:
        method, path, version = lines[0].decode("latin-1").split(" ", 2)
    except (UnicodeDecodeError, ValueError):
        raise ValueError("malformed request line")
    if not version.startswith("HTTP/1."):
        raise ValueError(f"unsupported protocol {version!r}")
    headers = _Headers()
    for line in lines[1:]:
        if not line:
            continue
        name, sep, value = line.partition(b":")
        if not sep:
            raise ValueError("malformed header line")
        headers[name.decode("latin-1").strip().lower()] = \
            value.decode("latin-1").strip()
    return method, path, version, headers


class _Conn:
    """Per-connection state owned by the loop thread: read buffer + parser
    position, pending write buffer, and the response-ordering flag that
    pauses request parsing while an earlier response is still owed."""

    __slots__ = ("sock", "fd", "rbuf", "wbuf", "events", "closed",
                 "close_after_write", "awaiting_response", "streaming",
                 "in_process", "head", "body_len", "lock")

    def __init__(self, sock: socket.socket):
        self.sock = sock
        self.fd = sock.fileno()
        #: serialises the off-loop direct-send fast path against close —
        #: without it a worker could write into a recycled fd
        self.lock = threading.Lock()
        self.rbuf = bytearray()
        self.wbuf = bytearray()
        self.events = selectors.EVENT_READ
        self.closed = False
        self.close_after_write = False
        #: a parsed request whose response hasn't been sent yet — further
        #: pipelined bytes stay buffered so responses keep request order
        self.awaiting_response = False
        #: chunked push mode: the connection belongs to its stream thread
        self.streaming = False
        self.in_process = False
        self.head: Optional[Tuple[str, str, _Headers, bool]] = None
        self.body_len = 0


class _StreamWriter:
    """File-like facade handed to ``stream_into``: ``write`` enqueues bytes
    on the owning connection through the loop (thread-safe) and raises
    ``BrokenPipeError`` once the subscriber is gone, which is how the
    stream thread learns to exit."""

    def __init__(self, loop: "_WireLoop", conn: _Conn):
        self._loop = loop
        self._conn = conn

    def write(self, data) -> int:
        if self._conn.closed or not self._loop.running:
            raise BrokenPipeError("stream subscriber gone")
        self._loop.send(self._conn, bytes(data))
        return len(data)

    def flush(self) -> None:
        pass


class _Responder:
    """One request's response channel: claim-once send of a single
    envelope, or promotion to a chunked push stream.

    This object is what the gateway's ``*_into`` methods (and tests that
    monkeypatch them) receive as ``handler`` — it keeps the old handler's
    ``_send_ok`` / ``_send_error`` surface.  ``claim()`` is the arbiter
    between racing completion paths (a future callback vs. its timeout
    timer): exactly one caller wins and sends."""

    def __init__(self, loop: "_WireLoop", conn: _Conn, headers: _Headers,
                 keep_alive: bool):
        self._loop = loop
        self._conn = conn
        self.headers = headers
        self.keep_alive = keep_alive
        #: response codec, negotiated per request via Accept
        self.binary = wire.wants_binary(headers.get("accept"))
        self.tenant: Optional[str] = None
        self._lock = threading.Lock()
        self._claimed = False
        self._responded = False

    @property
    def gateway(self) -> "ControlPlaneGateway":
        return self._loop.gateway

    def claim(self) -> bool:
        """Reserve the right to respond; True exactly once."""
        with self._lock:
            if self._claimed:
                return False
            self._claimed = True
            return True

    # -- single-envelope responses -------------------------------------------
    def _send(self, status: int, envelope: Dict) -> None:
        with self._lock:
            if self._responded:
                return
            self._responded = True
            self._claimed = True
        body, ctype = wire.encode_envelope(envelope, self.binary)
        head = (f"HTTP/1.1 {status} {_REASONS.get(status, '')}\r\n"
                f"Content-Type: {ctype}\r\n"
                f"Content-Length: {len(body)}\r\n"
                f"Connection: {'keep-alive' if self.keep_alive else 'close'}"
                "\r\n\r\n").encode("latin-1")
        self._loop.finish_response(self._conn, head + body,
                                   close_after=not self.keep_alive)

    def _send_ok(self, kind: str, body: Dict) -> None:
        self._send(200, wire.ok_envelope(kind, body,
                                         plane_id=self.gateway.plane_id))

    def _send_error(self, kind: str, err: WireError) -> None:
        self._send(wire.http_status(err.code),
                   wire.error_envelope(kind, err,
                                       plane_id=self.gateway.plane_id))

    # -- chunked push streams -------------------------------------------------
    def begin_stream(self, content_type: str = "application/x-ndjson"
                     ) -> _StreamWriter:
        """Send the stream response head and hand back the chunk writer.
        A streamed connection never returns to keep-alive rotation."""
        with self._lock:
            if self._responded:
                raise RuntimeError("response already sent")
            self._responded = True
            self._claimed = True
        self.keep_alive = False
        head = (f"HTTP/1.1 200 OK\r\n"
                f"Content-Type: {content_type}\r\n"
                "Cache-Control: no-cache\r\n"
                "Transfer-Encoding: chunked\r\n"
                "Connection: close\r\n\r\n").encode("latin-1")
        self._loop.begin_stream(self._conn, head)
        return _StreamWriter(self._loop, self._conn)

    def end_stream(self) -> None:
        """Close the connection once buffered chunks have drained (the
        terminal 0-chunk is written by ``streaming.end_chunks``)."""
        self._loop.finish_stream(self._conn)


class _WireLoop:
    """The selectors event loop: sole owner of every gateway socket.

    All socket reads, writes, and closes happen on the loop thread; other
    threads (scheduler futures, stream writers, telemetry listeners, timer
    users) hand work over via ``call_soon`` — a lock-guarded task queue plus
    a socketpair wakeup — or schedule deferred work with ``call_later``
    (timer heap, drives poll timeouts and long-poll expiry).  Per-connection
    write buffers absorb what the kernel won't take immediately; the
    selector's write interest is registered only while a buffer is
    non-empty."""

    MAX_HEADER_BYTES = 65536
    MAX_BODY_BYTES = 64 * 1024 * 1024
    RECV_CHUNK = 1 << 18

    def __init__(self, gateway: "ControlPlaneGateway", host: str, port: int,
                 backlog: int = 512):
        self.gateway = gateway
        self.running = False
        self._sel = selectors.DefaultSelector()
        self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        # the roadmap tests restart gateways on a fixed port; without
        # REUSEADDR the lingering TIME_WAIT from the previous instance
        # would make the rebind fail
        self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._listener.bind((host, port))
        self._listener.listen(backlog)
        self._listener.setblocking(False)
        self.address = self._listener.getsockname()
        self._wake_r, self._wake_w = socket.socketpair()
        self._wake_r.setblocking(False)
        self._wake_w.setblocking(False)
        self._sel.register(self._listener, selectors.EVENT_READ, "accept")
        self._sel.register(self._wake_r, selectors.EVENT_READ, "wake")
        self._tasks: "deque[Callable[[], None]]" = deque()
        self._tasks_lock = threading.Lock()
        self._timers: List[Tuple[float, int, Callable[[], None]]] = []
        self._timer_seq = itertools.count()
        self._conns: Dict[int, _Conn] = {}
        self._thread: Optional[threading.Thread] = None
        self._ident: Optional[int] = None

    # -- lifecycle ------------------------------------------------------------
    def start(self, name: str) -> None:
        self.running = True
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name=name)
        self._thread.start()

    def stop(self) -> None:
        self.running = False
        if self._thread is None:
            self._teardown()           # bound but never started
            return
        self._wakeup()
        self._thread.join(timeout=10.0)

    def _teardown(self) -> None:
        for conn in list(self._conns.values()):
            self._close_conn(conn)
        for s in (self._listener, self._wake_r, self._wake_w):
            try:
                s.close()
            except OSError:
                pass
        try:
            self._sel.close()
        except (OSError, RuntimeError):
            pass

    # -- thread-safe scheduling ----------------------------------------------
    def _on_loop(self) -> bool:
        return threading.get_ident() == self._ident

    def _wakeup(self) -> None:
        try:
            self._wake_w.send(b"\x01")
        except (BlockingIOError, OSError):
            pass                       # already pending / already closed

    def call_soon(self, fn: Callable[[], None]) -> None:
        with self._tasks_lock:
            self._tasks.append(fn)
        if not self._on_loop():
            self._wakeup()

    def call_later(self, delay_s: float, fn: Callable[[], None]) -> None:
        deadline = time.monotonic() + max(0.0, delay_s)  # planelint: allow(clock-seam) — selector-loop timer

        def arm() -> None:
            heapq.heappush(self._timers,
                           (deadline, next(self._timer_seq), fn))
        if self._on_loop():
            arm()
        else:
            self.call_soon(arm)

    def _safe(self, fn: Callable[[], None]) -> None:
        try:
            fn()
        except Exception:                                  # noqa: BLE001
            pass                       # loop must survive any callback

    # -- the loop -------------------------------------------------------------
    def _run(self) -> None:
        self._ident = threading.get_ident()
        while self.running:
            now = time.monotonic()  # planelint: allow(clock-seam) — selector-loop timer
            while self._timers and self._timers[0][0] <= now:
                _, _, fn = heapq.heappop(self._timers)
                self._safe(fn)
            with self._tasks_lock:
                has_tasks = bool(self._tasks)
            if has_tasks:
                timeout: Optional[float] = 0.0
            elif self._timers:
                timeout = max(0.0, self._timers[0][0] - time.monotonic())  # planelint: allow(clock-seam) — selector-loop timer
            else:
                timeout = None
            try:
                events = self._sel.select(timeout)
            except OSError:
                continue
            for key, mask in events:
                data = key.data
                if data == "accept":
                    self._accept()
                elif data == "wake":
                    self._drain_wake()
                else:
                    if mask & selectors.EVENT_WRITE:
                        self._on_writable(data)
                    if mask & selectors.EVENT_READ and not data.closed:
                        self._on_readable(data)
            while True:
                with self._tasks_lock:
                    if not self._tasks:
                        break
                    fn = self._tasks.popleft()
                self._safe(fn)
        self._teardown()

    def _drain_wake(self) -> None:
        try:
            while self._wake_r.recv(4096):
                pass
        except (BlockingIOError, OSError):
            pass

    # -- connections ----------------------------------------------------------
    def _accept(self) -> None:
        while True:
            try:
                sock, _addr = self._listener.accept()
            except (BlockingIOError, OSError):
                return
            sock.setblocking(False)
            try:
                sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            except OSError:
                pass
            conn = _Conn(sock)
            self._conns[conn.fd] = conn
            self._sel.register(sock, selectors.EVENT_READ, conn)

    def _close_conn(self, conn: _Conn) -> None:
        with conn.lock:
            if conn.closed:
                return
            conn.closed = True
            try:
                self._sel.unregister(conn.sock)
            except (KeyError, ValueError, OSError, RuntimeError):
                pass
            try:
                conn.sock.close()
            except OSError:
                pass
        self._conns.pop(conn.fd, None)

    def _set_mask(self, conn: _Conn, mask: int) -> None:
        if conn.closed or mask == conn.events:
            return
        try:
            self._sel.modify(conn.sock, mask, conn)
            conn.events = mask
        except (KeyError, ValueError, OSError):
            self._close_conn(conn)

    # -- reads ---------------------------------------------------------------
    def _on_readable(self, conn: _Conn) -> None:
        try:
            data = conn.sock.recv(self.RECV_CHUNK)
        except (BlockingIOError, InterruptedError):
            return
        except OSError:
            self._close_conn(conn)
            return
        if not data:
            self._close_conn(conn)
            return
        conn.rbuf += data
        self._process(conn)

    def _process(self, conn: _Conn) -> None:
        """Parse as many complete requests as the buffer holds.  Parsing
        pauses while a response is owed (``awaiting_response``) so a
        pipelining client still gets responses in request order, and stops
        for good once the connection is promoted to a push stream."""
        if conn.in_process:
            return                     # re-entry via an inline response
        conn.in_process = True
        try:
            while (not conn.closed and not conn.awaiting_response
                   and not conn.streaming):
                if conn.head is None:
                    idx = conn.rbuf.find(b"\r\n\r\n")
                    if idx < 0:
                        if len(conn.rbuf) > self.MAX_HEADER_BYTES:
                            self._reject_malformed(conn, 431)
                        return
                    raw = bytes(conn.rbuf[:idx])
                    del conn.rbuf[:idx + 4]
                    try:
                        method, path, version, headers = _parse_head(raw)
                        body_len = int(headers.get("content-length") or 0)
                    except ValueError:
                        self._reject_malformed(conn, 400)
                        return
                    if body_len < 0 or body_len > self.MAX_BODY_BYTES:
                        self._reject_malformed(conn, 413)
                        return
                    conn_hdr = (headers.get("connection") or "").lower()
                    keep_alive = ("keep-alive" in conn_hdr
                                  if version == "HTTP/1.0"
                                  else "close" not in conn_hdr)
                    conn.head = (method, path, headers, keep_alive)
                    conn.body_len = body_len
                if len(conn.rbuf) < conn.body_len:
                    return
                body = bytes(conn.rbuf[:conn.body_len])
                del conn.rbuf[:conn.body_len]
                method, path, headers, keep_alive = conn.head
                conn.head = None
                conn.body_len = 0
                conn.awaiting_response = True
                responder = _Responder(self, conn, headers, keep_alive)
                self.gateway.handle_request(responder, method, path,
                                            headers, body)
        finally:
            conn.in_process = False

    def _reject_malformed(self, conn: _Conn, status: int) -> None:
        body = (b'{"ok": false, "error": {"code": "BAD_REQUEST", '
                b'"message": "malformed HTTP request"}}')
        head = (f"HTTP/1.1 {status} {_REASONS.get(status, '')}\r\n"
                "Content-Type: application/json\r\n"
                f"Content-Length: {len(body)}\r\n"
                "Connection: close\r\n\r\n").encode("latin-1")
        self._do_send(conn, head + body, close_after=True)

    # -- writes ---------------------------------------------------------------
    def send(self, conn: _Conn, data: bytes, close_after: bool = False
             ) -> None:
        """Thread-safe enqueue of raw bytes on a connection."""
        if self._on_loop():
            self._do_send(conn, data, close_after)
        else:
            self.call_soon(lambda: self._do_send(conn, data, close_after))

    def _do_send(self, conn: _Conn, data: bytes, close_after: bool) -> None:
        if conn.closed:
            return
        if close_after:
            conn.close_after_write = True
        if not conn.wbuf:
            # optimistic inline send: the common case on loopback is that
            # the kernel takes the whole response without a selector pass
            try:
                n = conn.sock.send(data)
            except (BlockingIOError, InterruptedError):
                n = 0
            except OSError:
                self._close_conn(conn)
                return
            if n < len(data):
                conn.wbuf += data[n:]
        else:
            conn.wbuf += data
        self._after_write(conn)

    def _on_writable(self, conn: _Conn) -> None:
        if conn.closed or not conn.wbuf:
            self._after_write(conn)
            return
        try:
            n = conn.sock.send(memoryview(conn.wbuf)[:self.RECV_CHUNK])
        except (BlockingIOError, InterruptedError):
            return
        except OSError:
            self._close_conn(conn)
            return
        del conn.wbuf[:n]
        self._after_write(conn)

    def _after_write(self, conn: _Conn) -> None:
        if conn.closed:
            return
        if conn.wbuf:
            self._set_mask(conn, selectors.EVENT_READ | selectors.EVENT_WRITE)
        else:
            self._set_mask(conn, selectors.EVENT_READ)
            if conn.close_after_write:
                self._close_conn(conn)

    def finish_response(self, conn: _Conn, data: bytes,
                        close_after: bool) -> None:
        """Send a complete response and resume request parsing on the
        connection (thread-safe; deferred responses land here from
        scheduler worker threads)."""
        if self._on_loop():
            self._do_finish(conn, data, close_after)
            return
        # fast path: a worker thread sends the whole response itself,
        # skipping a loop wakeup (and its GIL handoff).  Legal only while
        # the conn is quiescent — awaiting_response parks reads, an empty
        # wbuf means no write interest — and only for keep-alive responses
        # (close_after needs loop-side mask/reap work anyway).
        if not close_after:
            with conn.lock:
                if (not conn.closed and conn.awaiting_response
                        and not conn.wbuf and not conn.streaming):
                    try:
                        n = conn.sock.send(data)
                    except (BlockingIOError, InterruptedError):
                        n = 0
                    except OSError:
                        n = len(data)   # dead conn; the loop reaps it
                    if n == len(data):
                        conn.awaiting_response = False
                        if conn.rbuf:   # pipelined bytes parked meanwhile
                            self.call_soon(
                                lambda: conn.closed
                                or conn.awaiting_response
                                or self._process(conn))
                        return
                    data = data[n:]     # tail drains through the loop
        self.call_soon(lambda: self._do_finish(conn, data, close_after))

    def _do_finish(self, conn: _Conn, data: bytes, close_after: bool) -> None:
        if conn.closed:
            return
        self._do_send(conn, data, close_after)
        conn.awaiting_response = False
        if not conn.closed and not conn.close_after_write:
            self._process(conn)        # pipelined bytes may already be here

    def begin_stream(self, conn: _Conn, head: bytes) -> None:
        def promote() -> None:
            if conn.closed:
                return
            conn.streaming = True
            conn.awaiting_response = False
            self._do_send(conn, head, close_after=False)
        if self._on_loop():
            promote()
        else:
            self.call_soon(promote)

    def finish_stream(self, conn: _Conn) -> None:
        def wind_down() -> None:
            if conn.closed:
                return
            conn.close_after_write = True
            self._after_write(conn)
        if self._on_loop():
            wind_down()
        else:
            self.call_soon(wind_down)


class _TelemetryWaiter:
    """A parked ``/v1/telemetry`` long-poll: holds no thread.  Registered
    as a cursor-log listener and poked on every append; whoever first sees
    matching events (a poke) or the deadline (a loop timer) claims the
    responder and answers.  Non-matching events silently advance the
    cursor, preserving the blocking read's filtered-long-poll contract."""

    def __init__(self, gw: "ControlPlaneGateway", handler: _Responder,
                 cursor: int, limit: int, resource: Optional[str], match):
        self.gw = gw
        self.handler = handler
        self.cursor = cursor
        self.limit = limit
        self.resource = resource
        self.match = match
        self._lock = threading.Lock()
        self._done = False

    def _read(self) -> Dict:
        return self.gw.telemetry_log.read(
            self.cursor, timeout_s=0.0, limit=self.limit,
            resource=self.resource, match=self.match)

    def poke(self) -> None:
        with self._lock:
            if self._done:
                return
            out = self._read()
            if not out["events"] and not out["closed"]:
                self.cursor = out["next_cursor"]
                return
            self._done = True
        self._finish(out)

    def expire(self) -> None:
        with self._lock:
            if self._done:
                return
            self._done = True
            out = self._read()
        self._finish(out)

    def _finish(self, out: Dict) -> None:
        self.gw.telemetry_log.remove_listener(self.poke)
        if self.handler.claim():
            out.pop("closed", None)
            self.handler._send_ok("telemetry", out)


class ControlPlaneGateway:
    """Event-loop HTTP front-end over one control plane (one Orchestrator +
    one scheduler worker pool + one telemetry cursor log).

        gw = ControlPlaneGateway(orch, plane="edge").start()
        ... ControlPlaneClient(gw.url) ...
        gw.stop()

    A gateway OWNS its scheduler unless one is passed in; ``stop()`` shuts
    down what it owns and leaves the orchestrator itself alone (planes
    outlive their wire frontends).  ``workers`` keeps sizing the scheduler
    pool — the wire layer itself no longer spends a thread per connection."""

    def __init__(self, orchestrator: Orchestrator, port: int = 0,
                 plane: str = "plane", workers: int = 8,
                 scheduler: Optional[ControlPlaneScheduler] = None,
                 api_keys: Optional[Dict[str, str]] = None,
                 telemetry_capacity: int = 4096):
        self.orchestrator = orchestrator
        self.plane = plane
        # the gateway names the plane; the orchestrator owns its identity
        self.topology = orchestrator.topology
        self.topology.set_name(plane)
        #: optional wire auth: api key -> tenant it authenticates as
        self.api_keys = dict(api_keys) if api_keys else None
        self._owns_scheduler = scheduler is None
        self.scheduler = scheduler or ControlPlaneScheduler(
            orchestrator, workers=workers)
        self.telemetry_log = TelemetryCursorLog(orchestrator.bus,
                                                capacity=telemetry_capacity)
        self._tickets: Dict[str, Future] = {}
        self._tickets_lock = threading.Lock()
        self._started_at = orchestrator.clock.now()
        self._loop = _WireLoop(self, "127.0.0.1", port)
        self.port = self._loop.address[1]

    # -- lifecycle ------------------------------------------------------------
    def start(self) -> "ControlPlaneGateway":
        self._loop.start(name=f"phys-mcp-gateway-{self.plane}")
        return self

    def stop(self) -> None:
        # loop teardown severs every live keep-alive connection: a killed
        # edge gateway must LOOK killed to its cloud parent (federation
        # failure semantics)
        self._loop.stop()
        self.telemetry_log.close()
        if self._owns_scheduler:
            self.scheduler.shutdown(wait=False)

    @property
    def url(self) -> str:
        return f"http://127.0.0.1:{self.port}"

    @property
    def plane_id(self) -> str:
        return self.topology.plane_id

    # -- wire auth ------------------------------------------------------------
    def authenticate(self, headers) -> Optional[str]:
        """Map the request's Bearer credential onto its tenant.  Open
        gateway (no ``api_keys``): returns None, wire ``tenant`` field is
        trusted as before.  Keyed gateway: missing/unknown credentials are
        a structured ``UNAUTHORIZED`` refusal."""
        if not self.api_keys:
            return None
        auth = headers.get("Authorization", "") or ""
        if auth.startswith("Bearer "):
            tenant = self.api_keys.get(auth[len("Bearer "):].strip())
            if tenant is not None:
                return tenant
        raise ControlPlaneError(
            ErrorCode.UNAUTHORIZED,
            "missing or unknown plane credentials "
            "(this gateway requires 'Authorization: Bearer <api-key>')",
            {"plane": self.plane})

    # -- routing --------------------------------------------------------------
    def handle_request(self, handler: _Responder, method: str, path: str,
                       headers: _Headers, raw_body: bytes) -> None:
        """Dispatch one parsed request.  Runs on the loop thread; endpoint
        handlers either respond inline or park the responder on a future /
        listener / timer and return immediately."""
        parts = wire.split_path(path)
        if parts[:1] != ("v1",):
            return handler._send_error("error", WireError(
                ErrorCode.NOT_FOUND, f"unknown path {path!r} "
                                     "(protocol v1 lives under /v1/)"))
        route = parts[1] if len(parts) > 1 else ""
        arg = parts[2] if len(parts) > 2 else None
        q = {k: v[-1] for k, v in parse_qs(urlparse(path).query).items()}
        kind = route or "error"
        try:
            # wire auth runs before ANY route logic; the mapped tenant (or
            # None on an open gateway) is what task submission trusts
            handler.tenant = self.authenticate(headers)
            if method == "GET":
                self._route_get(handler, route, arg, q, path)
            elif method == "POST":
                self._route_post(handler, route, headers, raw_body, path)
            else:
                handler._send_error(kind, WireError(
                    ErrorCode.NOT_FOUND, f"unsupported method {method!r}"))
        except ControlPlaneError as e:
            handler._send_error(kind, WireError(e.code, e.message, e.detail))
        except Exception as e:         # noqa: BLE001 — wire boundary
            handler._send_error(kind, WireError(ErrorCode.INTERNAL, repr(e)))

    def _route_get(self, handler: _Responder, route: str,
                   arg: Optional[str], q: Dict[str, str], path: str) -> None:
        if route == "health":
            handler._send_ok("health", self.health_body())
        elif route == "discover":
            handler._send_ok("discover", self.discover_body(q))
        elif route == "describe" and arg:
            handler._send_ok("describe", self.describe_body(arg))
        elif route == "twin" and arg:
            handler._send_ok("twin", self.twin_body(arg))
        elif route == "poll" and arg:
            self.poll_into(handler, arg, q)
        elif route == "telemetry":
            self.telemetry_into(handler, q)
        elif route == "stream":
            self._spawn_stream(handler, q)
        elif route == "topology":
            handler._send_ok("topology", self.topology_body())
        else:
            handler._send_error("error", WireError(
                ErrorCode.NOT_FOUND, f"unknown route {path!r}"))

    def _route_post(self, handler: _Responder, route: str, headers: _Headers,
                    raw_body: bytes, path: str) -> None:
        if route == "invoke":
            self.invoke_into(handler,
                             self._parse_body(headers, raw_body, "invoke"),
                             tenant=handler.tenant)
        elif route == "submit":
            handler._send_ok("submit", self.submit_body(
                self._parse_body(headers, raw_body, "submit"),
                tenant=handler.tenant))
        elif route == "submit_many":
            handler._send_ok("submit_many", self.submit_many_body(
                self._parse_body(headers, raw_body, "submit_many"),
                tenant=handler.tenant))
        elif route == "submit_coalesced":
            handler._send_ok("submit_coalesced", self.submit_coalesced_body(
                self._parse_body(headers, raw_body, "submit_coalesced"),
                tenant=handler.tenant))
        elif route == "poll_coalesced":
            self.poll_coalesced_into(handler, self._parse_body(
                headers, raw_body, "poll_coalesced"))
        else:
            handler._send_error("error", WireError(
                ErrorCode.NOT_FOUND, f"unknown route {path!r}"))

    @staticmethod
    def _parse_body(headers: _Headers, raw: bytes, expect_kind: str) -> Dict:
        """Decode the request envelope by its negotiated codec (Content-Type
        header, magic-byte sniff as fallback) and validate it."""
        envelope = wire.decode_envelope(raw, headers.get("content-type"))
        return wire.parse_request(envelope, expect_kind=expect_kind)

    # -- endpoint bodies ------------------------------------------------------
    def health_body(self) -> Dict:
        orch = self.orchestrator
        resources = {}
        for desc in orch.registry.all():
            snap = orch.bus.snapshot(desc.resource_id)
            resources[desc.resource_id] = (
                wire.snapshot_to_wire(snap) if snap is not None else None)
        breakers = None
        if orch.health is not None and hasattr(orch.health, "status"):
            try:
                breakers = orch.health.status()
            except Exception:                              # noqa: BLE001
                breakers = None
        return {
            "plane": self.plane,
            "uptime_s": round(
                self.orchestrator.clock.now() - self._started_at, 3),
            "resources": resources,
            "breakers": breakers,
            "scheduler": {"pending": self.scheduler.pending},
        }

    def discover_body(self, q: Dict[str, str]) -> Dict:
        filters = {k: q[k] for k in ("function", "input_modality",
                                     "output_modality", "latency_regime",
                                     "substrate_class") if k in q}
        if "repeated" in q:
            filters["repeated"] = q["repeated"].lower() in ("1", "true")
        descs = self.orchestrator.discover(**filters)
        return {"descriptors": [wire.descriptor_to_wire(d) for d in descs]}

    def _descriptor_or_404(self, rid: str):
        desc = self.orchestrator.registry.get(rid)
        if desc is None:
            raise ControlPlaneError(ErrorCode.NOT_FOUND,
                                    f"no such resource {rid!r}")
        return desc

    def describe_body(self, rid: str) -> Dict:
        desc = self._descriptor_or_404(rid)
        snap = self.orchestrator.bus.snapshot(rid)
        twin = self.orchestrator.twins.get(rid)
        return {
            "descriptor": wire.descriptor_to_wire(desc),
            "snapshot": wire.snapshot_to_wire(snap) if snap else None,
            "twin": twin.to_dict() if twin is not None else None,
        }

    def twin_body(self, rid: str) -> Dict:
        self._descriptor_or_404(rid)
        twin = self.orchestrator.twins.get(rid)
        if twin is None:
            raise ControlPlaneError(ErrorCode.NOT_FOUND,
                                    f"resource {rid!r} has no twin binding")
        return {"twin": twin.to_dict()}

    @staticmethod
    def _q_num(q: Dict, key: str, default, cast):
        """Numeric query param or a structured BAD_REQUEST (a typo'd
        cursor must not surface as INTERNAL)."""
        try:
            return cast(q.get(key, default))
        except (TypeError, ValueError):
            raise wire.ProtocolError(
                f"query param {key!r} must be a number, got {q.get(key)!r}")

    def _telemetry_params(self, q: Dict[str, str]):
        cursor = self._q_num(q, "cursor", 0, int)
        timeout_s = min(self._q_num(q, "timeout_s", 0.0, float), 30.0)
        limit = max(1, min(self._q_num(q, "limit", 256, int), 1024))
        try:
            filt = streaming.StreamFilter.from_query(q)
        except ValueError as e:
            raise wire.ProtocolError(str(e))
        return cursor, timeout_s, limit, q.get("resource"), filt

    def telemetry_body(self, q: Dict[str, str]) -> Dict:
        """Blocking read variant, kept for in-process callers; the wire
        route uses :meth:`telemetry_into` so long-polls park instead of
        holding the loop."""
        cursor, timeout_s, limit, resource, filt = self._telemetry_params(q)
        body = self.telemetry_log.read(
            cursor, timeout_s=timeout_s, limit=limit,
            resource=resource, match=filt.matches)
        body.pop("closed", None)      # cursor-log detail, not wire surface
        return body

    def telemetry_into(self, handler: _Responder, q: Dict[str, str]) -> None:
        cursor, timeout_s, limit, resource, filt = self._telemetry_params(q)
        out = self.telemetry_log.read(cursor, timeout_s=0.0, limit=limit,
                                      resource=resource, match=filt.matches)
        if out["events"] or timeout_s <= 0.0 or out["closed"]:
            out.pop("closed", None)
            handler._send_ok("telemetry", out)
            return
        waiter = _TelemetryWaiter(self, handler, out["next_cursor"], limit,
                                  resource, filt.matches)
        self.telemetry_log.add_listener(waiter.poke)
        self._loop.call_later(timeout_s, waiter.expire)
        waiter.poke()                  # event raced the registration?

    def topology_body(self) -> Dict:
        body = self.topology.to_dict()
        body["plane"] = self.plane
        body["registry_epoch"] = self.orchestrator.registry.epoch
        body["resources"] = len(self.orchestrator.registry.all())
        return body

    # -- streaming subscriptions ----------------------------------------------
    #: heartbeat interval bounds (s): floor keeps idle subscriptions cheap,
    #: ceiling bounds how long a silently-dead plane can look alive
    MIN_HEARTBEAT_S, MAX_HEARTBEAT_S = 0.2, 30.0

    def _spawn_stream(self, handler: _Responder, q: Dict[str, str]) -> None:
        """Run the subscription loop on its own thread: it blocks on the
        cursor log between events, which the loop thread must never do.
        Chunk writes funnel back through the loop's thread-safe enqueue."""
        threading.Thread(target=self._stream_entry, args=(handler, q),
                         daemon=True,
                         name=f"phys-mcp-stream-{self.plane}").start()

    def _stream_entry(self, handler: _Responder, q: Dict[str, str]) -> None:
        try:
            self.stream_into(handler, q)
        except ControlPlaneError as e:
            handler._send_error("stream", WireError(e.code, e.message,
                                                    e.detail))
        except (BrokenPipeError, ConnectionResetError, OSError):
            pass                       # subscriber went away; nothing to do
        except Exception as e:         # noqa: BLE001 — wire boundary
            handler._send_error("stream", WireError(ErrorCode.INTERNAL,
                                                    repr(e)))

    def stream_into(self, handler: _Responder, q: Dict[str, str]) -> None:
        """One server-push subscription: chunked ndjson over the open
        response.  Events come from the same sequence-numbered ring the
        cursor endpoint reads, so seq-gaplessness (zero lost events) and
        resume-by-cursor hold across both transports.  The loop runs until
        the client disconnects, the gateway stops, or ``max_s`` lapses."""
        try:
            filt = streaming.StreamFilter.from_query(q)
        except ValueError as e:
            raise wire.ProtocolError(str(e))
        cursor = self._q_num(q, "cursor", self.telemetry_log.tail(), int)
        heartbeat_s = min(max(self._q_num(q, "heartbeat_s", 10.0, float),
                              self.MIN_HEARTBEAT_S), self.MAX_HEARTBEAT_S)
        max_s = self._q_num(q, "max_s", 0.0, float)
        deadline = (time.monotonic() + max_s) if max_s > 0 else None  # planelint: allow(clock-seam) — stream deadline vs real client
        w = handler.begin_stream("application/x-ndjson")
        try:
            streaming.write_chunk(w, streaming.control_line(
                "hello", plane_id=self.plane_id, plane=self.plane,
                cursor=cursor, protocol_version=wire.PROTOCOL_VERSION,
                registry_epoch=self.orchestrator.registry.epoch))
            if cursor == 0:
                # change-feed baseline: a from-the-beginning subscriber gets
                # the CURRENT fleet — synthetic register events plus each
                # member's stored health snapshot (seq 0 — they are state,
                # not history; the ring cannot serve this because resources
                # typically register before any gateway exists).  Baseline +
                # live updates = a consistent feed with no re-fetch.
                epoch = self.orchestrator.registry.epoch
                for desc in self.orchestrator.registry.all():
                    entry = {"resource_id": desc.resource_id,
                             "kind": "registry", "seq": 0,
                             "timestamp": self.orchestrator.clock.now(),
                             "severity": "info",
                             "fields": {"action": "register", "epoch": epoch,
                                        "plane_id": self.plane_id,
                                        "descriptor": desc.to_dict(),
                                        "baseline": True}}
                    if filt.matches(entry):
                        streaming.write_chunk(w, streaming.event_line(entry))
                    snap = self.orchestrator.bus.snapshot(desc.resource_id)
                    if snap is None:
                        continue
                    fields = dict(snap.to_dict(), baseline=True)
                    entry = {"resource_id": desc.resource_id,
                             "kind": "health", "seq": 0,
                             "timestamp": self.orchestrator.clock.now(),
                             "severity": streaming.event_severity("health",
                                                                  fields),
                             "fields": fields}
                    if filt.matches(entry):
                        streaming.write_chunk(w, streaming.event_line(entry))
            while True:
                timeout = heartbeat_s
                if deadline is not None:
                    timeout = min(timeout, max(
                        0.0,
                        deadline - time.monotonic()))  # planelint: allow(clock-seam) — wire transport
                out = self.telemetry_log.read(
                    cursor, timeout_s=timeout, limit=256, match=filt.matches)
                cursor = out["next_cursor"]
                for entry in out["events"]:
                    streaming.write_chunk(w, streaming.event_line(entry))
                if out["closed"] or (
                        deadline is not None
                        and time.monotonic() >= deadline):  # planelint: allow(clock-seam) — wire transport
                    streaming.write_chunk(w, streaming.control_line(
                        "end", cursor=cursor,
                        dropped_events=out["dropped_events"]))
                    streaming.end_chunks(w)
                    handler.end_stream()
                    return
                if not out["events"]:
                    streaming.write_chunk(w, streaming.control_line(
                        "heartbeat", cursor=cursor,
                        dropped_events=out["dropped_events"]))
        except (BrokenPipeError, ConnectionResetError, OSError):
            pass                       # subscriber went away; nothing to do

    # -- execution ------------------------------------------------------------
    #: resolved tickets retained for polling before eviction (FIFO)
    MAX_TICKETS = 1024

    def _submit(self, body: Dict, tenant: Optional[str] = None) -> Future:
        try:
            task = wire.task_from_wire(body.get("task") or {})
        except (TypeError, ValueError, KeyError) as e:
            # a task body the dataclass refuses is the CLIENT's error, not a
            # retryable server fault
            raise wire.ProtocolError(f"malformed task body: {e!r}")
        if tenant is not None and task.tenant != tenant:
            # authenticated identity beats whatever tenant the wire claimed
            task = task.clone(tenant=tenant)
        deadline_s = body.get("deadline_s")
        try:
            return self.scheduler.submit_async(task, deadline_s=deadline_s)
        except SchedulerClosed as e:
            raise ControlPlaneError(ErrorCode.PLANE_UNAVAILABLE, str(e))

    def _outcome_wire(self, result, trace) -> Dict:
        """One task outcome as coalesced-poll wire fields."""
        if result.status == "completed":
            return {"state": "done", "ok": True,
                    "result": wire.result_to_wire(result),
                    "trace": wire.trace_to_wire(trace)}
        err = wire.rejection_to_error(result, trace)
        if err.code is ErrorCode.QUEUE_SATURATED:
            err.detail["retry_after_s"] = self.scheduler.retry_after_s()
        return {"state": "done", "ok": False, "error": err.to_wire()}

    def _respond_outcome(self, handler: _Responder, kind: str,
                         result, trace) -> None:
        """Completed results ride an ok envelope; anything else becomes the
        structured error envelope carrying code + trace (saturation errors
        additionally carry the live ``retry_after_s`` backoff hint)."""
        if result.status == "completed":
            handler._send_ok(kind, {
                "result": wire.result_to_wire(result),
                "trace": wire.trace_to_wire(trace),
            })
        else:
            err = wire.rejection_to_error(result, trace)
            if err.code is ErrorCode.QUEUE_SATURATED:
                err.detail["retry_after_s"] = self.scheduler.retry_after_s()
            handler._send_error(kind, err)

    def invoke_into(self, handler: _Responder, body: Dict,
                    tenant: Optional[str] = None) -> None:
        """Synchronous-on-the-wire invoke: the response is deferred onto the
        scheduler future's completion instead of parking a server thread."""
        fut = self._submit(body, tenant=tenant)

        def deliver(f: Future) -> None:
            try:
                try:
                    result, trace = f.result()
                except BaseException as e:                 # noqa: BLE001
                    handler._send_error("invoke", WireError(
                        ErrorCode.INTERNAL, repr(e)))
                    return
                self._respond_outcome(handler, "invoke", result, trace)
            except Exception as e:     # noqa: BLE001 — wire boundary
                handler._send_error("invoke", WireError(ErrorCode.INTERNAL,
                                                        repr(e)))
        fut.add_done_callback(deliver)

    def _store_ticket(self, fut: Future) -> str:
        ticket = f"ticket-{next(_ticket_ids):06d}"
        with self._tickets_lock:
            self._tickets[ticket] = fut
            # bound the store: evict the OLDEST RESOLVED tickets first (a
            # never-polled resolved future would otherwise retain its full
            # result forever); pending futures are only evicted when the
            # store is flooded with them
            while len(self._tickets) > self.MAX_TICKETS:
                victim = next((t for t, f in self._tickets.items()
                               if f.done()), None)
                if victim is None:
                    victim = next(iter(self._tickets))
                del self._tickets[victim]
        return ticket

    def submit_body(self, body: Dict, tenant: Optional[str] = None) -> Dict:
        return {"ticket": self._store_ticket(self._submit(body,
                                                          tenant=tenant))}

    def submit_many_body(self, body: Dict,
                         tenant: Optional[str] = None) -> Dict:
        tasks = body.get("tasks")
        if not isinstance(tasks, list):
            raise wire.ProtocolError("submit_many body needs a tasks list")
        deadline_s = body.get("deadline_s")
        # validate the WHOLE batch before queueing any of it: a malformed
        # task mid-list must not leave earlier tasks running on hardware
        # with their tickets never returned to the client
        parsed = []
        for i, t in enumerate(tasks):
            try:
                parsed.append(wire.task_from_wire(t or {}))
            except (TypeError, ValueError, KeyError) as e:
                raise wire.ProtocolError(
                    f"malformed task at index {i}: {e!r}")
        if tenant is not None:
            parsed = [t if t.tenant == tenant else t.clone(tenant=tenant)
                      for t in parsed]
        tickets = []
        for task in parsed:
            try:
                fut = self.scheduler.submit_async(task,
                                                  deadline_s=deadline_s)
            except SchedulerClosed as e:
                raise ControlPlaneError(ErrorCode.PLANE_UNAVAILABLE, str(e))
            tickets.append(self._store_ticket(fut))
        return {"tickets": tickets}

    def submit_coalesced_body(self, body: Dict,
                              tenant: Optional[str] = None) -> Dict:
        """Batched submit with PER-ENTRY outcomes (v1.2).  Unlike
        ``submit_many`` — whose all-or-nothing contract protects a single
        caller's batch — a coalesced frame carries tasks micro-batched from
        UNRELATED callers by the client SDK, so one malformed entry must
        fail alone, not poison its co-batched strangers.  Each outcome is
        either ``{"ticket": ...}`` or ``{"error": <wire error>}``, index-
        aligned with ``entries``."""
        entries = body.get("entries")
        if not isinstance(entries, list) or not entries:
            raise wire.ProtocolError(
                "submit_coalesced body needs a non-empty entries list")
        outcomes = []
        for entry in entries:
            entry = entry if isinstance(entry, dict) else {}
            try:
                task = wire.task_from_wire(entry.get("task") or {})
            except (TypeError, ValueError, KeyError) as e:
                outcomes.append({"error": WireError(
                    ErrorCode.BAD_REQUEST,
                    f"malformed task body: {e!r}").to_wire()})
                continue
            if tenant is not None and task.tenant != tenant:
                task = task.clone(tenant=tenant)
            try:
                fut = self.scheduler.submit_async(
                    task, deadline_s=entry.get("deadline_s"))
            except SchedulerClosed as e:
                outcomes.append({"error": WireError(
                    ErrorCode.PLANE_UNAVAILABLE, str(e)).to_wire()})
                continue
            outcomes.append({"ticket": self._store_ticket(fut)})
        return {"outcomes": outcomes}

    def poll_into(self, handler: _Responder, ticket: str,
                  q: Dict[str, str]) -> None:
        with self._tickets_lock:
            fut = self._tickets.get(ticket)
        if fut is None:
            raise ControlPlaneError(ErrorCode.NOT_FOUND,
                                    f"unknown ticket {ticket!r}")
        wait_s = min(self._q_num(q, "wait_s", 0.0, float), 30.0)

        def deliver(f: Future) -> None:
            if not handler.claim():
                return                 # the timeout timer answered first
            try:
                try:
                    result, trace = f.result()
                except BaseException as e:                 # noqa: BLE001
                    # exception-resolved future: release the ticket (every
                    # re-poll would re-raise forever), surface the error once
                    with self._tickets_lock:
                        self._tickets.pop(ticket, None)
                    handler._send_error("poll", WireError(ErrorCode.INTERNAL,
                                                          repr(e)))
                    return
                # deliver-once: the claiming response releases the ticket
                self._respond_outcome(handler, "poll", result, trace)
                with self._tickets_lock:
                    self._tickets.pop(ticket, None)
            except Exception as e:     # noqa: BLE001 — wire boundary
                handler._send_error("poll", WireError(ErrorCode.INTERNAL,
                                                      repr(e)))

        if fut.done():
            deliver(fut)
            return
        if wait_s <= 0.0:
            handler._send_ok("poll", {"state": "pending", "ticket": ticket})
            return

        def on_timeout() -> None:
            if handler.claim():
                handler._send_ok("poll", {"state": "pending",
                                          "ticket": ticket})
        fut.add_done_callback(deliver)
        self._loop.call_later(wait_s, on_timeout)

    def poll_coalesced_into(self, handler: _Responder, body: Dict) -> None:
        """Batched ticket poll (v1.2): one round-trip reports the state of
        N tickets.  With ``wait_s`` and every known ticket still pending,
        the response parks until the FIRST completion (or the deadline) and
        then reports all states — resolved outcomes are delivered-once
        exactly like ``poll``; unknown tickets get a per-entry NOT_FOUND
        instead of failing the frame."""
        tickets = body.get("tickets")
        if (not isinstance(tickets, list) or not tickets
                or not all(isinstance(t, str) for t in tickets)):
            raise wire.ProtocolError(
                "poll_coalesced body needs a non-empty tickets list")
        wait_s = min(self._q_num(body, "wait_s", 0.0, float), 30.0)
        with self._tickets_lock:
            futs = {t: self._tickets.get(t) for t in tickets}

        def report() -> Dict:
            outcomes = []
            for t in tickets:
                fut = futs.get(t)
                if fut is None:
                    outcomes.append({
                        "ticket": t, "state": "done", "ok": False,
                        "error": WireError(ErrorCode.NOT_FOUND,
                                           f"unknown ticket {t!r}").to_wire(),
                    })
                elif not fut.done():
                    outcomes.append({"ticket": t, "state": "pending"})
                else:
                    with self._tickets_lock:
                        self._tickets.pop(t, None)
                    try:
                        result, trace = fut.result()
                    except BaseException as e:             # noqa: BLE001
                        outcomes.append({
                            "ticket": t, "state": "done", "ok": False,
                            "error": WireError(ErrorCode.INTERNAL,
                                               repr(e)).to_wire()})
                    else:
                        outcomes.append(dict(self._outcome_wire(result,
                                                                trace),
                                             ticket=t))
            return {"outcomes": outcomes}

        live = [f for f in futs.values() if f is not None]
        if (wait_s <= 0.0 or len(live) < len(futs)
                or not live or any(f.done() for f in live)):
            handler._send_ok("poll_coalesced", report())
            return

        def fire(_f: Optional[Future] = None) -> None:
            if not handler.claim():
                return
            try:
                handler._send_ok("poll_coalesced", report())
            except Exception as e:     # noqa: BLE001 — wire boundary
                handler._send_error("poll_coalesced",
                                    WireError(ErrorCode.INTERNAL, repr(e)))
        self._loop.call_later(wait_s, fire)
        for f in live:
            f.add_done_callback(fire)
