"""phys-MCP wire layer: versioned protocol, gateway server, client SDK.

- :mod:`repro.gateway.protocol` — protocol v1: envelopes, faithful wire
  types, structured error taxonomy (re-exported from ``repro.core.errors``).
- :mod:`repro.gateway.server` — :class:`ControlPlaneGateway`, the threaded
  HTTP server exposing one control plane.
- :mod:`repro.gateway.client` — :class:`ControlPlaneClient`, the typed SDK.

Federation (a whole edge plane as one substrate of a cloud plane) lives in
:class:`repro.substrates.remote_plane.RemotePlaneAdapter`.
"""
from repro.gateway.protocol import (PROTOCOL_VERSION, ProtocolError,  # noqa: F401
                                    check_version)
from repro.gateway.server import (ControlPlaneGateway,  # noqa: F401
                                  TelemetryCursorLog)
from repro.gateway.client import ControlPlaneClient, GatewayError  # noqa: F401
from repro.gateway.stream import (SEVERITIES, StreamClosed,  # noqa: F401
                                  StreamFilter, TelemetryStream,
                                  event_severity)
